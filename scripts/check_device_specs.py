#!/usr/bin/env python
"""Lint every registered ``repro.arch`` DeviceSpec (runs in CI).

Checks, per device:
  * positive clock, sane topology (>=1 CU/SIMD; MXU dims positive);
  * every cycle-table instruction exists in the MFMA registry, with
    positive integer cycles and a boolean ``validated`` flag;
  * known dtypes: every instruction's operand dtype is one the
    instruction-selection policy can map from HLO;
  * validated-flag provenance: entries claiming ``validated=True`` must
    match the paper's measured tables (mi200/mi300) — derived devices may
    not inherit validation they never earned;
  * no s_set_gpr_idx-mode instruction carries a timing entry (the timing
    model cannot execute them, paper Section VI);
  * bandwidths/links are non-negative, and an advertised peak (if any)
    stays within 4x of the spec-derived peak;
  * serveability: the device's VMEM budget admits at least one valid
    ``paged_decode_attention`` tile plan for a production GQA geometry —
    the block-paged KV cache sizes its pool pages from exactly this
    plan, so a device that cannot plan it cannot serve.

Exit code 0 = catalog clean; 1 = violations (printed one per line).

    PYTHONPATH=src python scripts/check_device_specs.py
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.arch import HLO_DTYPE_TO_IN, get_device, list_devices  # noqa: E402
from repro.arch.registry import MI200_CYCLES, MI300_CYCLES  # noqa: E402
from repro.core import isa  # noqa: E402
from repro.kernels.plan import plan_for  # noqa: E402  (JAX-free module)

# The serve layer's page-size probe geometry: a dense production GQA
# head layout (32 query / 8 KV heads of 128) over a 512-token probe —
# the same call `repro.serve.paged_cache.default_page_size` makes.
_PAGED_PROBE = {"B": 1, "T": 512, "H": 32, "KV": 8, "hd": 128}

# The hardware-measured ground truth (paper Tables II-V): only these
# (device, instr) pairs may carry validated=True.
_VALIDATED_GROUND_TRUTH = {
    ("mi200", name): cycles
    for name, (cycles, v) in MI200_CYCLES.items() if v
}
_VALIDATED_GROUND_TRUTH.update({
    ("mi300", name): cycles
    for name, (cycles, v) in MI300_CYCLES.items() if v
})

_KNOWN_IN_DTYPES = set(HLO_DTYPE_TO_IN.values())


def check_spec(name: str) -> list:
    spec = get_device(name)
    errs = []

    def err(msg):
        errs.append(f"{name}: {msg}")

    if spec.clock_mhz <= 0:
        err(f"non-positive clock {spec.clock_mhz}")
    if spec.cu_count < 1 or spec.simd_per_cu < 1 or spec.mce_per_simd < 1:
        err("topology must have >=1 CU/SIMD/MCE")
    if spec.mxu_count < 0 or (spec.mxu_count and spec.mxu_dim < 1):
        err("bad MXU configuration")
    if not spec.has_cycle_table and not spec.mxu_count:
        err("neither a cycle table nor MXUs: no matrix path at all")
    # The kernel tile planner budgets block working sets against this;
    # a catalog device must be plannable (>= one aligned GEMM tile set).
    if spec.vmem_bytes <= 0:
        err("vmem_bytes must be positive (kernel tile-planning budget)")
    elif spec.vmem_bytes < 1 << 20:
        err(f"vmem_bytes={spec.vmem_bytes} cannot hold one MXU-aligned "
            "GEMM tile set (needs >= 1 MiB)")

    mem, ic = spec.memory, spec.interconnect
    for f in ("l1i_latency", "l1d_latency", "scalar_latency", "lds_latency",
              "l2_latency", "mem_latency", "valu_latency"):
        if getattr(mem, f) < 0:
            err(f"negative {f}")
    for f, v in (("l2_bw", mem.l2_bw), ("lds_bw", mem.lds_bw)):
        if v < 0:
            err(f"negative {f}")
    # hbm_bw/link_bw must be strictly positive: the roofline divides by
    # them (a zero would silently produce an infinite memory/collective
    # time for any device registered per the ROADMAP recipe).
    if mem.hbm_bw <= 0:
        err("hbm_bw must be positive (roofline memory term)")
    if ic.link_bw <= 0:
        err("link_bw must be positive (roofline collective term)")
    if ic.links < 1:
        err("interconnect needs >=1 link")

    for instr, entry in spec.cycle_table.items():
        meta = isa.MFMA_REGISTRY.get(instr)
        if meta is None:
            err(f"cycle table names unknown instruction {instr!r}")
            continue
        if not isinstance(entry.cycles, int) or entry.cycles < 1:
            err(f"{instr}: cycles must be a positive int, "
                f"got {entry.cycles!r}")
        if not isinstance(entry.validated, bool):
            err(f"{instr}: validated flag must be bool, "
                f"got {entry.validated!r}")
        if meta.in_dtype not in _KNOWN_IN_DTYPES:
            err(f"{instr}: operand dtype {meta.in_dtype!r} has no HLO "
                "mapping in the selection policy")
        if meta.gpr_idx_mode:
            err(f"{instr}: s_set_gpr_idx-mode instructions are not "
                "executable by the timing model (Section VI)")
        if entry.validated:
            truth = _VALIDATED_GROUND_TRUTH.get((name, instr))
            if truth is None:
                err(f"{instr}: claims validated=True but ({name}, {instr}) "
                    "is not in the paper's measured tables")
            elif truth != entry.cycles:
                err(f"{instr}: validated entry is {entry.cycles} cycles "
                    f"but the paper measured {truth}")

    # Peak must be derivable for EVERY device (the roofline and bridge
    # call it unconditionally) — e.g. a GPU table missing the canonical
    # dense instruction would pass every per-entry check yet crash there.
    try:
        derived = spec.peak_matrix_tflops * 1e12
    except Exception as e:  # noqa: BLE001 - any failure is a catalog bug
        err(f"cannot derive peak matrix throughput: {e}")
        derived = None
    if spec.peak_flops and derived:
        if not (derived / 4 <= spec.peak_flops <= derived * 4):
            err(f"advertised peak {spec.peak_flops:.3g} FLOP/s is >4x off "
                f"the spec-derived {derived:.3g}")

    # Serveability: the paged-decode planner must find a page size within
    # this device's VMEM budget, or PagedKVCache (and the whole
    # continuous-batching engine) cannot be constructed for it.
    for dt in ("bfloat16", "float32"):
        try:
            plan = plan_for("paged_decode_attention", _PAGED_PROBE,
                            dtype=dt, device=name)
        except Exception as e:  # noqa: BLE001 - any failure is a catalog bug
            err(f"no valid paged-decode plan for {dt} "
                f"(serve-layer page probe): {e}")
            continue
        page = plan.blocks.get("block_kv", 0)
        if page < 1 or _PAGED_PROBE["T"] % page:
            err(f"paged-decode plan for {dt} picked page {page}, which "
                f"does not tile the T={_PAGED_PROBE['T']} probe")
    return errs


def check_fleet_frontier(names: list) -> list:
    """Every registered device must yield a finite, feasible capacity
    frontier for every built-in traffic scenario — a new catalog entry
    whose bandwidths/peaks make the planner emit zero or infinite QPS
    is a catalog bug, not a planning result."""
    import math

    # deferred: pulls the serve layer (jax) unlike the pure spec checks
    from repro.fleet import frontier, list_scenarios

    errs = []
    rep = frontier(list_scenarios(), tuple(names))
    for r in rep.rows:
        where = f"{r.device}: scenario {r.scenario!r}"
        if not (math.isfinite(r.decode_tick_ms) and r.decode_tick_ms > 0):
            errs.append(f"{where} has a non-finite decode tick "
                        f"({r.decode_tick_ms})")
        elif not r.feasible:
            errs.append(f"{where} is infeasible under its SLO "
                        f"(decode tick {r.decode_tick_ms:.2f}ms vs "
                        f"p99 target {r.slo_p99_ms:g}ms)")
        elif not math.isfinite(r.cost_per_mtok):
            errs.append(f"{where} yields a non-finite cost per token")
    return errs


def main() -> int:
    failures = []
    names = list(list_devices())
    for name in names:
        failures += check_spec(name)
    failures += check_fleet_frontier(names)
    for f in failures:
        print(f"FAIL {f}")
    print(f"checked {len(names)} device specs "
          f"({', '.join(names)}) + fleet frontiers: "
          f"{'OK' if not failures else f'{len(failures)} violations'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
