"""Render the §Dry-run and §Roofline tables into EXPERIMENTS.md from the
experiments/dryrun/*.json artifacts (idempotent: replaces marker blocks)."""

import json
import pathlib
import re
import sys

sys.path.insert(0, "src")

from repro.launch.roofline import load_cells  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parent.parent


def dryrun_table() -> str:
    rows = []
    for f in sorted((ROOT / "experiments/dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        m = r.get("memory", {})
        h = r.get("hlo", {})
        rows.append((r["arch"], r["shape"], r["mesh"],
                     m.get("total_bytes_per_device", 0) / 2**30,
                     m.get("tpu_estimate_bytes_per_device", 0) / 2**30,
                     h.get("flops_per_device", 0),
                     h.get("collective_wire_bytes", 0) / 1e9,
                     r.get("compile_s", 0)))
    out = ["| arch | shape | mesh | mem GiB/dev | TPU-est GiB | FLOPs/dev "
           "| coll GB/dev | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for a, s, me, gb, tgb, fl, cw, cs in sorted(rows):
        out.append(f"| {a} | {s} | {me} | {gb:.2f} | {tgb:.2f} | {fl:.2e} "
                   f"| {cw:.1f} | {cs:.0f} |")
    return "\n".join(out)


def roofline_table() -> str:
    rows = load_cells(str(ROOT / "experiments/dryrun"))
    out = ["| arch | shape | compute_s | memory_s (kernel-adj / XLA-ref) "
           "| collective_s | dominant | MODEL/HLO | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_t']:.2f} "
            f"| {r['memory_t']:.2f} / {r['memory_t_xla']:.2f} "
            f"| {r['collective_t']:.2f} | {r['dominant']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} |")
    from collections import Counter
    census = Counter(r["dominant"] for r in rows)
    out.append("")
    out.append(f"Bottleneck census: {dict(census)}; constants: 197 TF/s "
               "bf16, 819 GB/s HBM, 2x50 GB/s ICI links.")
    return "\n".join(out)


def substitute(md: str, marker: str, table: str) -> str:
    block = f"<!-- {marker} -->\n{table}\n<!-- /{marker} -->"
    pat = re.compile(rf"<!-- {marker} -->.*?(<!-- /{marker} -->|$)",
                     re.DOTALL)
    if f"<!-- {marker} -->" in md:
        # replace existing block (with or without end marker)
        if f"<!-- /{marker} -->" in md:
            return pat.sub(block, md)
        return md.replace(f"<!-- {marker} -->", block)
    return md


def main():
    p = ROOT / "EXPERIMENTS.md"
    md = p.read_text()
    md = substitute(md, "DRYRUN_TABLE", dryrun_table())
    md = substitute(md, "ROOFLINE_TABLE", roofline_table())
    p.write_text(md)
    print("EXPERIMENTS.md tables rendered.")


if __name__ == "__main__":
    main()
