import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

"""Dry-run memory diagnostics: list the largest tensors in the compiled
per-device module (proxy for the buffer hogs)."""

import argparse
import re
import sys
from collections import Counter

import jax

sys.path.insert(0, "src")

from repro.configs import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_fn_and_specs
from repro.parallel.api import set_mesh

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]+)\]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    mesh = make_production_mesh()
    shape = SHAPES[args.shape]
    with set_mesh(mesh):
        fn, specs = cell_fn_and_specs(args.arch, shape, mesh)
        compiled = jax.jit(fn).lower(*specs).compile()
    try:
        ma = compiled.memory_analysis()
        print(f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB")
    except Exception as e:
        print("memory_analysis:", e)

    # largest result tensors in the HLO, with their op line (dedup by shape).
    # Fusion-internal ops don't allocate — skip fused computations.
    sizes = Counter()
    example = {}
    in_fused = False
    for line in compiled.as_text().splitlines():
        hdr = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
        if hdr:
            in_fused = "fused" in hdr.group(1) or "region" in hdr.group(1)
            continue
        if in_fused:
            continue
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        if re.search(r"=\s*\S+\s+parameter\(", line):
            continue
        sm = _SHAPE_RE.search(m.group(1))
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * _BYTES[dt]
        if b < 32 * 2**20:
            continue
        key = f"{dt}[{dims}]"
        sizes[key] += b
        if key not in example:
            opm = re.search(r"=\s*\S+\s+([\w\-]+)\(", line)
            example[key] = (opm.group(1) if opm else "?", line.strip()[:140])
    print("\n-- largest repeated shapes (sum over occurrences >32MiB each) --")
    for key, tot in sizes.most_common(args.top):
        op, ln = example[key]
        print(f"{tot/2**30:8.2f}GiB  {key:42s} {op:18s} {ln[:90]}")


if __name__ == "__main__":
    main()
