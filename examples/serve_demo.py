"""Continuous-batching serve demo: a ragged request mix (staggered
arrivals, mixed prompt/output lengths) slot-filled through the
block-paged KV cache, next to the synchronous bucket engine serving the
same work — the serve-side front door `benchmarks/serve_bench.py`
measures.

    PYTHONPATH=src python examples/serve_demo.py
    PYTHONPATH=src python examples/serve_demo.py --arch mamba2-370m
        (non-attention mixers cannot page; falls back to ServeEngine)
"""

import argparse
import math
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.serve import PagedServeEngine, Request, ServeEngine


def make_requests(rng, vocab, n, max_len):
    """Mostly short chat turns, a few long generations, ragged arrivals."""
    reqs = []
    tick = 0
    for _ in range(n):
        tick += int(rng.poisson(1))
        s = int(rng.integers(6, 48))
        gen = int(rng.integers(40, 80)) if rng.random() < 0.25 \
            else int(rng.integers(4, 16))
        gen = min(gen, max_len - s)
        prompt = rng.integers(0, vocab, (s,)).astype(np.int32)
        reqs.append(Request(prompt=prompt, n_steps=gen, arrival=tick))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = make_requests(rng, cfg.vocab_size, args.requests, args.max_len)
    total = sum(r.n_steps for r in reqs)

    try:
        eng = PagedServeEngine(cfg, params, max_len=args.max_len,
                               max_batch=args.max_batch)
    except NotImplementedError as e:
        # mamba2 / MLA / hybrid mixers keep state the paged cache cannot
        # hold — serve them through the synchronous bucket engine
        print(f"arch={cfg.name}: not pageable ({e}); using ServeEngine")
        s_max = max(r.prompt.shape[0] for r in reqs)
        n_max = max(r.n_steps for r in reqs)
        eng = ServeEngine(cfg, params,
                          max_len=32 * math.ceil((s_max + n_max) / 32))
        t0 = time.perf_counter()
        # same run(trace) protocol as the paged engine below — one padded
        # bucket replay (batch = the whole trace)
        results, stats = eng.run(reqs, temperature=args.temperature,
                                 batch=len(reqs))
        dt = time.perf_counter() - t0
        print(f"{len(reqs)} requests, {total} requested tokens, "
              f"wall={dt:.2f}s -> {total / dt:.1f} tok/s (bucketed, "
              f"{stats['decode_steps']} decode steps)")
        for i, r in enumerate(results[:3]):
            print(f"req{i}: {r.tokens[:10].tolist()}")
        return

    t0 = time.perf_counter()
    results, stats = eng.run(reqs, temperature=args.temperature)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name}: {len(reqs)} ragged requests "
          f"({total} requested tokens) on {args.max_batch} slots, "
          f"page={eng.page}, pool={eng.cache.capacity} blocks")
    print(f"wall={dt:.2f}s -> {total / dt:.1f} tok/s  "
          f"({stats['decode_steps']} decode steps over {stats['ticks']} "
          f"ticks, peak occupancy {stats['occupancy_max']:.0%})")
    for i, r in enumerate(results[:3]):
        wait = r.admitted - r.arrival
        print(f"req{i}: prompt={r.prompt_len:3d} +{len(r.tokens):3d} tokens "
              f"arrived@{r.arrival} admitted@{r.admitted} "
              f"(+{wait} tick wait) => {r.tokens[:8].tolist()}")


if __name__ == "__main__":
    main()
