"""Batched serving demo: prefill a request batch, decode with the KV-cache
engine, report per-phase timing — the serve-side path the decode_32k /
long_500k dry-run cells lower.

    PYTHONPATH=src python examples/serve_demo.py --arch jamba-v0.1-52b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.gen + 8)

    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.cross_attn:
        extras["media"] = jax.numpy.asarray(
            rng.randn(args.batch, cfg.cross_attn.n_media_tokens,
                      cfg.d_model) * 0.1, jax.numpy.bfloat16)
    if cfg.encoder:
        extras["frames"] = jax.numpy.asarray(
            rng.randn(args.batch, cfg.encoder.n_frames, cfg.d_model) * 0.1,
            jax.numpy.bfloat16)

    t0 = time.time()
    res = eng.generate(prompts, n_steps=args.gen,
                       temperature=args.temperature, extras=extras or None)
    dt = time.time() - t0
    print(f"arch={cfg.name}: {args.batch} requests x "
          f"({args.prompt_len} prompt + {args.gen} generated)")
    print(f"wall={dt:.2f}s  ->  {args.batch * args.gen / dt:.1f} tok/s "
          "(batched decode)")
    for i in range(min(2, args.batch)):
        print(f"req{i}: ...{prompts[i, -4:].tolist()} => "
              f"{res.tokens[i, :12].tolist()}")


if __name__ == "__main__":
    main()
