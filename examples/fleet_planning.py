"""Fleet capacity planning: the paper's "how do MCE optimizations impact
future systems" question answered at serving-fleet granularity.

Plans every built-in traffic scenario (``chat``, ``long_context``,
``bursty_batch``) on all five catalog devices, prints the paper-style
frontier table (devices needed, p99 vs SLO, tokens/s/device, relative
cost per Mtok), then asks the what-if the overlay machinery exists for:
what does a 2x-faster (and a 2x-slower) matrix-core engine buy the chat
fleet on mi300?

    PYTHONPATH=src python examples/fleet_planning.py
    PYTHONPATH=src python examples/fleet_planning.py --engine mfma
    PYTHONPATH=src python examples/fleet_planning.py --scenario chat \\
        --slo-p99-ms 100
"""

import argparse
import dataclasses

from repro.arch.overlay import IDENTITY, overlay_grid
from repro.fleet import frontier, get_scenario, list_scenarios

DEVICES = ("mi200", "mi300", "mi300x", "tpu_v5e", "tpu_v5p")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None,
                    help=f"one of {list_scenarios()} (default: all)")
    ap.add_argument("--slo-p99-ms", type=float, default=None)
    ap.add_argument("--engine", default="roofline")
    args = ap.parse_args()

    names = [args.scenario] if args.scenario else list_scenarios()
    scns = []
    for n in names:
        scn = get_scenario(n)
        if args.slo_p99_ms is not None:
            scn = dataclasses.replace(scn, slo=scn.slo.with_p99(
                args.slo_p99_ms))
        scns.append(scn)

    print("== Fleet frontier: every scenario on every catalog device ==\n")
    for scn in scns:
        print(f"  {scn.describe()}")
    print()
    rep = frontier(scns, DEVICES, engine=args.engine)
    print(rep.table())
    for scn in scns:
        best = rep.best(scn.name)
        if best:
            print(f"\n{scn.name}: serve on {best.devices_needed}x "
                  f"{best.device} — {best.tokens_per_s_device:.0f} "
                  f"tok/s/device at p99 {best.p99_token_ms:.0f}ms "
                  f"(SLO {best.slo_p99_ms:g}ms), "
                  f"{best.cost_per_mtok:.2f} $/Mtok relative")
        else:
            print(f"\n{scn.name}: no catalog device meets the SLO")

    print("\n== What-if: matrix-core engine scaling on the chat fleet "
          "(mi300) ==\n")
    ovs = [IDENTITY] + overlay_grid(mfma_scale=(0.5, 2.0))
    what_if = frontier("chat", ("mi300",), overlays=ovs,
                       engine=args.engine)
    print(what_if.table())
    base, faster, slower = what_if.rows
    print(f"\nA 2x-faster MCE (mfma x0.5) moves chat capacity "
          f"{base.max_qps:.2f} -> {faster.max_qps:.2f} qps/device; "
          f"a 2x-slower one drops it to {slower.max_qps:.2f}.  Decode "
          f"stays {base.bound}-bound, so the lever is the prefill side — "
          "exactly the asymmetry the planner exists to expose.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
