"""Quickstart: build a reduced assigned-architecture LM, train a few steps
on the synthetic stream, generate tokens — then cost the compiled step on
real accelerators with the unified ``repro.perf.predict`` API.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-7b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name} (family={cfg.family})")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n / 1e6:.2f}M")

    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=5,
                                                  total_steps=args.steps)))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg.vocab_size, batch=4, seq_len=64, seed=0,
                       correlation=1.0)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.3f}  lr={float(m['lr']):.2e}")

    eng = ServeEngine(cfg, params, max_len=128)
    prompt = data(123)["tokens"][:2, :16]
    out = eng.generate(prompt, n_steps=12)
    print("prompt :", prompt[0, -8:].tolist())
    print("decoded:", out.tokens[0].tolist())

    # The unified performance pipeline: cost THIS model's compiled train
    # step on real accelerators — one predict() call per question.
    from repro.arch import Overlay
    from repro.models.model import loss_fn
    from repro.perf import predict

    batch_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    txt = jax.jit(lambda p, b: loss_fn(cfg, p, b)).lower(
        params, batch_spec).compile().as_text()
    print("\nwhat-if: one train step, unified repro.perf predict()")
    for device, engine in (("mi300", "mfma"), ("mi300", "scoreboard"),
                           ("tpu_v5e", "roofline")):
        r = predict(txt, device=device, engine=engine)
        print(f"  {device:8s} {engine:10s} {r.total_time_s * 1e6:9.1f}us "
              f"({r.bound}-bound)")
    r2 = predict(txt, device="mi300", engine="mfma",
                 overlays=Overlay(mfma_scale=0.5, label="2x faster MCE"))
    print(f"  {'mi300':8s} {'mfma':10s} {r2.total_time_s * 1e6:9.1f}us "
          f"under scenario [{r2.scenario}]")


if __name__ == "__main__":
    main()
