"""Quickstart: build a reduced assigned-architecture LM, train a few steps
on the synthetic stream, generate tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-7b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name} (family={cfg.family})")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n / 1e6:.2f}M")

    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=5,
                                                  total_steps=args.steps)))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg.vocab_size, batch=4, seq_len=64, seed=0,
                       correlation=1.0)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.3f}  lr={float(m['lr']):.2e}")

    eng = ServeEngine(cfg, params, max_len=128)
    prompt = data(123)["tokens"][:2, :16]
    out = eng.generate(prompt, n_steps=12)
    print("prompt :", prompt[0, -8:].tolist())
    print("decoded:", out.tokens[0].tolist())


if __name__ == "__main__":
    main()
