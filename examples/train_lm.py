"""End-to-end training driver: data pipeline -> grad-accumulated AdamW ->
fault-tolerant controller -> async checkpoints -> final eval + generation.

Presets:
  smoke  (~2M params, CPU-friendly; default)     ~50 steps in minutes
  100m   (~100M params; the assignment's end-to-end target — a few hundred
         steps; run on real accelerators, or be patient on CPU)

    PYTHONPATH=src python examples/train_lm.py --preset smoke --steps 50
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.train.fault_tolerance import FailureInjector, TrainController
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

PRESETS = {
    # ~2.1M params: d=128, 4L, GQA 4/2 heads
    "smoke": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                  d_ff=512, vocab_size=2048, head_dim=32, batch=8,
                  seq_len=128, microbatches=2),
    # ~103M params: d=640, 10L — the "train ~100M for a few hundred steps"
    "100m": dict(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                 d_ff=2560, vocab_size=32768, head_dim=64, batch=32,
                 seq_len=512, microbatches=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], head_dim=p["head_dim"],
        microbatches=p["microbatches"])

    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params  batch={p['batch']}x{p['seq_len']}")

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticLM(cfg.vocab_size, batch=p["batch"],
                       seq_len=p["seq_len"], seed=0, correlation=0.9)

    def data_fn(i):
        return {k: jnp.asarray(v) for k, v in data(i).items()}

    injector = FailureInjector(at_steps=[args.inject_failure]) \
        if args.inject_failure >= 0 else None
    ctl = TrainController(step, args.ckpt_dir, ckpt_every=25,
                          injector=injector)
    state = (params, init_opt_state(params))
    start = 0
    if args.resume:
        state, start = ctl._restore(state)
        print(f"resumed from step {start}")

    t0 = time.time()
    state, log = ctl.run(state, data_fn, n_steps=args.steps,
                         start_step=start)
    dt = time.time() - t0
    losses = [e["loss"] for e in log if "loss" in e]
    toks = p["batch"] * p["seq_len"] * len(losses)
    print(f"\ntrained {len(losses)} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s); restarts={ctl.restarts}")
    print(f"loss: first5={np.mean(losses[:5]):.4f} "
          f"last5={np.mean(losses[-5:]):.4f}")
    if ctl.stragglers.events:
        print(f"stragglers flagged: {len(ctl.stragglers.events)}")


if __name__ == "__main__":
    main()
