"""Graceful-degradation demo: the paged serve engine under offered load
it cannot carry, and under injected allocator faults.

Three acts:

1. **Overload, naive**: a burst trace with deadlines on an unbounded
   FIFO queue — the queue grows, deadlines blow, most of the late work
   times out after burning decode steps on it.
2. **Overload, degraded gracefully**: same trace, same engine size, but
   with a ``max_queue`` bound and a deadline-aware admission policy —
   doomed work is shed *before* it costs anything and the surviving
   requests finish inside their deadlines.
3. **Fault injection**: a deterministic :class:`FaultPlan` seizes the
   whole block pool mid-run and forces a preemption; the engine
   preempts, requeues, recomputes — and the recomputed tokens are
   bit-identical to an uncontended run of the same trace.

    PYTHONPATH=src python examples/serve_resilience.py
    PYTHONPATH=src python examples/serve_resilience.py --seed 3
"""

import argparse
from collections import Counter

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import (OK, DeadlineAwareShed, Fault, FaultPlan,
                         PagedServeEngine, Request, get_trace)


def show(title, results, stats):
    by_status = Counter(r.status for r in results)
    line = ", ".join(f"{k}={v}" for k, v in sorted(by_status.items()))
    print(f"  {title}: {line}")
    print(f"    ticks={stats.ticks} decode_steps={stats.decode_steps} "
          f"tokens={stats.tokens} preemptions={stats.preemptions} "
          f"stalled_ticks={stats.stalled_ticks}")
    ok = [r for r in results if r.status == OK]
    if ok:
        waits = [r.admitted - r.arrival for r in ok]
        print(f"    served {len(ok)} requests, "
              f"worst admission wait {max(waits)} ticks")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=14)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    # --- acts 1 + 2: a burst trace with deadlines, on a 2-slot engine --
    trace = get_trace("overload")(args.requests, cfg.vocab_size,
                                  seed=args.seed, deadline_frac=0.9)
    n_dl = sum(1 for r in trace if r.deadline is not None)
    print(f"overload trace: {args.requests} requests in bursts, "
          f"{n_dl} carry deadlines")

    def engine(**kw):
        return PagedServeEngine(cfg, params, max_len=160, max_batch=2,
                                page=128, prefix_cache=False, **kw)

    print("\n[1] unbounded FIFO queue (no shedding):")
    show("naive", *engine().run(trace))

    print("\n[2] max_queue=4 + DeadlineAwareShed(slack=2):")
    results, stats = engine(max_queue=4,
                            admission=DeadlineAwareShed(slack=2)).run(trace)
    show("graceful", results, stats)
    shed = next((r for r in results if r.status == "SHED"), None)
    if shed is not None:
        print(f"    e.g. shed detail: {shed.detail!r}")

    # --- act 3: seize the pool, force a preemption, prove bit-parity ---
    rng = np.random.default_rng(args.seed)
    small = [Request(prompt=rng.integers(0, cfg.vocab_size, (8,))
                     .astype(np.int32), n_steps=12, arrival=a)
             for a in (0, 0, 1)]
    plan = FaultPlan(seed=args.seed, faults=[
        Fault(kind="exhaust", tick=2, n=8, duration=2),
        Fault(kind="preempt", tick=6, n=1),
        Fault(kind="stall", tick=9, duration=2),
    ])
    print("\n[3] fault injection (pool seizure + forced preemption + "
          "stall), invariants checked every tick:")
    quiet = engine()
    base, _ = quiet.run(small)
    chaos_eng = engine(check_invariants=True)
    chaos, cstats = chaos_eng.run(small, fault_plan=plan, max_ticks=2000)
    show("chaos", chaos, cstats)
    same = all(np.array_equal(a.tokens, b.tokens)
               for a, b in zip(base, chaos) if b.status == OK)
    print(f"    recomputed tokens bit-identical to fault-free run: {same}")
    print(f"    pool fully reclaimed: "
          f"{chaos_eng.cache.free_blocks == chaos_eng.cache.capacity}")


if __name__ == "__main__":
    main()
