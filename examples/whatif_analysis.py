"""MCE what-if analysis at framework scale (paper Section V-B, beyond the
microbenchmarks): sweep --mfma-scale over a REAL workload's compiled HLO
and report the matrix-unit-bound time per machine model.

Demonstrates the paper's headline use-case: "how would a 2x-faster (or
slower) matrix core change my workload?" — answered from the same compiled
artifact the dry-run validates, for any assigned architecture.

    PYTHONPATH=src python examples/whatif_analysis.py --arch qwen2-7b
"""

import argparse
import os

# this example lowers/compiles only — analyse the faithful bf16 program
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core.hlo_analysis import analyze
from repro.core.hlo_bridge import predict_dots
from repro.core.machine import get_machine
from repro.models import init_params
from repro.models.model import loss_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--scales", default="0.5,1,2,4")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    if cfg.cross_attn:
        batch["media"] = jax.ShapeDtypeStruct(
            (2, cfg.cross_attn.n_media_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (2, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)

    txt = jax.jit(lambda p, b: loss_fn(cfg, p, b)).lower(
        params, batch).compile().as_text()
    stats = analyze(txt)
    print(f"{args.arch} (reduced) train step: "
          f"{stats.flops / 1e9:.2f} GFLOP, {len(stats.dots)} dot sites")

    scales = [float(s) for s in args.scales.split(",")]
    print(f"\n{'machine':10s} " + " ".join(f"x{s:<8g}" for s in scales)
          + "  (matrix-unit-bound us)")
    for name in ("mi200", "mi300", "tpu_v5e"):
        row = []
        for s in scales:
            pred = predict_dots(get_machine(name, mfma_scale=s), stats.dots)
            row.append(f"{pred.mce_time_s * 1e6:<9.1f}")
        print(f"{name:10s} " + " ".join(row))
    print("\nNOTE (paper Section VI): on real code the end-to-end speedup "
          "is sub-linear in mfma-scale — compiler-scheduled independent "
          "work between MFMAs is fixed at compile time.")


if __name__ == "__main__":
    main()
