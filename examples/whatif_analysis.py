"""MCE what-if analysis at framework scale (paper Section V-B, beyond the
microbenchmarks): sweep --mfma-scale over a REAL workload's compiled HLO
through the unified ``repro.perf`` pipeline — every device in the
``repro.arch`` registry, a composed overlay-grid scenario sweep
(MFMA x clock), and all three cost engines (roofline / analytic MFMA /
event-driven scoreboard) answering from the same parsed KernelGraph.

Demonstrates the paper's headline use-case: "how would a 2x-faster (or
slower) matrix core change my workload?" — answered from the same compiled
artifact the dry-run validates, for any assigned architecture.

    PYTHONPATH=src python examples/whatif_analysis.py --arch qwen2-7b \
        [--devices mi300,mi300x] [--grid-device mi300x]
"""

import argparse
import os

# this example lowers/compiles only — analyse the faithful bf16 program
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

import jax
import jax.numpy as jnp

from repro.arch import Overlay, list_devices, overlay_grid
from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.models.model import loss_fn
from repro.perf import parse_cached, predict, sweep, format_reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--scales", default="0.5,1,2,4")
    ap.add_argument("--devices", default=None,
                    help="comma-separated registry names "
                         "(default: every registered device)")
    ap.add_argument("--grid-device", default="mi300x",
                    help="device for the composed overlay-grid sweep")
    args = ap.parse_args()

    # validate device selections BEFORE the (slow) compile
    scales = [float(s) for s in args.scales.split(",")]
    devices = ([d.strip() for d in args.devices.split(",") if d.strip()]
               if args.devices else list(list_devices()))
    unknown = [d for d in devices + [args.grid_device]
               if d not in list_devices()]
    if unknown:
        ap.error(f"unknown device(s) {unknown}; "
                 f"registered: {list(list_devices())}")

    cfg = get_config(args.arch).reduced()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    if cfg.cross_attn:
        batch["media"] = jax.ShapeDtypeStruct(
            (2, cfg.cross_attn.n_media_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (2, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)

    txt = jax.jit(lambda p, b: loss_fn(cfg, p, b)).lower(
        params, batch).compile().as_text()
    # parse ONCE; every sweep below reuses this KernelGraph via the cache
    graph = parse_cached(txt)
    print(f"{args.arch} (reduced) train step: "
          f"{graph.flops / 1e9:.2f} GFLOP, {len(graph.dots)} dot sites")

    print(f"\n{'machine':10s} " + " ".join(f"x{s:<8g}" for s in scales)
          + "  (matrix-unit-bound us)")
    for name in devices:
        reports = predict(graph, device=name, engine="mfma",
                          overlays=[Overlay(mfma_scale=s) for s in scales])
        print(f"{name:10s} " + " ".join(
            f"{r.total_time_s * 1e6:<9.1f}" for r in reports))

    # Composed scenarios: the overlay grid sweeps MFMA latency AND clock
    # together — one grid cell per (mfma_scale, clock_scale) pair.
    print(f"\noverlay grid on {args.grid_device} "
          "(scenario: matrix-unit-bound us)")
    for r in predict(graph, device=args.grid_device, engine="mfma",
                     overlays=overlay_grid(mfma_scale=(0.5, 1.0, 2.0),
                                           clock_scale=(1.0, 1.2))):
        print(f"  {r.scenario:24s} {r.total_time_s * 1e6:.1f}")

    # All three engines, one graph, one shared Report schema.
    print("\nengine comparison (same KernelGraph, baseline scenario)")
    print(format_reports(sweep({args.arch: graph},
                               devices=[args.grid_device],
                               engines=("roofline", "mfma", "scoreboard"))))
    print("\nNOTE (paper Section VI): on real code the end-to-end speedup "
          "is sub-linear in mfma-scale — compiler-scheduled independent "
          "work between MFMAs is fixed at compile time.")


if __name__ == "__main__":
    main()
