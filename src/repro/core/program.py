"""Instruction-stream IR for the MCE timing simulator.

A ``Program`` is a per-wavefront, in-order list of ``Instr``.  Registers are
symbolic names; the scoreboard tracks readiness per register, mirroring
gem5's register-dependency scoreboard.  The opcode set covers everything the
paper's microbenchmarks and our workload loops need:

  mfma        V_MFMA_* — occupies the SIMD's MCE, dsts ready after latency
  s_memtime   scalar counter probe — blocks the WF, dst = issue cycle
  s_nop       issue-slot filler (the paper's padding)
  s_waitcnt   blocks until outstanding vm/lgkm ops complete
  v_alu       generic VALU op
  v_load      vector memory load (L1D-class latency)
  ds_load     LDS load
  s_load      scalar memory load
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["Instr", "Program", "Wavefront", "Workload",
           "mfma", "s_memtime", "s_nop", "s_waitcnt", "v_alu", "v_load",
           "ds_load", "s_load"]


@dataclasses.dataclass(frozen=True)
class Instr:
    opcode: str
    dsts: Tuple[str, ...] = ()
    srcs: Tuple[str, ...] = ()
    mfma_name: Optional[str] = None   # for opcode == "mfma"
    tag: Optional[str] = None         # free-form label for result lookup


def mfma(name: str, d: str, a: str, b: str, c: str, *, tag: str = None) -> Instr:
    """D = C + A*B; reads a, b, c, writes d (paper Section III)."""
    return Instr("mfma", dsts=(d,), srcs=(a, b, c), mfma_name=name, tag=tag)


def s_memtime(dst: str, *, tag: str = None) -> Instr:
    return Instr("s_memtime", dsts=(dst,), tag=tag)


def s_nop(n: int = 0) -> Instr:
    del n  # gem5 models s_nop 0..n uniformly at issue granularity
    return Instr("s_nop")


def s_waitcnt() -> Instr:
    return Instr("s_waitcnt")


def v_alu(d: str, *srcs: str) -> Instr:
    return Instr("v_alu", dsts=(d,), srcs=tuple(srcs))


def v_load(d: str, *, tag: str = None) -> Instr:
    return Instr("v_load", dsts=(d,), tag=tag)


def ds_load(d: str) -> Instr:
    return Instr("ds_load", dsts=(d,))


def s_load(d: str) -> Instr:
    return Instr("s_load", dsts=(d,))


Program = List[Instr]


@dataclasses.dataclass
class Wavefront:
    wf_id: int
    program: Program
    cu: int = 0
    simd: int = 0          # which SIMD unit (hence which MCE) hosts this WF


@dataclasses.dataclass
class Workload:
    wavefronts: List[Wavefront]

    @classmethod
    def single(cls, program: Program, *, cu: int = 0, simd: int = 0) -> "Workload":
        return cls([Wavefront(0, program, cu=cu, simd=simd)])
