"""Loop-aware cost analysis over compiled HLO text.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts each op ONCE
even inside ``while`` loops — a scanned 60-layer transformer reports 1/60th
of its FLOPs.  Since the dry-run relies on scan-over-layers to keep compile
times sane, we re-derive the roofline inputs from ``compiled.as_text()``
with explicit trip-count multipliers:

* computations reachable from ENTRY via ``while(body=..., condition=...)``
  accumulate ``multiplier = parent_multiplier * trip_count`` (trip count from
  the ``known_trip_count`` backend config, falling back to the condition's
  ``compare(..., constant(N), direction=LT)``);
* per executed computation we account:
    - **flops**: ``dot`` ops as 2*B*M*N*K (operand shapes resolved through a
      module-wide symbol table; XLA:CPU keeps dots un-fused);
    - **bytes**: for every materialising op, result bytes + operand bytes
      (fusions therefore count their true kernel-boundary traffic);
    - **collectives**: result bytes + ring-model wire bytes per kind.

Cross-check: on while-free modules, totals match ``cost_analysis()`` closely
(tests assert this).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.hlo_bridge import (DotOp, _BYTES, _mnk, _parse_int_list,
                                   _DIMS_RE, _GROUPS_RE, _GROUPS_LIST_RE)

__all__ = ["HLOStats", "analyze"]

# note: parameter lists may contain nested parens (tuple params), so match
# loosely: name, open-paren, anything, '->', anything, trailing '{'
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_RESULT_SHAPES_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"^(?:\(([^)]*)\)|(\w+)\[[\d,]*\][^\s]*)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_WHILE_ATTR_RE = re.compile(r"condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"(%[\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
_DOT_ATTR_RE = _DIMS_RE

# ops that don't touch memory / are name-plumbing only
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "add-dependency", "partition-id", "replica-id",
             "iota"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


@dataclasses.dataclass
class HLOStats:
    flops: float                      # loop-aware total (per device)
    bytes_accessed: float             # loop-aware kernel-boundary bytes
    dots: List[Tuple[DotOp, float]]   # (dot, executed count)
    collectives: Dict[str, Dict[str, float]]  # kind -> count/result/wire bytes
    bytes_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)
    # fusion-boundary bytes of flash-attention block tensors ((..., S, 512)
    # score/prob intermediates).  The shipped Pallas flash kernel keeps
    # these in VMEM on TPU — the roofline reports memory_t with and without
    # them ("kernel-adjusted").
    flash_block_bytes: float = 0.0

    @property
    def collective_wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.collectives.values())


def _shape_bytes(dtype: str, dims: List[int]) -> float:
    if dtype not in _BYTES:
        return 0.0
    size = 1
    for d in dims:
        size *= d
    return float(size * _BYTES[dtype])


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_alias = None
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry_alias = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry_alias is not None:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _symbol_table(text: str) -> Dict[str, Tuple[str, List[int]]]:
    sym: Dict[str, Tuple[str, List[int]]] = {}
    for line in text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        sm = _RESULT_SHAPES_RE.search(rhs)
        if sm:
            sym[name] = (sm.group(1), _parse_int_list(sm.group(2)))
    return sym


def _opcode_of(rhs: str) -> Optional[str]:
    """Opcode from an op right-hand side like 'f32[8]{0} fusion(...)'."""
    m = re.match(r"^(?:\([^=]*?\)|[\w\[\]{},:#\*]+)\s+([\w\-]+)", rhs)
    return m.group(1) if m else None


def _operand_names(rhs: str) -> List[str]:
    lp = rhs.find("(")
    if lp < 0:
        return []
    depth, end = 0, -1
    for i in range(lp, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end < 0:
        return []
    inner = rhs[lp + 1:end]
    return re.findall(r"%[\w.\-]+", inner)


def _trip_count(line: str, cond_name: str,
                comps: Dict[str, List[str]]) -> float:
    m = _TRIP_RE.search(line)
    if m:
        return float(m.group(1))
    # fallback: condition compares induction var with constant, direction=LT
    consts = {}
    for cl in comps.get(cond_name, []):
        cm = _CONST_RE.search(cl)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))
    for cl in comps.get(cond_name, []):
        if "compare(" in cl and "direction=LT" in cl:
            for name in _operand_names(cl.split("=", 1)[1]):
                if name in consts:
                    return float(consts[name])
    return 1.0


def _wire_bytes(kind: str, nbytes: float, g: int) -> float:
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind in ("all-to-all", "ragged-all-to-all"):
        return nbytes * (g - 1) / g
    return nbytes  # collective-permute: one hop


def _convert_sources(text: str,
                     sym: Dict[str, Tuple[str, List[int]]]) -> Dict[str, str]:
    """name -> source dtype for every ``convert`` op (used to charge
    XLA:CPU's bf16->f32 dot-legalisation converts at bf16 width: those
    converts don't exist on TPU, whose MXU consumes bf16 natively)."""
    out = {}
    for line in text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        if not re.match(r"^\S+\s+convert\(", rhs):
            continue
        ops = re.findall(r"%[\w.\-]+", rhs[rhs.find("("):])
        if ops and ops[0] in sym:
            out[name] = sym[ops[0]][0]
    return out


def analyze(text: str, *, tpu_correct: bool = True) -> HLOStats:
    comps = _split_computations(text)
    sym = _symbol_table(text)
    cvt_src = _convert_sources(text, sym) if tpu_correct else {}

    def shape_bytes_of(name: str) -> float:
        if name not in sym:
            return 0.0
        dt, dims = sym[name]
        if tpu_correct and dt == "f32" and cvt_src.get(name) == "bf16":
            dt = "bf16"           # TPU keeps the native bf16 operand
        return _shape_bytes(dt, dims)

    # 1. multipliers: walk from entry through while ops
    mult: Dict[str, float] = defaultdict(float)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    entry_lines = comps["__entry__"]
    # identify the actual entry computation name to avoid double count
    entry_names = [n for n, ls in comps.items() if ls is entry_lines]
    real_entry = [n for n in entry_names if n != "__entry__"][0]
    mult[real_entry] = 1.0
    frontier = [real_entry]
    seen_while_in: Dict[str, bool] = {}
    while frontier:
        cname = frontier.pop()
        cmult = mult[cname]
        for line in comps.get(cname, []):
            if " while(" not in line:
                continue
            wm = _WHILE_ATTR_RE.search(line)
            if not wm:
                continue
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(line, cond, comps)
            for sub, m_extra in ((body, trips), (cond, trips + 1)):
                if sub in comps:
                    mult[sub] += cmult * m_extra
                    frontier.append(sub)

    # 2. executed computations = those with a multiplier (fusion-called
    #    computations are charged at their call site, not walked).
    flops = 0.0
    nbytes = 0.0
    flash_bytes = 0.0
    by_opcode: Dict[str, float] = defaultdict(float)
    dots: List[Tuple[DotOp, float]] = []
    colls: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})

    for cname, cmult in list(mult.items()):
        if cmult <= 0:
            continue
        for line in comps.get(cname, []):
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.groups()
            opcode = _opcode_of(rhs)
            if opcode is None or opcode in _FREE_OPS:
                continue
            if tpu_correct and opcode == "convert" \
                    and cvt_src.get(name) == "bf16":
                continue  # CPU dot-legalisation artifact: free on TPU
            # --- bytes: result + operands (kernel-boundary traffic) ---
            line_bytes = shape_bytes_of(name)
            for opn in _operand_names(rhs):
                line_bytes += shape_bytes_of(opn)
            nbytes += cmult * line_bytes
            by_opcode[opcode] += cmult * line_bytes
            if opcode in ("fusion", "dot"):
                rdt, rdims = sym.get(name, ("", []))
                if len(rdims) >= 3 and rdims[-1] == 512 and rdims[-2] >= 128:
                    flash_bytes += cmult * line_bytes

            # --- dot flops ---
            if opcode == "dot":
                attrs = rhs.split(")", 1)[1] if ")" in rhs else ""
                dims = {k: _parse_int_list(rx.search(attrs).group(1))
                        if rx.search(attrs) else []
                        for k, rx in _DOT_ATTR_RE.items()}
                opnames = _operand_names(rhs)
                if len(opnames) >= 2 and opnames[0] in sym and opnames[1] in sym:
                    (ldt, ldims), (_, rdims2) = sym[opnames[0]], sym[opnames[1]]
                    b, mm, nn, kk = _mnk(ldims, rdims2, dims["lhs_b"],
                                         dims["lhs_c"], dims["rhs_b"],
                                         dims["rhs_c"])
                    dot = DotOp(in_dtype=ldt, batch=b, m=mm, n=nn, k=kk)
                    dots.append((dot, cmult))
                    flops += cmult * dot.flops

            # --- collectives ---
            for kind in _COLLECTIVES:
                if opcode == kind or opcode == kind + "-start":
                    g = 1
                    gm = _GROUPS_RE.search(line)
                    if gm:
                        g = int(gm.group(2))
                    else:
                        gl = _GROUPS_LIST_RE.search(line)
                        if gl:
                            g = len([x for x in gl.group(1).split(",")
                                     if x.strip()])
                    # result shape: last tensor in the (possibly tuple) result
                    shapes = _RESULT_SHAPES_RE.findall(rhs.split(opcode)[0])
                    if shapes:
                        cdt, cdims = shapes[-1]
                        cb = _shape_bytes(cdt, _parse_int_list(cdims))
                        ops_n = _operand_names(rhs)
                        if tpu_correct and cdt == "f32" and ops_n and \
                                cvt_src.get(ops_n[0]) == "bf16":
                            cb /= 2  # TPU moves the bf16 tensor, not f32
                        st = colls[kind]
                        st["count"] += cmult
                        st["result_bytes"] += cmult * cb
                        st["wire_bytes"] += cmult * _wire_bytes(kind, cb, max(1, g))
                    break

    return HLOStats(flops=flops, bytes_accessed=nbytes, dots=dots,
                    collectives=dict(colls), bytes_by_opcode=dict(by_opcode),
                    flash_block_bytes=flash_bytes)
