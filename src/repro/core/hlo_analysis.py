"""Loop-aware cost analysis — compatibility shim over ``repro.perf.hlo_ir``.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts each op ONCE
even inside ``while`` loops — a scanned 60-layer transformer reports 1/60th
of its FLOPs.  The trip-count-aware parser that fixes this now lives in
:func:`repro.perf.hlo_ir.parse_module` (one parser for the whole
performance stack); this module keeps the legacy :class:`HLOStats` result
shape for existing call sites.  New code should use
``repro.perf.parse_cached`` / ``repro.perf.predict`` directly.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Tuple

from repro.core.hlo_bridge import DotOp
from repro.perf.hlo_ir import parse_module

__all__ = ["HLOStats", "analyze"]

_WARNED = False


def _warn_deprecated() -> None:
    global _WARNED
    if not _WARNED:
        _WARNED = True
        warnings.warn(
            "repro.core.hlo_analysis.analyze is deprecated; use "
            "repro.perf.parse_cached (loop-aware KernelGraph) or "
            "repro.perf.predict instead", DeprecationWarning,
            stacklevel=3)


@dataclasses.dataclass
class HLOStats:
    flops: float                      # loop-aware total (per device)
    bytes_accessed: float             # loop-aware kernel-boundary bytes
    dots: List[Tuple[DotOp, float]]   # (dot, executed count)
    collectives: Dict[str, Dict[str, float]]  # kind -> count/result/wire bytes
    bytes_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)
    # fusion-boundary bytes of flash-attention block tensors ((..., S, 512)
    # score/prob intermediates).  The shipped Pallas flash kernel keeps
    # these in VMEM on TPU — the roofline reports memory_t with and without
    # them ("kernel-adjusted").
    flash_block_bytes: float = 0.0

    @property
    def collective_wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.collectives.values())


def analyze(text: str, *, tpu_correct: bool = True) -> HLOStats:
    """Legacy view of :func:`repro.perf.hlo_ir.parse_module`.

    .. deprecated:: use :func:`repro.perf.parse_cached` instead.
    """
    _warn_deprecated()
    g = parse_module(text, tpu_correct=tpu_correct)
    return HLOStats(
        flops=g.flops,
        bytes_accessed=g.bytes_accessed,
        dots=[(op.as_dot(), cnt) for op, cnt in g.dot_pairs()],
        collectives=g.collectives,
        bytes_by_opcode=dict(g.bytes_by_opcode),
        flash_block_bytes=g.flash_block_bytes,
    )
