"""Compiled-HLO -> MFMA instruction accounting -> predicted kernel time.

This is the framework-scale payoff of the paper's contribution: given a
*compiled* JAX program (the dry-run artifact of any architecture in
``repro.configs``), decompose every ``dot`` into the MFMA instructions an
MI200/MI300 MCE would execute — or MXU passes on the TPU model — and predict
the matrix-unit-bound execution time, including under ``--mfma-scale``
what-ifs.  The analogue of running PyTorch/TF workloads over gem5's new MCE
support, at the speed of static analysis.

Two accounting layers:

* **Analytic** (`predict`): throughput model — each MCE retires one MFMA per
  ``mfma_cycles`` (no intra-WF pipelining, full cross-WF/SIMD parallelism,
  the paper's issue semantics in closed form).  Scales to billion-FLOP HLO.
* **Simulated** (`gemm_stream` + scoreboard): a representative tile loop run
  through the event-driven model to validate the analytic throughput
  assumption (tests assert they agree).

Parsing is regex-based over ``compiled.as_text()``; dots inside ``while``
bodies (scan layers) appear once, so we renormalise instruction counts by
``cost_analysis()['flops']`` — the compiler's ground truth for total work.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.arch import select as arch_select
from repro.core import isa
from repro.core.machine import MachineModel, as_machine
from repro.core.program import Program, Wavefront, Workload, mfma
from repro.core.scoreboard import simulate

__all__ = ["DotOp", "parse_dots", "parse_collectives", "best_instr",
           "mfma_count", "predict", "Prediction", "gemm_stream",
           "simulate_gemm_cu", "collective_bytes_total"]

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
          "s32": 4, "u32": 4, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
          "s64": 8, "u64": 8, "pred": 1, "s4": 1, "u4": 1}

# HLO dtype -> MFMA operand dtype mapping is a device-layer policy now:
_DTYPE_TO_IN = arch_select.HLO_DTYPE_TO_IN

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^\s]*\s+dot\(([^)]*)\)\s*,\s*(.*)")
_DIMS_RE = {
    "lhs_b": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
    "rhs_b": re.compile(r"rhs_batch_dims=\{([\d,]*)\}"),
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "rhs_c": re.compile(r"rhs_contracting_dims=\{([\d,]*)\}"),
}
_COLL_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# StableHLO (lowered, pre-partitioning) forms:
_SH_DOT_RE = re.compile(
    r"stablehlo\.dot_general\s+[^:]*?"
    r"(?:batching_dims\s*=\s*\[([\d, ]*)\]\s*x\s*\[[\d, ]*\]\s*,\s*)?"
    r"contracting_dims\s*=\s*\[([\d, ]*)\]\s*x\s*\[([\d, ]*)\][^:]*:\s*"
    r"\(tensor<([^>]+)>,\s*tensor<([^>]+)>\)")
_SH_CONV_RE = re.compile(r"stablehlo\.convolution")


@dataclasses.dataclass(frozen=True)
class DotOp:
    in_dtype: str          # HLO dtype of operands ("bf16", "f32", ...)
    batch: int
    m: int
    n: int
    k: int

    @property
    def macs(self) -> int:
        return self.batch * self.m * self.n * self.k

    @property
    def flops(self) -> int:
        return 2 * self.macs


def _parse_int_list(s: str) -> List[int]:
    s = s.strip()
    return [int(x) for x in s.split(",")] if s else []


def _tensor_sig(sig: str) -> Tuple[str, List[int]]:
    """'256x1024xbf16' -> ('bf16', [256, 1024]); '8xf32' -> ('f32', [8])."""
    parts = sig.split("x")
    dims, dtype = [], parts[-1]
    for p in parts[:-1]:
        dims.append(int(p))
    return dtype, dims


def _mnk(ldims, rdims, lhs_b, lhs_c, rhs_b, rhs_c) -> Tuple[int, int, int, int]:
    batch = 1
    for d in lhs_b:
        batch *= ldims[d]
    k_total = 1
    for d in lhs_c:
        k_total *= ldims[d]
    m_total = 1
    for i, d in enumerate(ldims):
        if i not in lhs_b and i not in lhs_c:
            m_total *= d
    n_total = 1
    for i, d in enumerate(rdims):
        if i not in rhs_b and i not in rhs_c:
            n_total *= d
    return batch, m_total, n_total, k_total


def _parse_stablehlo_dots(text: str) -> List[DotOp]:
    out: List[DotOp] = []
    for m in _SH_DOT_RE.finditer(text):
        bdims_s, lc_s, rc_s, lsig, rsig = m.groups()
        ldt, ldims = _tensor_sig(lsig)
        rdt, rdims = _tensor_sig(rsig)
        lhs_b = _parse_int_list((bdims_s or "").replace(" ", ""))
        # batching dims are leading & symmetric in stablehlo's pretty form
        rhs_b = list(lhs_b)
        lhs_c = _parse_int_list(lc_s.replace(" ", ""))
        rhs_c = _parse_int_list(rc_s.replace(" ", ""))
        b, mm, nn, kk = _mnk(ldims, rdims, lhs_b, lhs_c, rhs_b, rhs_c)
        out.append(DotOp(in_dtype=ldt, batch=b, m=mm, n=nn, k=kk))
    return out


def _parse_hlo_dots(text: str) -> List[DotOp]:
    # symbol table: %name -> (dtype, dims) for operand resolution
    sym: Dict[str, Tuple[str, List[int]]] = {}
    for m in _DEF_RE.finditer(text):
        sym[m.group(1)] = (m.group(2), _parse_int_list(m.group(3)))
    out: List[DotOp] = []
    for line in text.splitlines():
        if " dot(" not in line:
            continue
        m = _DOT_RE.search(line)
        if not m:
            continue
        odt, odims_s, operands, attrs = m.groups()
        odims = _parse_int_list(odims_s)
        dims = {k: _parse_int_list(rx.search(attrs).group(1))
                if rx.search(attrs) else [] for k, rx in _DIMS_RE.items()}
        # operands: either inline-shaped or bare %names
        inline = _SHAPE_RE.findall(operands)
        names = [t.strip().split(" ")[-1] for t in operands.split(",")]
        if len(inline) >= 2:
            (ldt, ls), (rdt, rs) = inline[0], inline[1]
            ldims, rdims = _parse_int_list(ls), _parse_int_list(rs)
        elif len(names) >= 2 and names[0] in sym and names[1] in sym:
            (ldt, ldims), (rdt, rdims) = sym[names[0]], sym[names[1]]
        else:
            # fall back: derive M,N from output; K unknown -> skip
            continue
        b, mm, nn, kk = _mnk(ldims, rdims, dims["lhs_b"], dims["lhs_c"],
                             dims["rhs_b"], dims["rhs_c"])
        out.append(DotOp(in_dtype=ldt, batch=b, m=mm, n=nn, k=kk))
    return out


def parse_dots(text: str) -> List[DotOp]:
    """Extract every dot op (each counted once, even inside while bodies).

    Accepts StableHLO (``lowered.as_text()`` — preserves bf16 operand types,
    global shapes) or post-SPMD HLO (``compiled.as_text()`` — per-device
    shapes; XLA:CPU upcasts bf16 dots to f32, a backend artifact).
    """
    if "stablehlo.dot_general" in text:
        return _parse_stablehlo_dots(text)
    return _parse_hlo_dots(text)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)          # replica_groups=[G,S]<=[N]
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)     # replica_groups={{0,1,2,3},...}
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind stats from post-SPMD HLO text.

    For each collective op we take the *result* shape printed on its line
    (per-device) plus the replica-group size, and derive ``wire_bytes`` —
    bytes a device moves over links, using ring-algorithm accounting:

      all-gather:         result * (g-1)/g      (receives all other shards)
      reduce-scatter:     result * (g-1)        (operand = result*g)
      all-reduce:         2 * result * (g-1)/g  (RS + AG phases)
      all-to-all:         result * (g-1)/g
      collective-permute: result                (one hop)

    Returns {kind: {count, result_bytes, wire_bytes}}.
    """
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, start = m.group(1), m.group(2)
        if f"{kind}-done" in line:
            continue  # async completion: payload counted at -start
        head = line.split(f" {kind}", 1)[0]
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        # async -start results are tuples (operand, result, ...): take last
        dt, dims_s = shapes[-1]
        if dt not in _BYTES:
            continue
        size = 1
        for d in _parse_int_list(dims_s):
            size *= d
        nbytes = float(size * _BYTES[dt])
        g = max(1, _group_size(line))
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = nbytes
        st = stats[kind]
        st["count"] += 1
        st["result_bytes"] += nbytes
        st["wire_bytes"] += wire
    return dict(stats)


def collective_bytes_total(hlo_text: str) -> float:
    """Total per-device wire bytes across all collectives."""
    return sum(v["wire_bytes"] for v in parse_collectives(hlo_text).values())


# ---------------------------------------------------------------------------
# Instruction selection + counting
# ---------------------------------------------------------------------------

def best_instr(machine: MachineModel, hlo_dtype: str) -> Optional[str]:
    """Highest-throughput supported MFMA instruction for an operand dtype.

    Thin wrapper: instruction selection is a device property owned by
    :mod:`repro.arch.select`; the machine contributes its backing spec and
    the active ``mfma_scale``.
    """
    machine = as_machine(machine)
    spec = machine.spec
    if spec is None and machine.gpu_table is not None:
        from repro.arch.registry import get_device
        spec = get_device(machine.gpu_table)   # hand-built legacy model
    if spec is None or not spec.has_cycle_table:
        return None
    return arch_select.best_mfma_for_hlo(spec, hlo_dtype,
                                         mfma_scale=machine.mfma_scale)


def mfma_count(dot: DotOp, instr_name: str) -> int:
    i = isa.lookup(instr_name)
    tiles = (dot.batch * math.ceil(dot.m / i.m) * math.ceil(dot.n / i.n)
             * math.ceil(dot.k / i.k))
    return math.ceil(tiles / i.blocks)


@dataclasses.dataclass
class Prediction:
    machine: str
    mfma_scale: float
    total_mfma: int
    mce_cycles: float          # throughput-bound cycles on the whole chip
    mce_time_s: float
    matrix_flops: float        # flops executed by matrix units
    instr_mix: Dict[str, int]
    repetition_factor: float   # cost_analysis flops / parsed-once flops


def predict_dots(machine: MachineModel,
                 dots_with_counts: Sequence[Tuple[DotOp, float]],
                 fallback_dtype: str = "bf16",
                 repetition_factor: float = 1.0) -> Prediction:
    """Matrix-unit-bound time for an explicit (dot, executed-count) list.

    ``machine`` may be a MachineModel, a ``repro.arch.DeviceSpec``, or a
    registered device name.
    """
    machine = as_machine(machine)
    instr_mix: Dict[str, int] = defaultdict(int)
    total_cycles = 0.0
    total_mfma = 0.0
    matrix_flops = 0.0

    for d, cnt in dots_with_counts:
        if machine.mxu_count:  # TPU analytic path: 128x128 systolic passes
            passes = (d.batch * math.ceil(d.m / machine.mxu_dim)
                      * math.ceil(d.n / machine.mxu_dim)
                      * math.ceil(d.k / machine.mxu_dim))
            # one pass streams mxu_dim rows through the array
            cycles = passes * machine.mxu_dim / machine.mxu_count
            cycles *= machine.mfma_scale  # what-if applies to MXU too
            total_cycles += cnt * cycles
            instr_mix[f"mxu_{machine.mxu_dim}x{machine.mxu_dim}"] += int(cnt * passes)
            total_mfma += cnt * passes
        else:
            name = best_instr(machine, d.in_dtype) or best_instr(machine, {
                "bf16": "bf16", "f16": "f16"}.get(fallback_dtype, "f32"))
            if name is None:
                continue
            n = mfma_count(d, name)
            lat = machine.mfma_cycles(name)
            # throughput bound: chip retires mce_per_cu*cu_count MFMAs / lat
            total_cycles += cnt * n * lat / (machine.mce_per_cu * machine.cu_count)
            instr_mix[name] += int(cnt * n)
            total_mfma += cnt * n
        matrix_flops += cnt * d.flops

    time_s = total_cycles / (machine.clock_mhz * 1e6)
    return Prediction(machine=machine.name, mfma_scale=machine.mfma_scale,
                      total_mfma=int(total_mfma), mce_cycles=total_cycles,
                      mce_time_s=time_s, matrix_flops=matrix_flops,
                      instr_mix=dict(instr_mix),
                      repetition_factor=repetition_factor)


def predict(machine: MachineModel, hlo_text: str,
            cost_flops: Optional[float] = None,
            fallback_dtype: str = "bf16") -> Prediction:
    """Matrix-unit-bound time prediction for a compiled module.

    ``cost_flops``: when given, the parsed (static) dot mix is renormalised
    so total matrix FLOPs match the caller's dynamic count (use
    :func:`repro.core.hlo_analysis.analyze` for loop-aware counts — XLA:CPU's
    own ``cost_analysis()`` counts while bodies once).
    """
    machine = as_machine(machine)
    dots = parse_dots(hlo_text)
    parsed_flops = float(sum(d.flops for d in dots))
    rep = 1.0
    if cost_flops and parsed_flops > 0:
        rep = max(1.0, cost_flops / parsed_flops)
    return predict_dots(machine, [(d, rep) for d in dots],
                        fallback_dtype=fallback_dtype, repetition_factor=rep)


# ---------------------------------------------------------------------------
# Representative-loop simulation (validates the analytic throughput model)
# ---------------------------------------------------------------------------

def gemm_stream(instr_name: str, n_tiles: int, wf_id: int) -> Program:
    """Independent MFMA tiles for one WF (software-pipelined: no dep chain)."""
    return [mfma(instr_name, d=f"acc{t}", a=f"a{t}", b=f"b{t}", c=f"acc{t}")
            for t in range(n_tiles)]


def simulate_gemm_cu(machine: MachineModel, instr_name: str, *,
                     tiles_per_wf: int = 8, n_wf: int = 8) -> Dict[str, float]:
    """Simulate one CU running a GEMM tile loop across n_wf wavefronts.

    WFs are assigned round-robin to SIMD units; with n_wf >= simd_per_cu the
    analytic throughput (mce_per_cu MFMAs per mfma_cycles) should be reached.
    """
    machine = as_machine(machine)
    wfs = [Wavefront(w, gemm_stream(instr_name, tiles_per_wf, w),
                     cu=0, simd=w % machine.simd_per_cu)
           for w in range(n_wf)]
    res = simulate(machine, Workload(wfs))
    total_mfma = tiles_per_wf * n_wf
    lat = machine.mfma_cycles(instr_name)
    analytic = total_mfma * lat / min(n_wf, machine.mce_per_cu)
    return {"makespan": res.makespan, "analytic_cycles": analytic,
            "mce_utilization": res.mce_utilization(machine),
            "total_mfma": total_mfma}
