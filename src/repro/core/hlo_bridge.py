"""Compiled-HLO -> MFMA accounting — compatibility shim over ``repro.perf``.

This module used to own the HLO text parsing and the closed-form MCE
throughput model; both now live in the unified performance pipeline
(:mod:`repro.perf.hlo_ir` for parsing, :mod:`repro.perf.engines` for
costing) where the roofline, scoreboard and what-if sweeps share them.
The legacy API is preserved exactly — same functions, same result shapes,
same numbers (``tests/test_perf_engines.py`` asserts engine/legacy parity)
— so existing call sites and notebooks keep working.  New code should call
``repro.perf.predict`` instead.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.machine import MachineModel, as_machine
from repro.perf import hlo_ir
from repro.perf.engines import (best_instr, cost_dot_pairs,  # noqa: F401
                                gemm_stream, mfma_count, simulate_gemm_cu)
from repro.perf.hlo_ir import DotOp  # noqa: F401  (legacy re-export)

__all__ = ["DotOp", "parse_dots", "parse_collectives", "best_instr",
           "mfma_count", "predict", "predict_dots", "Prediction",
           "gemm_stream", "simulate_gemm_cu", "collective_bytes_total"]

# Legacy aliases (hlo_analysis and external notebooks imported these):
_BYTES = hlo_ir.BYTES_PER_ELEM
_mnk = hlo_ir._mnk
_parse_int_list = hlo_ir._parse_int_list
_DIMS_RE = hlo_ir.DIMS_RE
_GROUPS_RE = hlo_ir.GROUPS_RE
_GROUPS_LIST_RE = hlo_ir.GROUPS_LIST_RE
_SHAPE_RE = hlo_ir.SHAPE_RE

_WARNED = False


def _warn_deprecated() -> None:
    """One-shot: this surface is kept for parity tests and old notebooks
    only, and goes away once the fleet layer's consumers are migrated."""
    global _WARNED
    if not _WARNED:
        _WARNED = True
        warnings.warn(
            "repro.core.hlo_bridge.predict is deprecated; call "
            "repro.perf.predict(workload, device=..., engine='mfma') — "
            "same numbers, one model home", DeprecationWarning,
            stacklevel=3)


def parse_dots(text: str) -> List[DotOp]:
    """Extract every dot op (each counted once, even inside while bodies).

    Accepts StableHLO (``lowered.as_text()``) or post-SPMD HLO
    (``compiled.as_text()``).  Thin wrapper over
    :func:`repro.perf.hlo_ir.parse_static_dots`.
    """
    return [op.as_dot() for op in hlo_ir.parse_static_dots(text)]


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind stats from post-SPMD HLO text (each op counted
    once).  Returns {kind: {count, result_bytes, wire_bytes}} — see
    :func:`repro.perf.hlo_ir.parse_collectives_static` for the ring-model
    wire-byte accounting."""
    return hlo_ir.parse_collectives_static(hlo_text)


def collective_bytes_total(hlo_text: str) -> float:
    """Total per-device wire bytes across all collectives."""
    return hlo_ir.collective_wire_bytes(hlo_text)


@dataclasses.dataclass
class Prediction:
    machine: str
    mfma_scale: float
    total_mfma: int
    mce_cycles: float          # throughput-bound cycles on the whole chip
    mce_time_s: float
    matrix_flops: float        # flops executed by matrix units
    instr_mix: Dict[str, int]
    repetition_factor: float   # cost_analysis flops / parsed-once flops


def predict_dots(machine: MachineModel,
                 dots_with_counts: Sequence[Tuple[DotOp, float]],
                 fallback_dtype: str = "bf16",
                 repetition_factor: float = 1.0) -> Prediction:
    """Matrix-unit-bound time for an explicit (dot, executed-count) list.

    ``machine`` may be a MachineModel, a ``repro.arch.DeviceSpec``, or a
    registered device name.  Delegates to the ONE model home,
    :func:`repro.perf.engines.cost_dot_pairs` (also behind
    ``MfmaAnalyticEngine``), so legacy and pipeline results agree exactly.
    """
    machine = as_machine(machine)
    costs = cost_dot_pairs(machine, dots_with_counts,
                           fallback_dtype=fallback_dtype)
    return Prediction(machine=machine.name, mfma_scale=machine.mfma_scale,
                      total_mfma=int(costs.total_mfma),
                      mce_cycles=costs.total_cycles,
                      mce_time_s=costs.time_s,
                      matrix_flops=costs.matrix_flops,
                      instr_mix=dict(costs.instr_mix),
                      repetition_factor=repetition_factor)


def predict(machine: MachineModel, hlo_text: str,
            cost_flops: Optional[float] = None,
            fallback_dtype: str = "bf16") -> Prediction:
    """Matrix-unit-bound time prediction for a compiled module.

    ``cost_flops``: when given, the parsed (static) dot mix is renormalised
    so total matrix FLOPs match the caller's dynamic count (use
    :func:`repro.core.hlo_analysis.analyze` for loop-aware counts — XLA:CPU's
    own ``cost_analysis()`` counts while bodies once).

    .. deprecated:: use :func:`repro.perf.predict` instead.
    """
    _warn_deprecated()
    machine = as_machine(machine)
    dots = parse_dots(hlo_text)
    parsed_flops = float(sum(d.flops for d in dots))
    rep = 1.0
    if cost_flops and parsed_flops > 0:
        rep = max(1.0, cost_flops / parsed_flops)
    return predict_dots(machine, [(d, rep) for d in dots],
                        fallback_dtype=fallback_dtype, repetition_factor=rep)
