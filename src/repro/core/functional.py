"""Functional semantics of MFMA instructions: D = C + A @ B, blocked.

This is the jnp oracle corresponding to the functional implementation the
paper added to ``src/arch/amdgpu/vega/insts/instructions.hh``; the Pallas
``mfma_gemm`` kernel and its ref share this contract.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import isa

_DTYPES = {
    "fp64": jnp.float64,
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "i8": jnp.int8,
    "i32": jnp.int32,
    "fp8": jnp.float8_e4m3fn,
}


def operand_dtypes(instr_name: str):
    i = isa.lookup(instr_name)
    return _DTYPES[i.in_dtype], _DTYPES[i.out_dtype]


def mfma_apply(instr_name: str, a, b, c):
    """Execute one MFMA instruction functionally.

    a: (blocks, M, K)   b: (blocks, K, N)   c: (blocks, M, N) -> d like c.
    Accumulation happens in the output dtype (fp32/i32/fp64), matching the
    MCE's wide accumulator.
    """
    i = isa.lookup(instr_name)
    in_dt, out_dt = operand_dtypes(instr_name)
    a = jnp.asarray(a, in_dt)
    b = jnp.asarray(b, in_dt)
    c = jnp.asarray(c, out_dt)
    assert a.shape == i.a_shape, (a.shape, i.a_shape)
    assert b.shape == i.b_shape, (b.shape, i.b_shape)
    assert c.shape == i.d_shape, (c.shape, i.d_shape)
    if i.out_dtype == "i32":
        prod = jnp.einsum("bmk,bkn->bmn", a.astype(jnp.int32), b.astype(jnp.int32))
    else:
        prod = jnp.einsum("bmk,bkn->bmn", a.astype(out_dt), b.astype(out_dt),
                          preferred_element_type=out_dt)
    return c + prod


def random_operands(instr_name: str, seed: int = 0):
    i = isa.lookup(instr_name)
    rng = np.random.RandomState(seed)
    in_dt, out_dt = operand_dtypes(instr_name)
    if i.in_dtype == "i8":
        a = rng.randint(-4, 4, size=i.a_shape).astype(np.int8)
        b = rng.randint(-4, 4, size=i.b_shape).astype(np.int8)
        c = rng.randint(-8, 8, size=i.d_shape).astype(np.int32)
    else:
        a = rng.randn(*i.a_shape).astype(np.float32)
        b = rng.randn(*i.b_shape).astype(np.float32)
        c = rng.randn(*i.d_shape).astype(np.float32)
    return jnp.asarray(a, in_dt), jnp.asarray(b, in_dt), jnp.asarray(c, out_dt)
