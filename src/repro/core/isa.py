"""MFMA instruction registry (functional metadata, gem5-parity quirks).

This is the JAX-side analogue of the paper's additions to
``src/arch/amdgpu/vega/insts/instructions.hh``: the static shape/dtype
metadata of every V_MFMA_* instruction the framework knows about, plus the
``s_set_gpr_idx`` addressing-mode restrictions of Section VI.

**Timing lives in** :mod:`repro.arch`: per-device cycle tables (the paper's
``mfma_cycles`` lookup in ``src/gpu-compute/compute_unit.cc``) are rows of
each :class:`repro.arch.DeviceSpec` in the device registry
(``repro.arch.registry``), where cross-checked entries carry
``validated=True`` provenance (Tables II-V "Expected" column) and
ISA-manual-pattern extensions carry ``validated=False``.  The
module-level ``MI200_CYCLES`` / ``MI300_CYCLES`` dicts and the
``mfma_cycles`` / ``supported_instructions`` functions here are
backward-compatible views over that registry.

Every matrix-core instruction computes ``D = C + A @ B`` where, per block,
``A`` is MxK, ``B`` is KxN and ``C``/``D`` are MxN; ``blocks`` independent
such products execute per instruction.  Instruction names follow AMD's
``V_MFMA_[out]_[M]x[N]x[K][_Bb]_[in]`` convention, normalised here to e.g.
``fp32_16x16x16fp16`` / ``f32_32x32x4_2b_bf16``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = [
    "MFMAInstr",
    "UnsupportedInstructionError",
    "MFMA_REGISTRY",
    "MI200_CYCLES",
    "MI300_CYCLES",
    "mfma_cycles",
    "lookup",
    "supported_instructions",
    "flops_per_instr",
]


class UnsupportedInstructionError(KeyError):
    """Raised for instructions a machine model does not implement.

    Mirrors the paper's Section VI: MFMA instructions that use the
    ``s_set_gpr_idx`` addressing mode (e.g. ``fp32_32x32x8fp16`` and
    ``fp32_32x32x1fp32``) are unsupported in gem5's timing model, and some
    instructions (e.g. ``i32_16x16x16i8``) were removed on MI300.  Also
    raised for unknown device names (consistently across ``mfma_cycles``
    *and* ``supported_instructions``).
    """


@dataclasses.dataclass(frozen=True)
class MFMAInstr:
    """Static metadata for one V_MFMA_* instruction."""

    name: str           # canonical short name, e.g. "fp32_16x16x16fp16"
    out_dtype: str      # accumulator / destination dtype
    in_dtype: str       # A/B operand dtype
    m: int
    n: int
    k: int
    blocks: int = 1
    # Paper Section VI: these require the s_set_gpr_idx addressing mode and
    # are therefore not implemented in the gem5-parity timing model.
    gpr_idx_mode: bool = False

    @property
    def macs(self) -> int:
        """Multiply-accumulates performed by one instruction (per WF)."""
        return self.m * self.n * self.k * self.blocks

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def d_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.m, self.n)

    @property
    def a_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.m, self.k)

    @property
    def b_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.k, self.n)


def _I(name, out, inp, m, n, k, blocks=1, gpr_idx=False) -> MFMAInstr:
    return MFMAInstr(name=name, out_dtype=out, in_dtype=inp, m=m, n=n, k=k,
                     blocks=blocks, gpr_idx_mode=gpr_idx)


#: All instructions the framework knows about.
MFMA_REGISTRY: Dict[str, MFMAInstr] = {
    i.name: i
    for i in [
        # --- paper-validated set (Tables II-V) -------------------------
        _I("fp64_16x16x4fp64", "fp64", "fp64", 16, 16, 4),
        _I("fp32_4x4x1fp32", "fp32", "fp32", 4, 4, 1, blocks=16),
        _I("fp32_16x16x4fp32", "fp32", "fp32", 16, 16, 4),
        _I("fp32_16x16x16fp16", "fp32", "fp16", 16, 16, 16),
        _I("i32_16x16x16i8", "i32", "i8", 16, 16, 16),
        _I("fp64_4x4x4fp64", "fp64", "fp64", 4, 4, 4, blocks=4),
        _I("fp32_4x4x4fp16", "fp32", "fp16", 4, 4, 4, blocks=16),
        # --- ISA-manual-pattern extensions (unvalidated timing class) --
        _I("fp32_32x32x2fp32", "fp32", "fp32", 32, 32, 2),
        _I("fp32_32x32x8fp16", "fp32", "fp16", 32, 32, 8, gpr_idx=True),
        _I("fp32_32x32x1fp32", "fp32", "fp32", 32, 32, 1, blocks=2, gpr_idx=True),
        _I("fp32_32x32x4bf16", "fp32", "bf16", 32, 32, 4),
        _I("f32_32x32x4_2b_bf16", "fp32", "bf16", 32, 32, 4, blocks=2),
        _I("fp32_16x16x16bf16", "fp32", "bf16", 16, 16, 16),
        _I("fp32_16x16x8bf16", "fp32", "bf16", 16, 16, 8),
        _I("i32_16x16x32i8", "i32", "i8", 16, 16, 32),
        _I("i32_32x32x16i8", "i32", "i8", 32, 32, 16),
        _I("fp32_16x16x32fp8", "fp32", "fp8", 16, 16, 32),
    ]
}


def lookup(name: str) -> MFMAInstr:
    try:
        return MFMA_REGISTRY[name]
    except KeyError as e:
        raise UnsupportedInstructionError(f"unknown MFMA instruction {name!r}") from e


def _spec(gpu: str):
    """Resolve a device name against the registry with this module's
    documented error contract (UnsupportedInstructionError throughout)."""
    # Lazy import: repro.arch lazily imports this module for instruction
    # metadata; resolving at call time keeps the layering acyclic.
    from repro.arch import registry
    try:
        return registry.get_device(gpu)
    except registry.UnknownDeviceError as e:
        raise UnsupportedInstructionError(
            f"unknown GPU model {gpu!r}") from e


def mfma_cycles(gpu: str, name: str, *, mfma_scale: float = 1.0,
                allow_gpr_idx: bool = False) -> int:
    """Latency in cycles of ``name`` on ``gpu`` — the mfma_cycles table.

    ``mfma_scale`` is the paper's ``--mfma-scale`` what-if parameter: the
    default latency is multiplied and rounded, exactly as in gem5.

    Thin view over ``repro.arch``: equivalent to
    ``get_device(gpu).mfma_cycles(name, ...)``.
    """
    return _spec(gpu).mfma_cycles(name, mfma_scale=mfma_scale,
                                  allow_gpr_idx=allow_gpr_idx)


def supported_instructions(gpu: str, *, validated_only: bool = False):
    """Instruction names ``gpu`` implements (timing-model-supported only).

    Raises :class:`UnsupportedInstructionError` for unknown device names —
    the same contract as :func:`mfma_cycles`.
    """
    return _spec(gpu).supported_instructions(validated_only=validated_only)


def flops_per_instr(name: str) -> int:
    return lookup(name).flops


def _legacy_table(gpu: str) -> Dict[str, Tuple[int, bool]]:
    return {name: (e.cycles, e.validated)
            for name, e in _spec(gpu).cycle_table.items()}


def __getattr__(name: str):
    # Backward-compatible views of the timing data that moved to
    # repro.arch.registry, materialised lazily (PEP 562) so importing this
    # module never pulls the arch package in at import time.
    if name == "MI200_CYCLES":
        return _legacy_table("mi200")
    if name == "MI300_CYCLES":
        return _legacy_table("mi300")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
