"""MFMA instruction registry and per-GPU cycle tables.

This is the JAX-side analogue of the paper's additions to
``src/arch/amdgpu/vega/insts/instructions.hh`` (functional metadata) and the
``mfma_cycles`` lookup table in ``src/gpu-compute/compute_unit.cc`` (timing).

Every matrix-core instruction computes ``D = C + A @ B`` where, per block,
``A`` is MxK, ``B`` is KxN and ``C``/``D`` are MxN; ``blocks`` independent
such products execute per instruction.  Instruction names follow AMD's
``V_MFMA_[out]_[M]x[N]x[K][_Bb]_[in]`` convention, normalised here to e.g.
``fp32_16x16x16fp16`` / ``f32_32x32x4_2b_bf16``.

Cycle counts marked ``validated=True`` are the "Expected" column of the
paper's Tables II-V (cross-checked against real MI210/MI300 hardware in the
paper).  Entries marked ``validated=False`` follow the ISA-manual pattern
(Table 27 of the MI300 ISA manual) and are included so the HLO bridge can
account real workloads; they carry the same latency class as their validated
shape-mates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

__all__ = [
    "MFMAInstr",
    "UnsupportedInstructionError",
    "MFMA_REGISTRY",
    "MI200_CYCLES",
    "MI300_CYCLES",
    "mfma_cycles",
    "lookup",
    "supported_instructions",
    "flops_per_instr",
]


class UnsupportedInstructionError(KeyError):
    """Raised for instructions a machine model does not implement.

    Mirrors the paper's Section VI: MFMA instructions that use the
    ``s_set_gpr_idx`` addressing mode (e.g. ``fp32_32x32x8fp16`` and
    ``fp32_32x32x1fp32``) are unsupported in gem5's timing model, and some
    instructions (e.g. ``i32_16x16x16i8``) were removed on MI300.
    """


@dataclasses.dataclass(frozen=True)
class MFMAInstr:
    """Static metadata for one V_MFMA_* instruction."""

    name: str           # canonical short name, e.g. "fp32_16x16x16fp16"
    out_dtype: str      # accumulator / destination dtype
    in_dtype: str       # A/B operand dtype
    m: int
    n: int
    k: int
    blocks: int = 1
    # Paper Section VI: these require the s_set_gpr_idx addressing mode and
    # are therefore not implemented in the gem5-parity timing model.
    gpr_idx_mode: bool = False

    @property
    def macs(self) -> int:
        """Multiply-accumulates performed by one instruction (per WF)."""
        return self.m * self.n * self.k * self.blocks

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def d_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.m, self.n)

    @property
    def a_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.m, self.k)

    @property
    def b_shape(self) -> Tuple[int, int, int]:
        return (self.blocks, self.k, self.n)


def _I(name, out, inp, m, n, k, blocks=1, gpr_idx=False) -> MFMAInstr:
    return MFMAInstr(name=name, out_dtype=out, in_dtype=inp, m=m, n=n, k=k,
                     blocks=blocks, gpr_idx_mode=gpr_idx)


#: All instructions the framework knows about.
MFMA_REGISTRY: Dict[str, MFMAInstr] = {
    i.name: i
    for i in [
        # --- paper-validated set (Tables II-V) -------------------------
        _I("fp64_16x16x4fp64", "fp64", "fp64", 16, 16, 4),
        _I("fp32_4x4x1fp32", "fp32", "fp32", 4, 4, 1, blocks=16),
        _I("fp32_16x16x4fp32", "fp32", "fp32", 16, 16, 4),
        _I("fp32_16x16x16fp16", "fp32", "fp16", 16, 16, 16),
        _I("i32_16x16x16i8", "i32", "i8", 16, 16, 16),
        _I("fp64_4x4x4fp64", "fp64", "fp64", 4, 4, 4, blocks=4),
        _I("fp32_4x4x4fp16", "fp32", "fp16", 4, 4, 4, blocks=16),
        # --- ISA-manual-pattern extensions (unvalidated timing class) --
        _I("fp32_32x32x2fp32", "fp32", "fp32", 32, 32, 2),
        _I("fp32_32x32x8fp16", "fp32", "fp16", 32, 32, 8, gpr_idx=True),
        _I("fp32_32x32x1fp32", "fp32", "fp32", 32, 32, 1, blocks=2, gpr_idx=True),
        _I("fp32_32x32x4bf16", "fp32", "bf16", 32, 32, 4),
        _I("f32_32x32x4_2b_bf16", "fp32", "bf16", 32, 32, 4, blocks=2),
        _I("fp32_16x16x16bf16", "fp32", "bf16", 16, 16, 16),
        _I("fp32_16x16x8bf16", "fp32", "bf16", 16, 16, 8),
        _I("i32_16x16x32i8", "i32", "i8", 16, 16, 32),
        _I("i32_32x32x16i8", "i32", "i8", 32, 32, 16),
        _I("fp32_16x16x32fp8", "fp32", "fp8", 16, 16, 32),
    ]
}


# ---------------------------------------------------------------------------
# Cycle tables.  Keys absent from a table mean "not supported on that GPU".
# Paper-validated entries (Tables II-V "Expected" column) are listed first.
# ---------------------------------------------------------------------------

#: (cycles, validated)
MI200_CYCLES: Dict[str, Tuple[int, bool]] = {
    "fp64_16x16x4fp64": (32, True),
    "fp32_4x4x1fp32": (8, True),
    "fp32_16x16x4fp32": (32, True),
    "fp32_16x16x16fp16": (32, True),
    "i32_16x16x16i8": (32, True),
    "fp64_4x4x4fp64": (16, True),
    "fp32_4x4x4fp16": (8, True),
    # ISA-manual-pattern latency classes (same class as shape-mates):
    "fp32_32x32x2fp32": (64, False),
    "fp32_32x32x4bf16": (64, False),
    "fp32_16x16x8bf16": (32, False),
}

MI300_CYCLES: Dict[str, Tuple[int, bool]] = {
    "fp64_16x16x4fp64": (32, True),
    "fp32_4x4x1fp32": (8, True),
    "fp32_16x16x4fp32": (32, True),
    # MI300 improved this latency vs MI200 (32 -> 16), Table IV:
    "fp32_16x16x16fp16": (16, True),
    "fp64_4x4x4fp64": (16, True),
    "fp32_4x4x4fp16": (8, True),
    # i32_16x16x16i8: REMOVED on MI300 (paper Section III-A).
    # New on MI300: 2-block bf16 variant, same cycles as MI200 1-block:
    "f32_32x32x4_2b_bf16": (64, False),
    "fp32_16x16x16bf16": (16, False),
    "i32_16x16x32i8": (16, False),
    "i32_32x32x16i8": (32, False),
    "fp32_16x16x32fp8": (16, False),
}

_TABLES: Mapping[str, Mapping[str, Tuple[int, bool]]] = {
    "mi200": MI200_CYCLES,
    "mi300": MI300_CYCLES,
}


def lookup(name: str) -> MFMAInstr:
    try:
        return MFMA_REGISTRY[name]
    except KeyError as e:
        raise UnsupportedInstructionError(f"unknown MFMA instruction {name!r}") from e


def mfma_cycles(gpu: str, name: str, *, mfma_scale: float = 1.0,
                allow_gpr_idx: bool = False) -> int:
    """Latency in cycles of ``name`` on ``gpu`` — the mfma_cycles table.

    ``mfma_scale`` is the paper's ``--mfma-scale`` what-if parameter: the
    default latency is multiplied and rounded, exactly as in gem5.
    """
    instr = lookup(name)
    if instr.gpr_idx_mode and not allow_gpr_idx:
        raise UnsupportedInstructionError(
            f"{name} uses the s_set_gpr_idx addressing mode, which the "
            "gem5-parity timing model does not support (paper Section VI)")
    table = _TABLES.get(gpu.lower())
    if table is None:
        raise UnsupportedInstructionError(f"unknown GPU model {gpu!r}")
    if name not in table:
        raise UnsupportedInstructionError(
            f"{name} is not supported on {gpu} "
            "(e.g. i32_16x16x16i8 was removed on MI300)")
    base, _ = table[name]
    return max(1, int(round(base * mfma_scale)))


def supported_instructions(gpu: str, *, validated_only: bool = False):
    table = _TABLES[gpu.lower()]
    out = []
    for name, (_, validated) in table.items():
        if validated_only and not validated:
            continue
        if lookup(name).gpr_idx_mode:
            continue
        out.append(name)
    return out


def flops_per_instr(name: str) -> int:
    return lookup(name).flops
