"""The paper's contribution: MCE/MFMA functional + timing models.

Device capability data (cycle tables, topology, memory, interconnect,
clocks) lives in the declarative :mod:`repro.arch` registry; this package
holds the execution models that consume it.

Public surface:
  isa            — MFMA instruction registry (+ legacy cycle-table views)
  machine        — MachineModel facade over repro.arch.DeviceSpec
  program        — instruction-stream IR
  scoreboard     — event-driven CU/SIMD/MCE simulator (NRDY_MATRIX_CORE)
  microbench     — Listing-1 streams + Eq. 1 extraction (Tables II-V)
  whatif         — --mfma-scale / overlay-grid analysis (Table VI)
  functional     — D = C + A@B oracle semantics
  hlo_bridge     — compiled-HLO -> MFMA streams -> predicted kernel time
"""

from repro.core import isa, machine, program, scoreboard, microbench  # noqa: F401
from repro.core.machine import (MI200, MI300, TPU_V5E, as_machine,  # noqa: F401
                                get_machine, list_machines)
