"""The paper's contribution: MCE/MFMA functional + timing models.

Public surface:
  isa            — MFMA registry + MI200/MI300 cycle tables (+ what-if scale)
  machine        — MachineModel (paper Table I params; TPU v5e analytic model)
  program        — instruction-stream IR
  scoreboard     — event-driven CU/SIMD/MCE simulator (NRDY_MATRIX_CORE)
  microbench     — Listing-1 streams + Eq. 1 extraction (Tables II-V)
  whatif         — --mfma-scale analysis (Table VI)
  functional     — D = C + A@B oracle semantics
  hlo_bridge     — compiled-HLO -> MFMA streams -> predicted kernel time
"""

from repro.core import isa, machine, program, scoreboard, microbench  # noqa: F401
from repro.core.machine import MI200, MI300, TPU_V5E, get_machine  # noqa: F401
