"""What-if analysis via ``mfma_scale`` (paper Section V-B, Table VI).

Scaling the MFMA cycle table lets users explore faster/slower future MCE
designs.  As the paper notes (Section VI), on real code the speedup is NOT
linear because the compiler fixed the amount of independent work between
MFMAs at compile time; the microbenchmark path below shows the linear
(instruction-isolated) effect while :mod:`repro.core.hlo_bridge` exposes the
workload-level (Amdahl-limited) effect.
"""

from __future__ import annotations

from typing import Dict, Iterable, Sequence

from repro.core import isa
from repro.core.machine import MachineModel
from repro.core.microbench import measure_latency

__all__ = ["scale_table", "scale_sweep"]


def scale_table(machine: MachineModel, scales: Sequence[float] = (1.0, 2.0),
                instr_names: Sequence[str] = None,
                n_mfma: int = 2) -> Dict[str, Dict[float, float]]:
    """Reproduces paper Table VI: measured latency per instruction x scale."""
    if instr_names is None:
        instr_names = isa.supported_instructions(machine.gpu_table,
                                                 validated_only=True)
    out: Dict[str, Dict[float, float]] = {}
    for name in instr_names:
        out[name] = {}
        for s in scales:
            m = machine.with_scale(s)
            out[name][s] = measure_latency(m, name, n_mfma)
    return out


def scale_sweep(machine: MachineModel, instr_name: str,
                scales: Iterable[float]) -> Dict[float, float]:
    return {s: measure_latency(machine.with_scale(s), instr_name, 4)
            for s in scales}
