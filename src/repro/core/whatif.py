"""What-if analysis (paper Section V-B, Table VI) over overlay scenarios.

Scaling the MFMA cycle table lets users explore faster/slower future MCE
designs.  As the paper notes (Section VI), on real code the speedup is NOT
linear because the compiler fixed the amount of independent work between
MFMAs at compile time; the microbenchmark path below shows the linear
(instruction-isolated) effect while :mod:`repro.core.hlo_bridge` exposes the
workload-level (Amdahl-limited) effect.

The single ``mfma_scale`` float generalises to composable
:class:`repro.arch.Overlay` scenarios (clock/memory-latency/bandwidth
scaling, per-instruction table patches); sweeps are overlay *grids* —
see :func:`overlay_table` and :func:`grid_sweep` for the
instruction-isolated (microbenchmark) view and :func:`workload_grid` for
whole-workload scenario sweeps through the unified ``repro.perf``
pipeline (any engine, parsed once).
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

from repro.arch.overlay import Overlay, overlay_grid
from repro.core import isa
from repro.core.machine import MachineModel
from repro.core.microbench import measure_latency

__all__ = ["scale_table", "scale_sweep", "overlay_table", "grid_sweep",
           "workload_grid"]


def _validated_instrs(machine: MachineModel) -> Sequence[str]:
    if not machine.has_mfma_table:
        raise isa.UnsupportedInstructionError(
            f"{machine.name} has no MFMA cycle table to sweep; pass "
            "instr_names explicitly or use a table-bearing device")
    return machine.supported_instructions(validated_only=True)


def scale_table(machine: MachineModel, scales: Sequence[float] = (1.0, 2.0),
                instr_names: Optional[Sequence[str]] = None,
                n_mfma: int = 2) -> Dict[str, Dict[float, float]]:
    """Reproduces paper Table VI: measured latency per instruction x scale.

    ``with_scale`` semantics: each scale *replaces* the machine's
    ``mfma_scale`` (the paper's CLI knob).  For composable scenarios use
    :func:`overlay_table`.
    """
    if instr_names is None:
        instr_names = _validated_instrs(machine)
    out: Dict[str, Dict[float, float]] = {}
    for name in instr_names:
        out[name] = {}
        for s in scales:
            out[name][s] = measure_latency(machine.with_scale(s), name,
                                           n_mfma)
    return out


def overlay_table(machine: MachineModel, overlays: Sequence[Overlay],
                  instr_names: Optional[Sequence[str]] = None,
                  n_mfma: int = 2) -> Dict[str, Dict[str, float]]:
    """Measured Listing-1 latency per instruction x overlay scenario.

    Returns ``{instr: {overlay_label: cycles}}``; the general form of the
    paper's Table VI where a scenario may also turn clocks, memory
    latencies or individual table entries.
    """
    if instr_names is None:
        instr_names = _validated_instrs(machine)
    out: Dict[str, Dict[str, float]] = {}
    for name in instr_names:
        out[name] = {}
        for ov in overlays:
            m = machine.with_overlay(ov)
            out[name][ov.describe()] = measure_latency(m, name, n_mfma)
    return out


def scale_sweep(machine: MachineModel, instr_name: str,
                scales: Iterable[float]) -> Dict[float, float]:
    return {s: measure_latency(machine.with_scale(s), instr_name, 4)
            for s in scales}


def grid_sweep(machine: MachineModel, instr_name: str, *, n_mfma: int = 4,
               **axes: Iterable[float]) -> Dict[str, float]:
    """Full-grid microbenchmark sweep over overlay knobs.

    >>> grid_sweep(m, "fp32_16x16x16fp16",
    ...            mfma_scale=(0.5, 1, 2), mem_latency_scale=(1, 2))
    {'mfma x0.5': ..., 'mfma x0.5, memlat x2': ..., ...}
    """
    out: Dict[str, float] = {}
    for ov in overlay_grid(**axes):
        out[ov.describe()] = measure_latency(machine.with_overlay(ov),
                                             instr_name, n_mfma)
    return out


def workload_grid(workload, machine, *, engine="mfma", **axes):
    """Whole-workload scenario grid through the unified pipeline.

    The workload-level counterpart of :func:`grid_sweep`: ``workload`` is
    HLO text / a ``KernelGraph`` / a dry-run artifact path, ``engine`` any
    registered cost engine, and the result maps each overlay scenario to
    its shared-schema :class:`repro.perf.Report` (parsed exactly once
    across the whole grid).

    >>> workload_grid(compiled.as_text(), "mi300x",
    ...               mfma_scale=(0.5, 1, 2), clock_scale=(1, 1.2))
    {'mfma x0.5': Report(...), ...}
    """
    from repro.perf.pipeline import predict  # local: keep core import-light
    overlays = overlay_grid(**axes)
    reports = predict(workload, device=machine, engine=engine,
                      overlays=overlays)
    return {ov.describe(): rep for ov, rep in zip(overlays, reports)}
