"""Machine models: simulated-GPU parameters (paper Table I) + TPU target.

``MachineModel`` carries everything the scoreboard simulator and the HLO
bridge need: functional-unit topology, per-instruction-class latencies, the
MFMA cycle table selector and the ``mfma_scale`` what-if knob.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import isa

__all__ = ["MachineModel", "MI200", "MI300", "TPU_V5E", "get_machine"]


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    gpu_table: Optional[str]      # key into isa cycle tables; None => analytic only
    clock_mhz: float
    # -- CU topology (paper Section III / Table I) --
    cu_count: int = 60
    simd_per_cu: int = 4
    mce_per_simd: int = 1
    max_wf_per_simd: int = 10
    wavefront_size: int = 64
    # -- issue / probe calibration (paper Section IV-C, from [35]-[37]) --
    t_inst: int = 4               # per-instruction issue overhead, cycles
    t_memtime: int = 40           # s_memtime scalar-counter access, cycles
    # -- memory-system latencies, cycles (paper Table I) --
    l1i_latency: int = 40
    l1d_latency: int = 140
    scalar_latency: int = 41
    lds_latency: int = 65
    l2_latency: int = 269
    mem_latency: int = 483
    valu_latency: int = 1
    # -- the what-if knob (paper Section V-B) --
    mfma_scale: float = 1.0
    # -- TPU-analytic parameters (for the MXU machine) --
    mxu_count: int = 0
    mxu_dim: int = 128

    def with_scale(self, mfma_scale: float) -> "MachineModel":
        return dataclasses.replace(self, mfma_scale=mfma_scale)

    @property
    def mce_per_cu(self) -> int:
        return self.simd_per_cu * self.mce_per_simd

    def mfma_cycles(self, instr_name: str) -> int:
        if self.gpu_table is None:
            raise isa.UnsupportedInstructionError(
                f"{self.name} has no MFMA cycle table; use the analytic MXU path")
        return isa.mfma_cycles(self.gpu_table, instr_name,
                               mfma_scale=self.mfma_scale)

    def supports(self, instr_name: str) -> bool:
        try:
            self.mfma_cycles(instr_name)
            return True
        except isa.UnsupportedInstructionError:
            return False

    # --- analytic peaks (used by the HLO bridge / roofline) -------------
    @property
    def matrix_flops_per_cycle(self) -> float:
        """Peak matrix-unit FLOPs per cycle for the whole chip."""
        if self.mxu_count:
            return 2.0 * self.mxu_count * self.mxu_dim * self.mxu_dim
        # GPU: one MFMA of the densest class per MCE per `cycles`.
        # Use fp32_16x16x16fp16 as the canonical dense-ML instruction.
        inst = isa.lookup("fp32_16x16x16fp16")
        cyc = self.mfma_cycles("fp32_16x16x16fp16")
        return inst.flops * self.cu_count * self.mce_per_cu / cyc

    @property
    def peak_matrix_tflops(self) -> float:
        return self.matrix_flops_per_cycle * self.clock_mhz * 1e6 / 1e12


MI200 = MachineModel(name="mi200", gpu_table="mi200", clock_mhz=1801.0)
MI300 = MachineModel(name="mi300", gpu_table="mi300", clock_mhz=1801.0)

# TPU v5e: 197 bf16 TFLOP/s/chip = 2 * mxu_count * 128^2 * clock.
# 8 MXUs @ ~750 MHz reproduces the public peak within 0.2%.
TPU_V5E = MachineModel(
    name="tpu_v5e", gpu_table=None, clock_mhz=750.0,
    cu_count=1, simd_per_cu=1, mce_per_simd=8,
    mxu_count=8, mxu_dim=128,
)

_MACHINES = {"mi200": MI200, "mi300": MI300, "tpu_v5e": TPU_V5E}


def get_machine(name: str, *, mfma_scale: float = 1.0) -> MachineModel:
    m = _MACHINES[name.lower()]
    return m.with_scale(mfma_scale) if mfma_scale != 1.0 else m
