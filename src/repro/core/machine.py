"""Execution-facing machine models — a thin facade over ``repro.arch``.

Device capability data (paper Table I topology, Tables II-V cycle tables,
memory latencies/bandwidths, interconnect, clocks) lives in the declarative
:class:`repro.arch.DeviceSpec` registry; :class:`MachineModel` is the
flat, scoreboard-friendly view of one spec plus the runtime what-if state
(``mfma_scale`` and composed :class:`repro.arch.Overlay` scenarios).
Existing call sites keep working unchanged: every legacy field
(``cu_count``, ``t_inst``, ``l1d_latency``, ...) is populated from the
spec, and ``get_machine`` accepts any device in the registry — not just
the original hard-coded pair.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.arch.overlay import Overlay
from repro.arch.registry import get_device, list_devices
from repro.arch.spec import (CANONICAL_DENSE_INSTR, DeviceSpec,
                             matrix_peak_flops_per_cycle, scale_cycles)
from repro.core import isa

__all__ = ["MachineModel", "MI200", "MI300", "TPU_V5E", "get_machine",
           "list_machines", "as_machine"]


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    gpu_table: Optional[str]      # device name with a cycle table; None => analytic only
    clock_mhz: float
    # -- CU topology (paper Section III / Table I) --
    cu_count: int = 60
    simd_per_cu: int = 4
    mce_per_simd: int = 1
    max_wf_per_simd: int = 10
    wavefront_size: int = 64
    # -- issue / probe calibration (paper Section IV-C, from [35]-[37]) --
    t_inst: int = 4               # per-instruction issue overhead, cycles
    t_memtime: int = 40           # s_memtime scalar-counter access, cycles
    # -- memory-system latencies, cycles (paper Table I) --
    l1i_latency: int = 40
    l1d_latency: int = 140
    scalar_latency: int = 41
    lds_latency: int = 65
    l2_latency: int = 269
    mem_latency: int = 483
    valu_latency: int = 1
    # -- the what-if knob (paper Section V-B) --
    mfma_scale: float = 1.0
    # -- TPU-analytic parameters (for the MXU machine) --
    mxu_count: int = 0
    mxu_dim: int = 128
    # -- the backing capability spec (None only for hand-built models) --
    spec: Optional[DeviceSpec] = dataclasses.field(
        default=None, compare=False, repr=False)

    @classmethod
    def from_spec(cls, spec: DeviceSpec, *,
                  mfma_scale: float = 1.0) -> "MachineModel":
        mem = spec.memory
        return cls(
            name=spec.name,
            gpu_table=spec.name if spec.has_cycle_table else None,
            clock_mhz=spec.clock_mhz,
            cu_count=spec.cu_count,
            simd_per_cu=spec.simd_per_cu,
            mce_per_simd=spec.mce_per_simd,
            max_wf_per_simd=spec.max_wf_per_simd,
            wavefront_size=spec.wavefront_size,
            t_inst=spec.t_inst,
            t_memtime=spec.t_memtime,
            l1i_latency=mem.l1i_latency,
            l1d_latency=mem.l1d_latency,
            scalar_latency=mem.scalar_latency,
            lds_latency=mem.lds_latency,
            l2_latency=mem.l2_latency,
            mem_latency=mem.mem_latency,
            valu_latency=mem.valu_latency,
            mfma_scale=mfma_scale,
            mxu_count=spec.mxu_count,
            mxu_dim=spec.mxu_dim,
            spec=spec,
        )

    def with_scale(self, mfma_scale: float) -> "MachineModel":
        return dataclasses.replace(self, mfma_scale=mfma_scale)

    def with_overlay(self, overlay: Overlay) -> "MachineModel":
        """Apply a what-if scenario; returns a new machine.

        ``overlay.mfma_scale`` composes into the machine's ``mfma_scale``
        knob (lookup-time scaling, the paper's semantics — and what
        ``Prediction.mfma_scale`` reports); the remaining knobs
        (clock/memory-latency/bandwidth scaling, table patches) are baked
        into a transformed spec.
        """
        spec_part = dataclasses.replace(overlay, mfma_scale=1.0)
        if self.spec is None:
            if not spec_part.is_identity:
                raise ValueError(
                    f"{self.name} has no backing DeviceSpec: only the "
                    "mfma_scale overlay knob can apply to a hand-built "
                    "MachineModel")
            return self.with_scale(self.mfma_scale * overlay.mfma_scale)
        new_spec = self.spec if spec_part.is_identity \
            else spec_part.apply(self.spec)

        # Transform THIS machine's fields (not a rebuild from the spec), so
        # replace()-style tweaks the caller made survive the overlay.
        def _mem(v: int) -> int:
            return scale_cycles(v, overlay.mem_latency_scale)

        return dataclasses.replace(
            self,
            spec=new_spec,
            clock_mhz=self.clock_mhz * overlay.clock_scale,
            l1i_latency=_mem(self.l1i_latency),
            l1d_latency=_mem(self.l1d_latency),
            scalar_latency=_mem(self.scalar_latency),
            lds_latency=_mem(self.lds_latency),
            l2_latency=_mem(self.l2_latency),
            mem_latency=_mem(self.mem_latency),
            mfma_scale=self.mfma_scale * overlay.mfma_scale)

    @property
    def mce_per_cu(self) -> int:
        return self.simd_per_cu * self.mce_per_simd

    @property
    def has_mfma_table(self) -> bool:
        if self.spec is not None:
            return self.spec.has_cycle_table
        return self.gpu_table is not None

    def mfma_cycles(self, instr_name: str) -> int:
        if self.spec is not None and self.spec.has_cycle_table:
            return self.spec.mfma_cycles(instr_name,
                                         mfma_scale=self.mfma_scale)
        if self.gpu_table is None:
            raise isa.UnsupportedInstructionError(
                f"{self.name} has no MFMA cycle table; use the analytic MXU path")
        return isa.mfma_cycles(self.gpu_table, instr_name,
                               mfma_scale=self.mfma_scale)

    def supported_instructions(self, *, validated_only: bool = False
                               ) -> Sequence[str]:
        """Timing-model-supported instruction names on this machine."""
        if self.spec is not None and self.spec.has_cycle_table:
            return self.spec.supported_instructions(
                validated_only=validated_only)
        if self.gpu_table is None:
            raise isa.UnsupportedInstructionError(
                f"{self.name} has no MFMA cycle table; use the analytic MXU path")
        return isa.supported_instructions(self.gpu_table,
                                          validated_only=validated_only)

    def supports(self, instr_name: str) -> bool:
        try:
            self.mfma_cycles(instr_name)
            return True
        except isa.UnsupportedInstructionError:
            return False

    # --- analytic peaks (used by the HLO bridge / roofline) -------------
    @property
    def matrix_flops_per_cycle(self) -> float:
        """Peak matrix-unit FLOPs per cycle for the whole chip.

        One formula home (`repro.arch.spec.matrix_peak_flops_per_cycle`),
        fed this machine's own fields so replace()-tweaked topology and
        the active mfma_scale are honoured.
        """
        cyc = None if self.mxu_count else self.mfma_cycles(
            CANONICAL_DENSE_INSTR)
        return matrix_peak_flops_per_cycle(
            mxu_count=self.mxu_count, mxu_dim=self.mxu_dim,
            cu_count=self.cu_count, mce_per_cu=self.mce_per_cu,
            canonical_cycles=cyc)

    @property
    def peak_matrix_tflops(self) -> float:
        return self.matrix_flops_per_cycle * self.clock_mhz * 1e6 / 1e12


MI200 = MachineModel.from_spec(get_device("mi200"))
MI300 = MachineModel.from_spec(get_device("mi300"))
TPU_V5E = MachineModel.from_spec(get_device("tpu_v5e"))


def get_machine(name: str, *, mfma_scale: float = 1.0,
                overlay: Optional[Overlay] = None) -> MachineModel:
    """Machine model for any device in the ``repro.arch`` registry."""
    m = MachineModel.from_spec(get_device(name), mfma_scale=mfma_scale)
    return m.with_overlay(overlay) if overlay is not None else m


def list_machines() -> Sequence[str]:
    return list(list_devices())


def as_machine(obj) -> MachineModel:
    """Coerce a MachineModel, DeviceSpec, or device name to a machine —
    lets the scoreboard and bridge take any of the three."""
    if isinstance(obj, MachineModel):
        return obj
    if isinstance(obj, DeviceSpec):
        return MachineModel.from_spec(obj)
    return get_machine(obj)
