"""Event-driven CU/SIMD/MCE timing simulator (the paper's gem5 additions).

Semantics modelled, per paper Section III:

* 1 MCE per SIMD unit, ``simd_per_cu`` SIMD units per CU.  A per-SIMD
  ``NRDY_MATRIX_CORE`` counter holds the cycle until which that SIMD's MCE
  is busy; the scoreboard check refuses to issue an MFMA before it drains.
  This enforces (a) no two concurrent MFMAs on one SIMD — from the same WF
  *or* different WFs — and (b) no intra-WF MFMA pipelining (the observed
  AMD compiler behaviour the paper models).
* Wavefronts issue in order.  An instruction issues at::

      max(operands_ready, fu_available, wf_earliest_issue)

  where ``wf_earliest_issue`` is the previous instruction's issue cycle +
  ``t_inst`` (the calibrated 4-cycle issue overhead), except after a
  *blocking* scalar op (``s_memtime``, ``s_waitcnt``) where it is that op's
  completion cycle.
* Non-MCE work (VALU, memory, scalar) proceeds concurrently with a busy
  MCE, provided it has no true data dependency on the MFMA destination —
  exactly the independent-work/NOP discussion in the paper.
* ``s_memtime`` returns the cycle counter at issue and blocks the WF for
  ``t_memtime`` cycles (the scalar-cache access).  With this convention the
  paper's Listing-1 microbenchmark measures
  ``T_total = (N-1) * T_MFMA + T_memtime + T_inst`` and Eq. 1 recovers the
  per-instruction latency exactly.

Arbitration between WFs competing for one MCE is oldest-first (lowest
wf_id), matching gem5's ordered scoreboard walk; the simulator is fully
deterministic (no KVM jitter), so reproduced tables match the paper's
"Expected" column.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.machine import MachineModel, as_machine
from repro.core.program import Instr, Wavefront, Workload

__all__ = ["SimResult", "WFResult", "simulate", "simulate_program"]

_BLOCKING = {"s_memtime", "s_waitcnt"}


@dataclasses.dataclass
class IssueRecord:
    wf_id: int
    index: int
    opcode: str
    issue: int
    complete: int
    tag: Optional[str] = None


@dataclasses.dataclass
class WFResult:
    wf_id: int
    records: List[IssueRecord]
    regs: Dict[str, int]           # final symbolic register values (timestamps)
    finish: int

    def value(self, reg: str) -> int:
        return self.regs[reg]

    def by_tag(self, tag: str) -> IssueRecord:
        for r in self.records:
            if r.tag == tag:
                return r
        raise KeyError(tag)


@dataclasses.dataclass
class SimResult:
    wf: Dict[int, WFResult]
    makespan: int
    mce_busy: Dict[Tuple[int, int], int]        # (cu, simd) -> busy cycles
    stall_cycles: Dict[str, int]                # reason -> total stall cycles

    def mce_utilization(self, machine: MachineModel) -> float:
        if self.makespan == 0:
            return 0.0
        total = sum(self.mce_busy.values())
        n_mce = max(1, len(self.mce_busy))
        return total / (n_mce * self.makespan)


def _latency(machine: MachineModel, instr: Instr) -> int:
    op = instr.opcode
    if op == "mfma":
        return machine.mfma_cycles(instr.mfma_name)
    if op == "s_memtime":
        return machine.t_memtime
    if op == "v_alu":
        return machine.valu_latency
    if op == "v_load":
        return machine.l1d_latency
    if op == "ds_load":
        return machine.lds_latency
    if op == "s_load":
        return machine.scalar_latency
    if op in ("s_nop", "s_waitcnt"):
        return 0
    raise ValueError(f"unknown opcode {op!r}")


def simulate(machine, workload: Workload) -> SimResult:
    """Run every wavefront to completion; returns per-WF timing + stats.

    ``machine`` may be a :class:`MachineModel`, a
    :class:`repro.arch.DeviceSpec`, or a registered device name — any
    device in the ``repro.arch`` registry simulates without further glue.
    """
    machine = as_machine(machine)
    # Per-(cu, simd) MCE availability — the NRDY_MATRIX_CORE counters.
    nrdy_matrix_core: Dict[Tuple[int, int], int] = defaultdict(int)
    mce_busy: Dict[Tuple[int, int], int] = defaultdict(int)
    stalls: Dict[str, int] = defaultdict(int)

    @dataclasses.dataclass
    class _WFState:
        wf: Wavefront
        pc: int = 0
        earliest: int = 0                 # earliest next issue cycle
        last_issue: int = -(10 ** 9)
        regs_ready: Dict[str, int] = dataclasses.field(default_factory=dict)
        regs_value: Dict[str, int] = dataclasses.field(default_factory=dict)
        outstanding: List[int] = dataclasses.field(default_factory=list)
        records: List[IssueRecord] = dataclasses.field(default_factory=list)

    states = {w.wf_id: _WFState(wf=w) for w in workload.wavefronts}
    for st in states.values():
        key = (st.wf.cu, st.wf.simd)
        mce_busy.setdefault(key, 0)

    # Event loop: (candidate_time, wf_id).  We pop the WF that can attempt
    # an issue earliest; ties break oldest-first (lowest wf_id), matching
    # the ordered scoreboard walk in gem5.
    heap: List[Tuple[int, int]] = [(0, wf_id) for wf_id in sorted(states)]
    heapq.heapify(heap)

    while heap:
        t_candidate, wf_id = heapq.heappop(heap)
        st = states[wf_id]
        if st.pc >= len(st.wf.program):
            continue
        instr = st.wf.program[st.pc]
        key = (st.wf.cu, st.wf.simd)

        # 1. operand readiness (true data dependencies)
        ops_ready = 0
        for r in instr.srcs:
            ops_ready = max(ops_ready, st.regs_ready.get(r, 0))
        # 2. WAW/WAR on destinations (in-order WF => only WAW matters)
        for r in instr.dsts:
            ops_ready = max(ops_ready, st.regs_ready.get(r, 0))
        # 3. functional-unit availability
        fu_ready = t_candidate
        if instr.opcode == "mfma":
            fu_ready = max(fu_ready, nrdy_matrix_core[key])
        if instr.opcode == "s_waitcnt":
            # drain all outstanding tracked ops for this WF
            if st.outstanding:
                fu_ready = max(fu_ready, max(st.outstanding))

        issue = max(st.earliest, ops_ready, fu_ready, t_candidate)
        if issue > t_candidate:
            # Not ready yet at candidate time: requeue at the real time.
            if ops_ready > t_candidate:
                stalls["data_dependency"] += ops_ready - t_candidate
            if instr.opcode == "mfma" and nrdy_matrix_core[key] > t_candidate:
                stalls["nrdy_matrix_core"] += nrdy_matrix_core[key] - t_candidate
            heapq.heappush(heap, (issue, wf_id))
            continue

        lat = _latency(machine, instr)
        complete = issue + lat

        if instr.opcode == "mfma":
            nrdy_matrix_core[key] = complete      # MCE busy until done
            mce_busy[key] += lat
        if instr.opcode == "s_memtime":
            # dst = cycle counter sampled at issue
            for d in instr.dsts:
                st.regs_value[d] = issue
                st.regs_ready[d] = complete
        else:
            for d in instr.dsts:
                st.regs_ready[d] = complete
                st.regs_value[d] = complete
        if instr.opcode in ("v_load", "ds_load", "s_load"):
            st.outstanding.append(complete)

        st.records.append(IssueRecord(wf_id, st.pc, instr.opcode, issue,
                                      complete, tag=instr.tag))
        # Next-issue rule: blocking scalar ops hold the WF to completion.
        if instr.opcode in _BLOCKING:
            st.earliest = complete
        else:
            st.earliest = issue + machine.t_inst
        st.last_issue = issue
        st.pc += 1
        if st.pc < len(st.wf.program):
            heapq.heappush(heap, (st.earliest, wf_id))

    results: Dict[int, WFResult] = {}
    makespan = 0
    for wf_id, st in states.items():
        finish = max((r.complete for r in st.records), default=0)
        makespan = max(makespan, finish)
        results[wf_id] = WFResult(wf_id, st.records, dict(st.regs_value), finish)
    return SimResult(wf=results, makespan=makespan,
                     mce_busy=dict(mce_busy), stall_cycles=dict(stalls))


def simulate_program(machine: MachineModel, program, **kw) -> WFResult:
    res = simulate(machine, Workload.single(program, **kw))
    return res.wf[0]
