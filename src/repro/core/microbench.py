"""Listing-1 microbenchmarks, Eq. 1 latency extraction, and the
representative GEMM tile loops the scoreboard engine measures.

``build_listing1`` reconstructs the paper's inlined-assembly kernel as IR::

    s_waitcnt                  # line 2: lgkmcnt(0) & vmcnt(0)
    [s_nop padding]            # blue-highlighted instructions needed this
    s_memtime  -> start        # line 3
    v_mfma x N (data-dependent chain through D/C)   # lines 4-8
    s_memtime  -> end          # line 9
    s_waitcnt                  # line 10

The MFMAs accumulate in place (``[C] "v"(d)`` in Listing 1), so each reads
the previous one's destination: the chain serialises, the scoreboard holds
each issue for the full MFMA latency, and

    T_total = (N_MFMA - 1) * T_MFMA + T_memtime + T_inst          (paper)
    T_MFMA  = (T_total - T_memtime - T_inst) / (N_MFMA - 1)       (Eq. 1)

As in the paper, the functional output of this stream is intentionally
wrong (no independent work / NOPs between dependent MFMAs) — it is a pure
timing probe.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Optional, Sequence

from repro.core import isa
from repro.core.machine import MachineModel, as_machine
from repro.core.program import (Program, Wavefront, Workload, mfma,
                                s_memtime, s_nop, s_waitcnt)
from repro.core.scoreboard import WFResult, simulate, simulate_program

__all__ = ["build_listing1", "t_total", "eq1_latency", "measure_latency",
           "latency_table", "gemm_stream", "simulate_gemm_cu",
           "plan_microops", "measure_plan_throughput"]


def build_listing1(instr_name: str, n_mfma: int, *, padding_nops: int = 0) -> Program:
    if n_mfma < 2:
        raise ValueError("need >= 2 MFMAs: the final one is not waited on "
                         "(paper Section IV-C)")
    prog: Program = [s_waitcnt()]
    prog += [s_nop() for _ in range(padding_nops)]
    prog.append(s_memtime("s_start", tag="start"))
    for i in range(n_mfma):
        # D = C + A*B with C == D: in-place accumulate => true dep chain.
        prog.append(mfma(instr_name, d="v_d", a="v_a", b="v_b", c="v_d",
                         tag=f"mfma{i}"))
    prog.append(s_memtime("s_end", tag="end"))
    prog.append(s_waitcnt())
    return prog


def t_total(result: WFResult) -> int:
    """total = end - start, as accumulated on line 13 of Listing 1."""
    return result.value("s_end") - result.value("s_start")


def eq1_latency(total: int, n_mfma: int, machine: MachineModel) -> float:
    """Equation 1 of the paper."""
    return (total - machine.t_memtime - machine.t_inst) / (n_mfma - 1)


def measure_latency(machine: MachineModel, instr_name: str, n_mfma: int,
                    *, padding_nops: int = 0) -> float:
    prog = build_listing1(instr_name, n_mfma, padding_nops=padding_nops)
    res = simulate_program(machine, prog)
    return eq1_latency(t_total(res), n_mfma, machine)


def latency_table(machine: MachineModel,
                  instr_names: Optional[Sequence[str]] = None,
                  n_range: Iterable[int] = (2, 3, 4, 5)) -> Dict[str, Dict[int, float]]:
    """Reproduces paper Tables III/V (gem5 columns) for ``machine``.

    Returns {instr: {N: measured_latency}}.  Deterministic, so values match
    the 'Expected' column rather than the KVM-jittered samples.
    """
    if instr_names is None:
        instr_names = machine.supported_instructions(validated_only=True)
    return {name: {n: measure_latency(machine, name, n) for n in n_range}
            for name in instr_names}


# ---------------------------------------------------------------------------
# Representative GEMM tile loops (the scoreboard engine's measurement path)
# ---------------------------------------------------------------------------

def gemm_stream(instr_name: str, n_tiles: int, wf_id: int) -> Program:
    """Independent MFMA tiles for one WF (software-pipelined: no dep chain)."""
    return [mfma(instr_name, d=f"acc{t}", a=f"a{t}", b=f"b{t}", c=f"acc{t}")
            for t in range(n_tiles)]


def simulate_gemm_cu(machine: MachineModel, instr_name: str, *,
                     tiles_per_wf: int = 8, n_wf: int = 8) -> Dict[str, float]:
    """Simulate one CU running a GEMM tile loop across n_wf wavefronts.

    WFs are assigned round-robin to SIMD units; with n_wf >= simd_per_cu the
    analytic throughput (mce_per_cu MFMAs per mfma_cycles) should be reached.
    """
    machine = as_machine(machine)
    wfs = [Wavefront(w, gemm_stream(instr_name, tiles_per_wf, w),
                     cu=0, simd=w % machine.simd_per_cu)
           for w in range(n_wf)]
    res = simulate(machine, Workload(wfs))
    total_mfma = tiles_per_wf * n_wf
    lat = machine.mfma_cycles(instr_name)
    analytic = total_mfma * lat / min(n_wf, machine.mce_per_cu)
    return {"makespan": res.makespan, "analytic_cycles": analytic,
            "mce_utilization": res.mce_utilization(machine),
            "total_mfma": total_mfma}


def plan_microops(plan, instr_name: str) -> int:
    """MFMA micro-ops covering ONE (block_m, block_n, block_k) plan tile.

    ``plan`` is a :class:`repro.kernels.plan.TilePlan` for a GEMM-shaped
    kernel — the same object the Pallas kernel executes, so the simulated
    stream and the real tile loop cover identical work.
    """
    i = isa.lookup(instr_name)
    b = plan.blocks
    tiles = (math.ceil(b["block_m"] / i.m) * math.ceil(b["block_n"] / i.n)
             * math.ceil(b["block_k"] / i.k))
    return math.ceil(tiles / i.blocks)


def measure_plan_throughput(machine: MachineModel, instr_name: str, plan, *,
                            max_tiles_per_wf: int = 16) -> Dict[str, float]:
    """Measured per-CU throughput for one plan tile at full occupancy.

    One WF per MCE; each WF's stream is its share of the plan tile's
    micro-ops, capped at ``max_tiles_per_wf`` (measured cycles/MFMA
    converges well before that — the cap bounds event-sim cost, not
    fidelity).  Returns the ``simulate_gemm_cu`` dict plus the per-WF
    stream length actually simulated."""
    machine = as_machine(machine)
    n_wf = machine.mce_per_cu
    per_wf = max(1, min(max_tiles_per_wf,
                        math.ceil(plan_microops(plan, instr_name) / n_wf)))
    out = simulate_gemm_cu(machine, instr_name, tiles_per_wf=per_wf,
                           n_wf=n_wf)
    out["tiles_per_wf"] = per_wf
    out["cycles_per_mfma_cu"] = out["makespan"] / out["total_mfma"]
    return out
