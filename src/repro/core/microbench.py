"""Listing-1 microbenchmarks and the Eq. 1 latency extraction.

``build_listing1`` reconstructs the paper's inlined-assembly kernel as IR::

    s_waitcnt                  # line 2: lgkmcnt(0) & vmcnt(0)
    [s_nop padding]            # blue-highlighted instructions needed this
    s_memtime  -> start        # line 3
    v_mfma x N (data-dependent chain through D/C)   # lines 4-8
    s_memtime  -> end          # line 9
    s_waitcnt                  # line 10

The MFMAs accumulate in place (``[C] "v"(d)`` in Listing 1), so each reads
the previous one's destination: the chain serialises, the scoreboard holds
each issue for the full MFMA latency, and

    T_total = (N_MFMA - 1) * T_MFMA + T_memtime + T_inst          (paper)
    T_MFMA  = (T_total - T_memtime - T_inst) / (N_MFMA - 1)       (Eq. 1)

As in the paper, the functional output of this stream is intentionally
wrong (no independent work / NOPs between dependent MFMAs) — it is a pure
timing probe.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

from repro.core.machine import MachineModel
from repro.core.program import (Program, mfma, s_memtime, s_nop, s_waitcnt)
from repro.core.scoreboard import WFResult, simulate_program

__all__ = ["build_listing1", "t_total", "eq1_latency", "measure_latency",
           "latency_table"]


def build_listing1(instr_name: str, n_mfma: int, *, padding_nops: int = 0) -> Program:
    if n_mfma < 2:
        raise ValueError("need >= 2 MFMAs: the final one is not waited on "
                         "(paper Section IV-C)")
    prog: Program = [s_waitcnt()]
    prog += [s_nop() for _ in range(padding_nops)]
    prog.append(s_memtime("s_start", tag="start"))
    for i in range(n_mfma):
        # D = C + A*B with C == D: in-place accumulate => true dep chain.
        prog.append(mfma(instr_name, d="v_d", a="v_a", b="v_b", c="v_d",
                         tag=f"mfma{i}"))
    prog.append(s_memtime("s_end", tag="end"))
    prog.append(s_waitcnt())
    return prog


def t_total(result: WFResult) -> int:
    """total = end - start, as accumulated on line 13 of Listing 1."""
    return result.value("s_end") - result.value("s_start")


def eq1_latency(total: int, n_mfma: int, machine: MachineModel) -> float:
    """Equation 1 of the paper."""
    return (total - machine.t_memtime - machine.t_inst) / (n_mfma - 1)


def measure_latency(machine: MachineModel, instr_name: str, n_mfma: int,
                    *, padding_nops: int = 0) -> float:
    prog = build_listing1(instr_name, n_mfma, padding_nops=padding_nops)
    res = simulate_program(machine, prog)
    return eq1_latency(t_total(res), n_mfma, machine)


def latency_table(machine: MachineModel,
                  instr_names: Optional[Sequence[str]] = None,
                  n_range: Iterable[int] = (2, 3, 4, 5)) -> Dict[str, Dict[int, float]]:
    """Reproduces paper Tables III/V (gem5 columns) for ``machine``.

    Returns {instr: {N: measured_latency}}.  Deterministic, so values match
    the 'Expected' column rather than the KVM-jittered samples.
    """
    if instr_names is None:
        instr_names = machine.supported_instructions(validated_only=True)
    return {name: {n: measure_latency(machine, name, n) for n in n_range}
            for name in instr_names}
