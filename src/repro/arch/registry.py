"""The device catalog: data-driven :class:`DeviceSpec` instances.

The MI200/MI300 cycle tables (paper Tables II-V) live here now — moved out
of ``repro.core.isa``, which re-exports them in the legacy
``{name: (cycles, validated)}`` form for backward compatibility.  Base
devices are spelled out in full; variants (``mi300x``, ``tpu_v5p``) are
*deltas* via :meth:`DeviceSpec.derive`, which is the pattern for adding a
new device: start from the closest base, override what differs, and mark
inherited timing entries unvalidated (``revalidate=False``) until they are
measured (ROADMAP "Architecture" section shows a complete example).
"""

from __future__ import annotations

from typing import Dict, Iterable, Tuple

from repro.arch.spec import (CycleEntry, DeviceSpec, Interconnect,
                             MemoryHierarchy, UnknownDeviceError)

__all__ = [
    "MI200_CYCLES",
    "MI300_CYCLES",
    "register_device",
    "get_device",
    "list_devices",
    "UnknownDeviceError",
]


# ---------------------------------------------------------------------------
# MFMA timing tables: {instr: (cycles, validated)}.
# Keys absent from a table mean "not supported on that GPU".
# Paper-validated entries (Tables II-V "Expected" column) are listed first.
# ---------------------------------------------------------------------------

MI200_CYCLES: Dict[str, Tuple[int, bool]] = {
    "fp64_16x16x4fp64": (32, True),
    "fp32_4x4x1fp32": (8, True),
    "fp32_16x16x4fp32": (32, True),
    "fp32_16x16x16fp16": (32, True),
    "i32_16x16x16i8": (32, True),
    "fp64_4x4x4fp64": (16, True),
    "fp32_4x4x4fp16": (8, True),
    # ISA-manual-pattern latency classes (same class as shape-mates):
    "fp32_32x32x2fp32": (64, False),
    "fp32_32x32x4bf16": (64, False),
    "fp32_16x16x8bf16": (32, False),
}

MI300_CYCLES: Dict[str, Tuple[int, bool]] = {
    "fp64_16x16x4fp64": (32, True),
    "fp32_4x4x1fp32": (8, True),
    "fp32_16x16x4fp32": (32, True),
    # MI300 improved this latency vs MI200 (32 -> 16), Table IV:
    "fp32_16x16x16fp16": (16, True),
    "fp64_4x4x4fp64": (16, True),
    "fp32_4x4x4fp16": (8, True),
    # i32_16x16x16i8: REMOVED on MI300 (paper Section III-A).
    # New on MI300: 2-block bf16 variant, same cycles as MI200 1-block:
    "f32_32x32x4_2b_bf16": (64, False),
    "fp32_16x16x16bf16": (16, False),
    "i32_16x16x32i8": (16, False),
    "i32_32x32x16i8": (32, False),
    "fp32_16x16x32fp8": (16, False),
}


def _table(raw: Dict[str, Tuple[int, bool]]) -> Dict[str, CycleEntry]:
    return {k: CycleEntry(cycles, validated)
            for k, (cycles, validated) in raw.items()}


# ---------------------------------------------------------------------------
# Base devices
# ---------------------------------------------------------------------------

MI200 = DeviceSpec(
    name="mi200",
    family="amd-cdna2",
    clock_mhz=1801.0,
    # CU topology + memory latencies are the paper's Table I defaults.
    memory=MemoryHierarchy(hbm_bw=1638e9),          # MI210: 1.6 TB/s HBM2e
    interconnect=Interconnect(links=3, link_bw=50e9),
    cycle_table=_table(MI200_CYCLES),
    vmem_bytes=8 << 20,      # 8 MiB L2 as the tile-staging budget
)

MI300 = DeviceSpec(
    name="mi300",
    family="amd-cdna3",
    clock_mhz=1801.0,
    memory=MemoryHierarchy(hbm_bw=5300e9),          # HBM3: 5.3 TB/s
    interconnect=Interconnect(links=7, link_bw=64e9),
    cycle_table=_table(MI300_CYCLES),
    vmem_bytes=32 << 20,     # per-XCD L2 + Infinity Cache staging slice
)

# TPU v5e: 197 bf16 TFLOP/s/chip = 2 * mxu_count * 128^2 * clock.
# 8 MXUs @ ~750 MHz reproduces the public peak within 0.2%; peak_flops
# pins the advertised figure the roofline uses.
TPU_V5E = DeviceSpec(
    name="tpu_v5e",
    family="google-tpu",
    clock_mhz=750.0,
    cu_count=1, simd_per_cu=1, mce_per_simd=8,
    mxu_count=8, mxu_dim=128,
    memory=MemoryHierarchy(hbm_bw=819e9),
    # a bidirectional-ring collective on one torus dimension drives 2 ICI
    # links (~50 GB/s each) concurrently; a 2D-torus all-reduce can stripe
    # further — we stay conservative.
    interconnect=Interconnect(links=2, link_bw=50e9),
    peak_flops=197e12,
    vmem_bytes=16 << 20,     # ~16 MiB VMEM per core feeds the MXUs
)

# ---------------------------------------------------------------------------
# Derived devices (deltas of the bases)
# ---------------------------------------------------------------------------

# MI300X-class part: full 304-CU CDNA3 at boost clock.  The timing table is
# inherited from mi300 but has NOT been re-measured on this silicon, so
# every entry is demoted to validated=False (provenance stays honest).
MI300X = MI300.derive(
    "mi300x",
    revalidate=False,
    cu_count=304,
    clock_mhz=2100.0,
    # memory + interconnect inherited from the mi300 base
)

# TPU v5p: 459 bf16 TFLOP/s => 8 MXUs @ ~1.75 GHz; 2765 GB/s HBM and
# ~100 GB/s ICI links.
TPU_V5P = TPU_V5E.derive(
    "tpu_v5p",
    clock_mhz=1750.0,
    hbm_bw=2765e9,
    links=2, link_bw=100e9,
    peak_flops=459e12,
)


_REGISTRY: Dict[str, DeviceSpec] = {}


def register_device(spec: DeviceSpec, *, replace: bool = False) -> DeviceSpec:
    """Add ``spec`` to the catalog (idempotent only with ``replace``)."""
    key = spec.name.lower()
    if key in _REGISTRY and not replace:
        raise ValueError(f"device {spec.name!r} is already registered")
    _REGISTRY[key] = spec
    return spec


def get_device(name: str) -> DeviceSpec:
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        raise UnknownDeviceError(
            f"unknown device {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_devices() -> Iterable[str]:
    return sorted(_REGISTRY)


for _spec in (MI200, MI300, MI300X, TPU_V5E, TPU_V5P):
    register_device(_spec)
