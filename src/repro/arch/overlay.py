"""Composable what-if overlays over :class:`DeviceSpec`.

The paper's single ``--mfma-scale`` float (Section V-B) generalises to a
declarative scenario transform: scale the MFMA timing table, the clock,
memory latencies or bandwidths, or patch individual table entries — and
compose several of those into one scenario.  Sweeps become overlay *grids*
(the cartesian product of per-knob value lists), so "MFMA 2x faster AND
HBM 1.5x slower" is one grid cell, not a bespoke code path.

Scaled/patched table entries are marked ``validated=False``: a what-if
scenario is by definition not hardware-measured.

The mfma-scale rounding (``max(1, round(cycles * scale))``) matches the
gem5 patch exactly, so overlay results are bit-identical to the legacy
``MachineModel.with_scale`` path (asserted by ``tests/test_arch_registry``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Mapping

from repro.arch.spec import CycleEntry, DeviceSpec, scale_cycles

__all__ = ["Overlay", "IDENTITY", "overlay_grid"]


@dataclasses.dataclass(frozen=True)
class Overlay:
    """One what-if scenario, expressed as multiplicative deltas + patches."""

    mfma_scale: float = 1.0        # the paper's --mfma-scale knob
    clock_scale: float = 1.0
    mem_latency_scale: float = 1.0
    bw_scale: float = 1.0          # HBM + link bandwidths
    table_patches: Mapping[str, int] = dataclasses.field(
        default_factory=dict)    # instr -> absolute cycles (pre-scale)
    label: str = ""

    @property
    def is_identity(self) -> bool:
        return (self.mfma_scale == 1.0 and self.clock_scale == 1.0
                and self.mem_latency_scale == 1.0 and self.bw_scale == 1.0
                and not self.table_patches)

    def describe(self) -> str:
        if self.label:
            return self.label
        parts = []
        if self.mfma_scale != 1.0:
            parts.append(f"mfma x{self.mfma_scale:g}")
        if self.clock_scale != 1.0:
            parts.append(f"clock x{self.clock_scale:g}")
        if self.mem_latency_scale != 1.0:
            parts.append(f"memlat x{self.mem_latency_scale:g}")
        if self.bw_scale != 1.0:
            parts.append(f"bw x{self.bw_scale:g}")
        for k, v in self.table_patches.items():
            parts.append(f"{k}={v}cy")
        return ", ".join(parts) or "baseline"

    def compose(self, other: "Overlay") -> "Overlay":
        """Apply ``other`` on top of this overlay (scales multiply;
        ``other``'s table patches win on conflict)."""
        patches: Dict[str, int] = dict(self.table_patches)
        patches.update(other.table_patches)
        label = ", ".join(x for x in (self.label, other.label) if x)
        return Overlay(
            mfma_scale=self.mfma_scale * other.mfma_scale,
            clock_scale=self.clock_scale * other.clock_scale,
            mem_latency_scale=self.mem_latency_scale * other.mem_latency_scale,
            bw_scale=self.bw_scale * other.bw_scale,
            table_patches=patches,
            label=label,
        )

    def apply(self, spec: DeviceSpec) -> DeviceSpec:
        """The spec this scenario describes.

        Note for MXU (table-less) devices the ``mfma_scale`` knob has no
        table to scale — ``MachineModel.with_overlay`` threads it into the
        analytic pass-cycle path instead.
        """
        if self.is_identity:
            return spec
        table: Dict[str, CycleEntry] = {}
        for name, entry in spec.cycle_table.items():
            base = self.table_patches.get(name, entry.cycles)
            cycles = scale_cycles(base, self.mfma_scale)
            touched = (cycles != entry.cycles or name in self.table_patches)
            table[name] = CycleEntry(
                cycles, validated=entry.validated and not touched)
        # patches for instructions the device lacks ADD support for them
        # (hypothesised-new-instruction what-ifs), mirroring derive()
        for name, base in self.table_patches.items():
            if name not in table:
                table[name] = CycleEntry(
                    scale_cycles(base, self.mfma_scale), validated=False)
        memory = spec.memory.scaled(self.mem_latency_scale)
        if self.bw_scale != 1.0:
            memory = dataclasses.replace(
                memory,
                hbm_bw=memory.hbm_bw * self.bw_scale,
                l2_bw=memory.l2_bw * self.bw_scale,
                lds_bw=memory.lds_bw * self.bw_scale)
        interconnect = spec.interconnect
        if self.bw_scale != 1.0:
            interconnect = dataclasses.replace(
                interconnect, link_bw=interconnect.link_bw * self.bw_scale)
        return dataclasses.replace(
            spec,
            name=f"{spec.name}+{self.describe()}",
            clock_mhz=spec.clock_mhz * self.clock_scale,
            memory=memory,
            interconnect=interconnect,
            cycle_table=table,
            # an advertised peak no longer holds under a scenario
            peak_flops=spec.peak_flops * self.clock_scale / self.mfma_scale,
        )


IDENTITY = Overlay()


def overlay_grid(**axes: Iterable[float]) -> List[Overlay]:
    """Cartesian sweep grid over overlay knobs.

    >>> overlay_grid(mfma_scale=(0.5, 1, 2), clock_scale=(1, 1.2))
    [Overlay(mfma_scale=0.5, clock_scale=1), ...]   # 6 scenarios

    Axis names must be scalar :class:`Overlay` fields
    (``table_patches`` grids are built by hand).
    """
    valid = {f.name for f in dataclasses.fields(Overlay)} - {
        "table_patches", "label"}
    for k in axes:
        if k not in valid:
            raise TypeError(f"unknown overlay axis {k!r}; valid: "
                            f"{sorted(valid)}")
    names = list(axes)
    grid = []
    for values in itertools.product(*(axes[n] for n in names)):
        grid.append(Overlay(**dict(zip(names, map(float, values)))))
    return grid
