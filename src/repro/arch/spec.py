"""`DeviceSpec`: the declarative device-capability schema.

One frozen, data-only description per accelerator, unifying what used to be
smeared across four layers of the repro:

* compute topology (CU/SIMD/MCE for AMD matrix cores, MXU count/dim for
  TPUs) — previously frozen constants in ``repro.core.machine``;
* per-instruction MFMA cycle tables with ``validated`` provenance (the
  paper's Tables II-V "Expected" column vs ISA-manual-pattern entries) —
  previously dict literals in ``repro.core.isa``;
* the memory hierarchy — L1/LDS/L2/HBM *latencies* (paper Table I) and
  *bandwidths* (roofline) in one place;
* the interconnect (link count x per-link bandwidth) — previously
  module-level magic numbers in ``repro.launch.roofline``;
* clocks and advertised peak FLOP/s.

Specs are immutable; variants are expressed as *deltas* via
:meth:`DeviceSpec.derive` (see ``repro.arch.registry``) and what-if
scenarios as composable :class:`repro.arch.overlay.Overlay` transforms.

This module deliberately has **no module-level imports from repro.core**:
``repro.core.isa`` keeps the instruction *registry* (shapes, dtypes,
``gpr_idx`` addressing quirks) and re-exports the legacy cycle-table dicts
from here, so instruction metadata is imported lazily at call time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

__all__ = [
    "CycleEntry",
    "MemoryHierarchy",
    "Interconnect",
    "DeviceSpec",
    "UnknownDeviceError",
]


def _isa():
    # Lazy: repro.core.isa imports legacy table views from repro.arch, so
    # this module must not import it at module scope.
    from repro.core import isa
    return isa


#: Canonical dense-ML instruction anchoring GPU peak-throughput math.
CANONICAL_DENSE_INSTR = "fp32_16x16x16fp16"


def scale_cycles(cycles: int, scale: float) -> int:
    """The gem5 what-if rounding rule: multiply, round, clamp to >= 1.

    The ONE home of this contract — cycle-table scaling, memory-latency
    scaling, and machine-level overlays must all round identically or
    spec-level and machine-level scenarios drift apart.
    """
    if scale == 1.0:
        return cycles
    return max(1, int(round(cycles * scale)))


def matrix_peak_flops_per_cycle(*, mxu_count: int, mxu_dim: int,
                                cu_count: int, mce_per_cu: int,
                                canonical_cycles: Optional[int]) -> float:
    """Whole-chip peak matrix FLOPs/cycle — the ONE home of the formula.

    MXU devices: systolic-array throughput.  GPU devices: one
    ``CANONICAL_DENSE_INSTR`` per MCE per ``canonical_cycles``.
    Both ``DeviceSpec`` and ``repro.core.machine.MachineModel`` call this
    with their own (possibly tweaked) values.
    """
    if mxu_count:
        return 2.0 * mxu_count * mxu_dim * mxu_dim
    flops = _isa().lookup(CANONICAL_DENSE_INSTR).flops
    return flops * cu_count * mce_per_cu / canonical_cycles


class UnknownDeviceError(KeyError):
    """Raised when a device name is not in the registry.

    Subclasses :class:`KeyError` so legacy ``except KeyError`` call sites
    keep working; :mod:`repro.core.isa` converts it to
    ``UnsupportedInstructionError`` to preserve its documented contract.
    """


@dataclasses.dataclass(frozen=True)
class CycleEntry:
    """One row of a per-device MFMA timing table.

    ``validated=True`` entries are the paper's Tables II-V "Expected"
    column (cross-checked on real MI210/MI300 hardware); ``False`` entries
    follow the ISA-manual latency-class pattern, or were inherited onto a
    derived device whose silicon has not been measured.
    """

    cycles: int
    validated: bool = False


@dataclasses.dataclass(frozen=True)
class MemoryHierarchy:
    """Latencies in core cycles (paper Table I) + bandwidths in bytes/s."""

    l1i_latency: int = 40
    l1d_latency: int = 140
    scalar_latency: int = 41
    lds_latency: int = 65
    l2_latency: int = 269
    mem_latency: int = 483
    valu_latency: int = 1
    hbm_bw: float = 0.0          # bytes/s, whole chip
    l2_bw: float = 0.0           # bytes/s, whole chip (0 = unspecified)
    lds_bw: float = 0.0          # bytes/s, whole chip (0 = unspecified)

    def scaled(self, latency_scale: float) -> "MemoryHierarchy":
        """Uniformly scale every *memory* latency (what-if knob).

        ``valu_latency`` is a compute-pipe latency and is deliberately
        untouched — a "slower HBM" scenario must not slow the vector ALU.
        Bandwidths are kept (see Overlay.bw_scale for those).
        """
        if latency_scale == 1.0:
            return self
        return dataclasses.replace(
            self,
            **{f: scale_cycles(getattr(self, f), latency_scale)
               for f in ("l1i_latency", "l1d_latency", "scalar_latency",
                         "lds_latency", "l2_latency", "mem_latency")})


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """Off-chip links as the roofline charges them.

    ``links`` is the number of links a ring collective drives
    *concurrently* (2 for a bidirectional ring on one torus dimension),
    not the physical port count; ``link_bw`` is per-link bytes/s.
    """

    links: int = 1
    link_bw: float = 0.0


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Full capability description of one accelerator."""

    name: str
    family: str = ""              # e.g. "amd-cdna2", "google-tpu"
    clock_mhz: float = 1000.0
    # -- compute topology (paper Section III / Table I) ------------------
    cu_count: int = 60
    simd_per_cu: int = 4
    mce_per_simd: int = 1
    max_wf_per_simd: int = 10
    wavefront_size: int = 64
    # -- issue / probe calibration (paper Section IV-C) ------------------
    t_inst: int = 4
    t_memtime: int = 40
    # -- TPU-analytic matrix units (0 => MFMA cycle-table device) --------
    mxu_count: int = 0
    mxu_dim: int = 128
    # -- fast on-chip tile budget in bytes (VMEM per TPU core; an L2 /
    #    Infinity-Cache staging slice on cycle-table GPUs).  The kernel
    #    tile planner (repro.kernels.plan) sizes block working sets
    #    against this; 0 means "unspecified" and the planner falls back
    #    to a conservative default.
    vmem_bytes: int = 0
    # -- memory + interconnect ------------------------------------------
    memory: MemoryHierarchy = MemoryHierarchy()
    interconnect: Interconnect = Interconnect()
    # -- MFMA timing table: instr name -> CycleEntry ---------------------
    cycle_table: Mapping[str, CycleEntry] = dataclasses.field(
        default_factory=dict)
    # -- advertised peak matrix FLOP/s (0 => derive from the tables) -----
    peak_flops: float = 0.0

    # ------------------------------------------------------------------
    # Topology helpers
    # ------------------------------------------------------------------
    @property
    def mce_per_cu(self) -> int:
        return self.simd_per_cu * self.mce_per_simd

    @property
    def has_cycle_table(self) -> bool:
        return bool(self.cycle_table)

    # ------------------------------------------------------------------
    # Timing table (the paper's mfma_cycles lookup)
    # ------------------------------------------------------------------
    def mfma_cycles(self, name: str, *, mfma_scale: float = 1.0,
                    allow_gpr_idx: bool = False) -> int:
        """Latency in cycles of ``name`` on this device.

        ``mfma_scale`` is the paper's ``--mfma-scale`` what-if parameter:
        the tabled latency is multiplied and rounded, exactly as in gem5.
        """
        isa = _isa()
        instr = isa.lookup(name)
        if instr.gpr_idx_mode and not allow_gpr_idx:
            raise isa.UnsupportedInstructionError(
                f"{name} uses the s_set_gpr_idx addressing mode, which the "
                "gem5-parity timing model does not support "
                "(paper Section VI)")
        if not self.has_cycle_table:
            raise isa.UnsupportedInstructionError(
                f"{self.name} has no MFMA cycle table; "
                "use the analytic MXU path")
        entry = self.cycle_table.get(name)
        if entry is None:
            raise isa.UnsupportedInstructionError(
                f"{name} is not supported on {self.name} "
                "(e.g. i32_16x16x16i8 was removed on MI300)")
        return scale_cycles(entry.cycles, mfma_scale)

    def supported_instructions(self, *, validated_only: bool = False
                               ) -> Sequence[str]:
        isa = _isa()
        out = []
        for name, entry in self.cycle_table.items():
            if validated_only and not entry.validated:
                continue
            if isa.lookup(name).gpr_idx_mode:
                continue
            out.append(name)
        return out

    def supports(self, name: str) -> bool:
        isa = _isa()
        try:
            self.mfma_cycles(name)
            return True
        except isa.UnsupportedInstructionError:
            return False

    # ------------------------------------------------------------------
    # Analytic peaks (HLO bridge / roofline)
    # ------------------------------------------------------------------
    def matrix_flops_per_cycle_at(self, mfma_scale: float = 1.0) -> float:
        """Peak matrix-unit FLOPs per cycle for the whole chip.

        ``mfma_scale`` reaches the GPU cycle lookup; the MXU path is
        throughput-fixed per pass (the what-if applies to pass time in
        the bridge instead).
        """
        cyc = None if self.mxu_count else self.mfma_cycles(
            CANONICAL_DENSE_INSTR, mfma_scale=mfma_scale)
        return matrix_peak_flops_per_cycle(
            mxu_count=self.mxu_count, mxu_dim=self.mxu_dim,
            cu_count=self.cu_count, mce_per_cu=self.mce_per_cu,
            canonical_cycles=cyc)

    @property
    def matrix_flops_per_cycle(self) -> float:
        return self.matrix_flops_per_cycle_at()

    @property
    def peak_matrix_tflops(self) -> float:
        return self.matrix_flops_per_cycle * self.clock_mhz * 1e6 / 1e12

    @property
    def peak_flops_effective(self) -> float:
        """Advertised peak FLOP/s when known, else the derived peak."""
        return self.peak_flops or self.peak_matrix_tflops * 1e12

    # ------------------------------------------------------------------
    # Variant construction (the registry's delta mechanism)
    # ------------------------------------------------------------------
    def derive(self, name: str, *,
               table_patches: Optional[Mapping[str, int]] = None,
               table_remove: Sequence[str] = (),
               table_add: Optional[Mapping[str, Tuple[int, bool]]] = None,
               revalidate: bool = True,
               **overrides) -> "DeviceSpec":
        """A new spec expressed as a delta of this one.

        ``table_patches`` replaces cycle counts for existing instructions,
        ``table_remove`` drops instructions, ``table_add`` maps new
        instruction names to ``(cycles, validated)``.  With
        ``revalidate=False`` every inherited entry is marked
        ``validated=False`` — the right provenance for a derived device
        whose silicon has not been measured against the paper's tables.
        """
        table: Dict[str, CycleEntry] = {}
        for instr, entry in self.cycle_table.items():
            if instr in table_remove:
                continue
            cycles = entry.cycles
            validated = entry.validated and revalidate
            if table_patches and instr in table_patches:
                cycles, validated = table_patches[instr], False
            table[instr] = CycleEntry(cycles, validated)
        if table_patches:
            for instr in table_patches:
                if instr not in table and instr not in table_remove:
                    table[instr] = CycleEntry(table_patches[instr], False)
        if table_add:
            for instr, (cycles, validated) in table_add.items():
                table[instr] = CycleEntry(cycles, validated)
        mem_over = {k: overrides.pop(k) for k in list(overrides)
                    if hasattr(MemoryHierarchy, k) and
                    k in MemoryHierarchy.__dataclass_fields__}
        ic_over = {k: overrides.pop(k) for k in list(overrides)
                   if k in Interconnect.__dataclass_fields__}
        memory = dataclasses.replace(self.memory, **mem_over) \
            if mem_over else self.memory
        interconnect = dataclasses.replace(self.interconnect, **ic_over) \
            if ic_over else self.interconnect
        return dataclasses.replace(
            self, name=name, cycle_table=table, memory=memory,
            interconnect=interconnect, **overrides)
