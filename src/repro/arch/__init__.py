"""repro.arch — the unified device-capability layer.

One declarative :class:`DeviceSpec` per accelerator carries everything the
simulator, HLO bridge, roofline and what-if sweeps need: compute topology,
MFMA cycle tables with validation provenance, memory-hierarchy latencies
*and* bandwidths, interconnect, clocks and advertised peaks.

  spec      — the DeviceSpec schema (+ MemoryHierarchy / Interconnect)
  registry  — the device catalog (mi200, mi300, mi300x, tpu_v5e, tpu_v5p)
  overlay   — composable what-if scenario transforms + sweep grids
  select    — instruction-selection policy (best MFMA per dtype)

Consumers: ``repro.core.machine`` (thin execution facade),
``repro.core.isa`` (instruction registry; legacy table views),
``repro.launch.roofline`` (peaks/bandwidths), ``repro.core.whatif``
(overlay sweeps).  To add a device, see ROADMAP.md "Architecture".
"""

from repro.arch.overlay import IDENTITY, Overlay, overlay_grid  # noqa: F401
from repro.arch.registry import (UnknownDeviceError,  # noqa: F401
                                 get_device, list_devices, register_device)
from repro.arch.select import (HLO_DTYPE_TO_IN, best_mfma,  # noqa: F401
                               best_mfma_for_hlo, throughput_ranking)
from repro.arch.spec import (CycleEntry, DeviceSpec,  # noqa: F401
                             Interconnect, MemoryHierarchy)

__all__ = [
    "CycleEntry", "DeviceSpec", "Interconnect", "MemoryHierarchy",
    "Overlay", "IDENTITY", "overlay_grid",
    "UnknownDeviceError", "get_device", "list_devices", "register_device",
    "HLO_DTYPE_TO_IN", "best_mfma", "best_mfma_for_hlo",
    "throughput_ranking",
]
