"""Instruction-selection policy: the best matrix instruction per device.

Which MFMA a GEMM of a given operand dtype should use is a *device*
property (it depends on that device's timing table and supported set), not
an HLO-bridge detail — so the policy that used to live in
``repro.core.hlo_bridge.best_instr`` is owned here and the bridge calls in.

Policy: maximise per-MCE throughput (FLOPs per cycle at the tabled
latency); break ties toward larger tiles, which is what rocBLAS-generated
kernels do in practice.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.arch.spec import DeviceSpec

__all__ = ["HLO_DTYPE_TO_IN", "best_mfma", "best_mfma_for_hlo",
           "throughput_ranking"]

#: HLO/StableHLO element type -> MFMA operand dtype.
HLO_DTYPE_TO_IN: Dict[str, str] = {
    "f64": "fp64", "f32": "fp32", "bf16": "bf16", "f16": "fp16",
    "s8": "i8", "u8": "i8", "f8e4m3fn": "fp8",
}


def _isa():
    from repro.core import isa
    return isa


def best_mfma(spec: DeviceSpec, in_dtype: str, *,
              mfma_scale: float = 1.0) -> Optional[str]:
    """Highest-throughput supported MFMA for an operand dtype, or None."""
    isa = _isa()
    if not spec.has_cycle_table:
        return None
    best, best_key = None, (-1.0, -1)
    for name in spec.supported_instructions():
        inst = isa.lookup(name)
        if inst.in_dtype != in_dtype:
            continue
        cycles = spec.mfma_cycles(name, mfma_scale=mfma_scale)
        # primary: throughput; tie-break: larger tiles (rocBLAS-realistic)
        key = (inst.flops / cycles, inst.macs)
        if key > best_key:
            best, best_key = name, key
    return best


def best_mfma_for_hlo(spec: DeviceSpec, hlo_dtype: str, *,
                      mfma_scale: float = 1.0) -> Optional[str]:
    """`best_mfma` keyed by the HLO element type ("bf16", "f32", ...)."""
    want = HLO_DTYPE_TO_IN.get(hlo_dtype)
    if want is None:
        return None
    return best_mfma(spec, want, mfma_scale=mfma_scale)


def throughput_ranking(spec: DeviceSpec, *, mfma_scale: float = 1.0):
    """All supported instructions sorted by descending throughput —
    the full selection table `best_mfma` picks from (debug/reporting)."""
    isa = _isa()
    rows = []
    for name in spec.supported_instructions():
        inst = isa.lookup(name)
        cycles = spec.mfma_cycles(name, mfma_scale=mfma_scale)
        rows.append((inst.flops / cycles, inst.macs, name, inst.in_dtype))
    rows.sort(reverse=True)
    return [{"name": n, "in_dtype": d, "flops_per_cycle": t, "macs": m}
            for t, m, n, d in rows]
