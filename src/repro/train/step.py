"""jit-able train / eval / serve step builders.

``make_train_step(cfg)`` returns ``step(params, opt_state, batch)`` doing
forward + backward + AdamW — the function every ``train_*`` dry-run cell
lowers.  Gradient all-reduce across data/pod axes is implicit in GSPMD
(batch-sharded loss => reduced grads); the optional int8 pod-axis gradient
compression wraps the grads pytree before the update
(``repro.parallel.compression``).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import loss_fn
from repro.train.optim import OptConfig, adamw_update

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[OptConfig] = None,
                    grad_transform: Optional[Callable] = None,
                    microbatches: Optional[int] = None) -> Callable:
    """``microbatches`` > 1 enables gradient accumulation: the global batch
    is split along dim 0 and scanned, dividing activation memory by the
    microbatch count (grads accumulate in f32).  Defaults to
    ``cfg.microbatches``."""
    opt_cfg = opt_cfg or OptConfig()
    n_micro = microbatches or getattr(cfg, "microbatches", 1)
    acc_dtype = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))

    def loss_grads(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)

    def step(params, opt_state, batch) -> Tuple:
        if n_micro == 1:
            (loss, metrics), grads = loss_grads(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = loss_grads(params, mb)
                acc_g, acc_l, acc_ce, acc_aux = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), acc_g, g)
                return (acc_g, acc_l + l, acc_ce + m["ce"],
                        acc_aux + m["aux"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0, 0.0), mbs)
            inv = 1.0 / n_micro
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, metrics = loss * inv, {"ce": ce * inv, "aux": aux * inv}
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads,
                                                      opt_state)
        out = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out

    return step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def step(params, batch) -> Dict[str, jax.Array]:
        loss, metrics = loss_fn(cfg, params, batch)
        return {"loss": loss, **metrics}
    return step
