"""Fault-tolerant training controller: checkpoint/restart, failure
injection, straggler detection.

At 1000+ nodes the mean time between node failures drops below the job
length, so the control loop — not the step function — owns reliability:

* every step runs inside a recovery boundary; a ``WorkerFailure`` (real
  or injected) triggers restore-from-latest-checkpoint and replay,
* an async :class:`~repro.train.checkpoint.Checkpointer` bounds lost work
  to ``ckpt_every`` steps while overlapping I/O with compute,
* a per-step deadline (EMA x ``straggler_factor``) flags stragglers; the
  mitigation hook defaults to log-and-continue (on real pods: trigger
  hot-spare swap / re-shard, both of which reduce to the elastic-restore
  path this module already exercises).

``TrainController.run`` is deliberately synchronous-SPMD-shaped: the same
loop works under multi-process jax with per-host data shards.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.train.checkpoint import Checkpointer, latest_step, restore

__all__ = ["WorkerFailure", "FailureInjector", "TrainController",
           "StragglerStats"]


class WorkerFailure(RuntimeError):
    """A (simulated) node failure surfaced to the control loop."""


@dataclasses.dataclass
class FailureInjector:
    """Raises WorkerFailure when ``step`` reaches each of ``at_steps``
    (once per entry), simulating node loss."""
    at_steps: Sequence[int] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.at_steps and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerStats:
    ema: float = 0.0
    beta: float = 0.9
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float, factor: float) -> bool:
        if self.ema == 0.0:
            self.ema = dt
            return False
        slow = dt > factor * self.ema
        if slow:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        self.ema = self.beta * self.ema + (1 - self.beta) * dt
        return slow


class TrainController:
    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 50, keep: int = 3,
                 injector: Optional[FailureInjector] = None,
                 straggler_factor: float = 3.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 max_restarts: int = 10):
        self.step_fn = step_fn
        self.ckpt = Checkpointer(ckpt_dir, every=ckpt_every, keep=keep)
        self.injector = injector
        self.stragglers = StragglerStats()
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.max_restarts = max_restarts
        self.restarts = 0
        self.log: List[Dict] = []

    def _restore(self, state, fallback_state=None, fallback_step: int = 0):
        """Restore (params, opt_state) from the latest checkpoint.

        The async save thread must be joined BEFORE probing for the latest
        checkpoint: a save launched a step or two before the failure may
        not have done its atomic rename yet, and probing first would miss
        it.  (Probe-then-wait was the restart-divergence bug: with no
        visible checkpoint the controller "replayed" from the *current*
        warm state at step 0, double-applying updates.)

        With no checkpoint on disk the only correct replay base is the
        state the run started from — ``fallback_state`` at
        ``fallback_step`` — never the current mid-run state.
        """
        self.ckpt.wait()
        step = latest_step(self.ckpt.dir)
        if step is None:
            if fallback_state is None:
                fallback_state = state
            return fallback_state, fallback_step
        restored, step = restore(self.ckpt.dir, state)
        return restored, step

    def run(self, state, data_iter_fn: Callable[[int], Any],
            n_steps: int, start_step: int = 0):
        """Run to ``n_steps``; ``state`` is (params, opt_state);
        ``data_iter_fn(step)`` returns that step's batch (resumable by
        construction).  Returns (state, metrics_log)."""
        step = start_step
        initial_state, initial_step = state, start_step
        while step < n_steps:
            try:
                batch = data_iter_fn(step)
                if self.injector:
                    self.injector.check(step)
                t0 = time.time()
                params, opt_state, metrics = self.step_fn(state[0], state[1],
                                                          batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                state = (params, opt_state)
                if self.stragglers.observe(step, dt, self.straggler_factor):
                    if self.on_straggler:
                        self.on_straggler(step, dt)
                self.log.append({"step": step,
                                 "loss": float(metrics["loss"]), "dt": dt})
                step += 1
                self.ckpt.maybe_save(step, state, extra={"step": step})
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                state, step = self._restore(state, initial_state,
                                            initial_step)
                self.log.append({"step": step, "event": "restart",
                                 "cause": str(e)})
        self.ckpt.maybe_save(step, state, force=True)
        self.ckpt.wait()
        return state, self.log
