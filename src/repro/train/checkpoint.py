"""Mesh-agnostic sharded checkpointing with async save + elastic restore.

Layout: one ``.npy`` per pytree leaf (full logical array) + ``meta.json``
(step, tree manifest).  Because leaves are stored at full logical shape,
a restore may target a *different* mesh / device count than the save —
``restore`` re-shards via ``jax.device_put`` with the target NamedShardings
(elastic scaling: grow or shrink the pod between runs).

Saves run on a background thread (``wait()`` joins before the next save),
overlapping checkpoint I/O with training compute.  ``latest_step`` +
atomic directory rename give crash consistency: a checkpoint is visible
only after its final rename.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else f"#{p.idx}" for p in path)
        out[key or "_root"] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None):
    """Blocking save of ``tree`` at ``step`` (atomic via rename)."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        shape, dtype = list(arr.shape), str(arr.dtype)
        arr = np.ascontiguousarray(arr)  # NB: promotes 0-d to (1,)
        fname = f"{len(manifest):06d}.npy"
        # store raw bits (uintN view): np.save cannot round-trip ml_dtypes
        # like bfloat16; the true dtype/shape live in the manifest
        np.save(tmp / fname, arr.view(np.dtype(f"uint{8 * arr.itemsize}")))
        manifest[key] = {"file": fname, "shape": shape, "dtype": dtype}
    meta = {"step": step, "manifest": manifest, "extra": extra or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, *, step: Optional[int] = None,
            sharding_fn: Optional[Callable] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``sharding_fn(path_key, np_array)`` may return a
    Sharding to re-shard onto the *current* mesh (elastic restore); None
    keeps default placement."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    manifest = meta["manifest"]

    flat_like = _flatten(like)
    missing = set(flat_like) - set(manifest)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    import jax.numpy as jnp
    leaves = {}
    for key in flat_like:
        ent = manifest[key]
        arr = np.load(d / ent["file"]).view(jnp.dtype(ent["dtype"])) \
            .reshape(ent["shape"])
        like_leaf = flat_like[key]
        if tuple(arr.shape) != tuple(like_leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected "
                f"{like_leaf.shape}")
        if arr.dtype != like_leaf.dtype:
            arr = arr.astype(like_leaf.dtype)
        sh = sharding_fn(key, arr) if sharding_fn else None
        leaves[key] = jax.device_put(arr, sh) if sh is not None \
            else jax.device_put(arr)

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in paths:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else f"#{p.idx}" for p in path)
        ordered.append(leaves[key or "_root"])
    return jax.tree_util.tree_unflatten(treedef, ordered), step


class Checkpointer:
    """Async checkpointer: ``maybe_save`` returns immediately; the write
    happens on a worker thread (joined before the next save or on close)."""

    def __init__(self, ckpt_dir: str, *, every: int = 100, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.every = every
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def maybe_save(self, step: int, tree, *, extra=None, force=False):
        if not force and (step == 0 or step % self.every):
            return False
        self.wait()
        # materialise on the main thread (device_get), write on the worker
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            save(self.dir, step, host_tree, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
        return True

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
