"""AdamW with cosine schedule + global-norm clipping, in pure JAX.

Optimizer moments are f32 and inherit the parameters' FSDP/TP sharding, so
per-device optimizer state is params_bytes * 4 / n_devices (ZeRO-equivalent
under full FSDP).  Parameters stay bf16 (no f32 master copy; the f32 `m`
carries the low-order bits' signal — recorded in DESIGN.md numerics).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_schedule",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    def zeros(p):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1-d params (standard practice)."""
    name = ""
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    return name not in ("ln1", "ln2", "lnx", "final_norm", "norm", "scale",
                        "bias", "A_log", "D", "dt_bias", "conv_b", "gate",
                        "kv_norm", "bq", "bk", "bv", "bi", "bo")


def adamw_update(cfg: OptConfig, params, grads,
                 state) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)
    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    m_tree = jax.tree_util.tree_unflatten(treedef, new_m)
    v_tree = jax.tree_util.tree_unflatten(treedef, new_v)
    new_state = {"m": m_tree, "v": v_tree, "step": step + 1}
    return params2, new_state, {"grad_norm": gnorm, "lr": lr}
