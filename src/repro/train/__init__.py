"""Training substrate: optimizer, train step, checkpointing, fault tolerance."""

from repro.train.optim import (OptConfig, init_opt_state, adamw_update,
                               lr_schedule)  # noqa: F401
from repro.train.step import make_train_step, make_eval_step  # noqa: F401
