"""Mamba2 mixer: causal depthwise conv + chunked SSD (state-space duality).

The SSD scan processes the sequence in chunks of ``cfg.ssm.chunk``:
quadratic attention-like work *within* a chunk (MXU-friendly — this is the
part the Pallas ``mamba2_ssd`` kernel tiles for VMEM), linear-cost state
recurrence *across* chunks (lax.scan carry, f32).  O(S) overall — this is
why the SSM/hybrid architectures run the long_500k shape.

Dual execution path: with ``cfg.use_pallas``, :func:`ssd_chunked` routes
through ``repro.kernels.dispatch`` to the ``kernels.mamba2_ssd`` Pallas
kernel (the planner picks the chunk — chunked SSD is exact at any chunk
size — and ragged S is zero-padded with ``dt=0`` identity steps).  On a
mesh the kernel runs under ``shard_map`` with batch/heads sharded per
the logical-axis rules (the single B/C group broadcasts).  A carried
initial state or unplannable (local) shapes fall back to the XLA
chunked scan below with a logged reason.

Shapes: x (B,S,nh,hd); B/C (B,S,G,ds) shared per group; dt (B,S,nh);
state carry (B,nh,hd,ds).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense
from repro.parallel.api import current_mesh, shard

__all__ = ["init_ssm", "ssm_train", "ssm_decode", "init_ssm_cache",
           "ssd_chunked", "ssd_step", "d_inner_of"]


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = d_inner_of(cfg)
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def init_ssm(cfg: ModelConfig, key) -> Dict:
    s = cfg.ssm
    D = cfg.d_model
    d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    dt = cdtype(cfg)
    sc = 1.0 / math.sqrt(D)
    return {
        # order: [z, x, B, C, dt]
        "in_proj": jax.random.normal(ks[0], (D, 2 * d_in + 2 * s.n_groups
                                             * s.d_state + nh), dt) * sc,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_dim), dt) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), dt),
        "out_proj": jax.random.normal(ks[2], (d_in, D), dt)
                    * (1.0 / math.sqrt(d_in) / math.sqrt(max(1, cfg.n_layers))),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_in, nh, _ = _dims(cfg)
    gs = s.n_groups * s.d_state
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gs, 2 * d_in + 2 * gs], axis=-1)
    return z, xs, Bm, Cm, dt


def _conv_train(w, x: jax.Array, d_conv: int) -> jax.Array:
    """Causal depthwise conv over (B, S, C): sum of shifted taps."""
    pads = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for tau in range(d_conv):
        y = y + pads[:, tau:tau + S, :].astype(jnp.float32) \
            * w["conv_w"][tau].astype(jnp.float32)
    y = y + w["conv_b"].astype(jnp.float32)
    return jax.nn.silu(y).astype(x.dtype)


def _ssd_kernel_path(x, dt, A, Bm, Cm, h0,
                     device=None) -> Optional[Tuple[jax.Array,
                                                    jax.Array]]:
    """Try the Pallas ``mamba2_ssd`` kernel; ``None`` -> XLA scan.

    The planner picks the chunk (chunked SSD is exact at any chunk size,
    pinned by ``test_property_chunk_invariance``); ragged S is padded
    with ``dt=0`` identity steps, so the final state stays exact.
    """
    B, S, nh, hd = x.shape
    ds = Bm.shape[3]
    if h0 is not None:
        kdispatch.fallback(
            "mamba2_ssd", "carried initial state h0 is not part of the "
                          "kernel contract (prefill-continuation path)")
        return None
    dec = kdispatch.decide(
        "mamba2_ssd", {"B": B, "S": S, "nh": nh, "hd": hd, "ds": ds,
                       "G": Bm.shape[2]},
        dtype=x.dtype, device=device, sharded=current_mesh() is not None)
    if not dec.use_kernel:
        return None
    return kops.mamba2_ssd(x, dt, A, Bm, Cm,
                           plan=None if dec.sharded else dec.plan,
                           device=device, pad=True, sharded=dec.sharded)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: jax.Array = None, *,
                use_pallas: bool = False,
                pallas_device=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x (B,S,nh,hd); dt (B,S,nh) f32 (post-softplus); A (nh,) f32 (negative);
    Bm/Cm (B,S,G,ds).  Returns y (B,S,nh,hd) and final state (B,nh,hd,ds) f32.
    With ``use_pallas`` the Pallas kernel is tried first (dispatch falls
    back here when it cannot support the op).
    """
    if use_pallas:
        out = _ssd_kernel_path(x, dt, A, Bm, Cm, h0, device=pallas_device)
        if out is not None:
            return out
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    S_orig = S
    if S % chunk:
        # zero-pad to a chunk multiple: dt=0 makes padded steps identity
        # state updates (exp(0)=1 decay, zero input contribution)
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    hpg = nh // G  # heads per group

    xc = x.reshape(B, nc, chunk, nh, hd)
    dtc = dt.reshape(B, nc, chunk, nh)
    Bc = Bm.reshape(B, nc, chunk, G, ds)
    Cc = Cm.reshape(B, nc, chunk, G, ds)

    def body(h_prev, inp):
        xq, dtq, Bq, Cq = inp                      # (B,chunk,...)
        dA = dtq * A                               # (B,Q,nh) log-decay, <= 0
        cum = jnp.cumsum(dA, axis=1)               # (B,Q,nh)
        total = cum[:, -1]                         # (B,nh)
        # intra-chunk: scores per group, decay per head
        scores = jnp.einsum("bigs,bjgs->bijg", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))          # (B,Q,Q,G)
        Lg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,nh)
        i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        causal = (j <= i)[None, :, :, None]
        # mask BEFORE exp: masked entries have Lg > 0 (anti-causal decay
        # sums), whose exp overflows and NaNs the backward via inf * 0
        W = jnp.exp(jnp.where(causal, Lg, -1e30))             # (B,Q,Q,nh)
        W = W * dtq[:, None, :, :]                            # x dt_j
        W = W * scores.repeat(hpg, axis=-1) if G > 1 else \
            W * scores[..., 0][..., None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        Ch = Cq.repeat(hpg, axis=2) if G > 1 else \
            jnp.broadcast_to(Cq, (B, chunk, nh, ds))
        y_inter = jnp.einsum("bihs,bhps->bihp", Ch.astype(jnp.float32), h_prev)
        y_inter = y_inter * jnp.exp(cum)[..., None]
        # state update
        decay_j = jnp.exp(total[:, None] - cum)               # (B,Q,nh)
        Bh = Bq.repeat(hpg, axis=2) if G > 1 else \
            jnp.broadcast_to(Bq, (B, chunk, nh, ds))
        dx = (dtq * decay_j)[..., None] * xq.astype(jnp.float32)  # (B,Q,nh,hd)
        h_new = jnp.exp(total)[..., None, None] * h_prev + \
            jnp.einsum("bjhp,bjhs->bhps", dx, Bh.astype(jnp.float32))
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    h_final, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, hd)
    return y[:, :S_orig], h_final


def ssd_step(x, dt, A, Bm, Cm, h):
    """Single-token SSD update.  x (B,nh,hd); dt (B,nh); Bm/Cm (B,G,ds);
    h (B,nh,hd,ds) f32.  Returns (y, h_new)."""
    B, nh, hd = x.shape
    G, ds = Bm.shape[1], Bm.shape[2]
    hpg = nh // G
    da = jnp.exp(dt * A)                                       # (B,nh)
    Bh = Bm.repeat(hpg, axis=1) if G > 1 else \
        jnp.broadcast_to(Bm, (B, nh, ds))
    Ch = Cm.repeat(hpg, axis=1) if G > 1 else \
        jnp.broadcast_to(Cm, (B, nh, ds))
    h_new = da[..., None, None] * h + \
        jnp.einsum("bhp,bhs->bhps", (dt[..., None] * x.astype(jnp.float32)),
                   Bh.astype(jnp.float32))
    y = jnp.einsum("bhs,bhps->bhp", Ch.astype(jnp.float32), h_new)
    return y.astype(x.dtype), h_new


def _gated_norm(cfg: ModelConfig, w_norm, y: jax.Array, z: jax.Array):
    """Mamba2 gated RMSNorm: norm(y * silu(z)) in f32."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + cfg.norm_eps)
            * w_norm.astype(jnp.float32)).astype(y.dtype)


def ssm_train(cfg: ModelConfig, w, x: jax.Array) -> jax.Array:
    s = cfg.ssm
    B, S, D = x.shape
    d_in, nh, conv_dim = _dims(cfg)
    zxbcdt = dense(x, w["in_proj"])
    z, xs, Bm, Cm, dtr = _split_proj(cfg, zxbcdt)
    xbc = _conv_train(w, jnp.concatenate([xs, Bm, Cm], axis=-1), s.d_conv)
    xbc = shard(xbc, "batch", None, "tp")
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    # SSD compute shards the head dim (heads-per-device x full sequence);
    # sequence sharding would make the chunk scan's dynamic slices collective
    xh = shard(xs.reshape(B, S, nh, s.head_dim), "batch", None, "heads", None)
    Bg = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cg = Cm.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"])
    dt = shard(dt, "batch", None, "heads")
    A = -jnp.exp(w["A_log"])
    y, _ = ssd_chunked(xh, dt, A, Bg, Cg, s.chunk,
                       use_pallas=cfg.use_pallas,
                       pallas_device=cfg.pallas_device)
    y = y + w["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = shard(y.reshape(B, S, d_in).astype(x.dtype), "batch", None, "tp")
    return dense(_gated_norm(cfg, w["norm"], y, z), w["out_proj"])


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None) -> Dict:
    s = cfg.ssm
    d_in, nh, conv_dim = _dims(cfg)
    dt = dtype or cdtype(cfg)
    return {"conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dt),
            "state": jnp.zeros((batch, nh, s.head_dim, s.d_state),
                               jnp.float32)}


def ssm_decode(cfg: ModelConfig, w, x: jax.Array, cache: Dict,
               pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, D) -> (y (B,1,D), new cache).  O(1) per token."""
    del pos  # state summarises the context; no positional input
    s = cfg.ssm
    B = x.shape[0]
    d_in, nh, conv_dim = _dims(cfg)
    zxbcdt = dense(x[:, 0], w["in_proj"])            # (B, ...)
    z, xs, Bm, Cm, dtr = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)
    conv = jnp.einsum("btc,tc->bc", window.astype(jnp.float32),
                      w["conv_w"].astype(jnp.float32)) \
        + w["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    xh = xs.reshape(B, nh, s.head_dim)
    Bg = Bm.reshape(B, s.n_groups, s.d_state)
    Cg = Cm.reshape(B, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"])
    A = -jnp.exp(w["A_log"])
    y, h_new = ssd_step(xh, dt, A, Bg, Cg, cache["state"])
    y = y + (w["D"].astype(jnp.float32)[:, None]
             * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B, 1, d_in)
    out = dense(_gated_norm(cfg, w["norm"], y, z[:, None]), w["out_proj"])
    return out, {"conv": window[:, 1:], "state": h_new}
