"""Attention mixers: GQA self-attention, MLA (DeepSeek-V2), cross-attention.

Each mixer exposes:
  init_*      -> weight tree
  *_train     -> full-sequence causal (or cross) attention
  *_decode    -> single-token step against a KV cache (dynamic_update_slice)

Memory/sharding design (dry-run-validated on the (16,16) production mesh):

* Long sequences use a blockwise online-softmax attention (`_flash_sdpa`,
  a lax.scan over KV blocks) so peak logits memory is O(S x block), never
  O(S x T).  The Pallas `flash_attention` kernel implements the same
  contract for real TPUs; this XLA formulation is the GSPMD-shardable
  reference the dry-run compiles.
* Dual execution path: with ``cfg.use_pallas`` the :func:`attention`
  entry point routes through ``repro.kernels.dispatch`` to the Pallas
  kernels — ``kernels.flash_attention`` for the train/prefill step and
  ``kernels.decode_attention`` for the single-token KV-cache step —
  padding ragged (non-128-multiple) shapes via the ops-layer
  pad/mask/slice path.  Under an active mesh the dispatcher plans
  against the *per-shard* shapes (batch/heads shard via the logical-axis
  rules) and the kernels execute inside ``shard_map``, so
  ``use_pallas=True`` survives ``launch.mesh`` execution.  Anything the
  kernel contract cannot express (MLA's ``v_head_dim != qk_dim``, a
  custom softmax scale, unplannable local shards) falls back to the XLA
  reference below with a logged reason, so the flag is always safe to
  set.
* Query heads are TP-sharded when `n_heads` divides the model axis
  (mistral 32H, internlm2 48H, llama-vision 64H, ...).  When they do not
  (yi 56H, qwen2 28H, whisper 8H), we instead shard the *query sequence*
  over the model axis ("seq_tp") — attention math is position-parallel, so
  this is exact, and it keeps per-device logits bounded.
* Decode KV caches shard batch over "batch" and sequence over "kv_seq"
  (model, then data when free — long_500k with batch 1 gets 256-way
  sequence sharding).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense, mm, norm_apply, rope
from repro.parallel.api import current_mesh, shard

__all__ = ["init_attn", "attn_train", "attn_decode", "attn_decode_paged",
           "attn_prefill_paged", "init_mla", "mla_train", "mla_decode",
           "init_cross", "cross_train", "cross_decode", "init_attn_cache",
           "init_mla_cache", "sdpa", "attention"]

_FLASH_BLOCK = 512
_FLASH_MIN_T = 2048     # plain sdpa below this KV length
_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _heads_divisible(n_heads: int) -> bool:
    mesh = current_mesh()
    if mesh is None:
        return True
    return n_heads % mesh.shape.get("model", 1) == 0


def _shard_q(q: jax.Array) -> jax.Array:
    """(B, S, H, hd): heads-TP when divisible, else sequence-TP."""
    if _heads_divisible(q.shape[2]):
        return shard(q, "batch", None, "heads", None)
    return shard(q, "batch", "seq_tp", None, None)


def _shard_kv(k: jax.Array) -> jax.Array:
    """(B, T, KV, hd) train-time K/V: batch-sharded, heads when divisible."""
    if _heads_divisible(k.shape[2]):
        return shard(k, "batch", None, "heads", None)
    return shard(k, "batch", None, None, None)


def _kv_len_bc(kv_len) -> jax.Array:
    """Normalise ``kv_len`` for (B, H, S, T) logits masks: a scalar
    broadcasts as-is; a per-request (B,) vector gains (1, 1, 1) tails."""
    kl = jnp.asarray(kv_len, jnp.int32)
    return kl[:, None, None, None] if kl.ndim == 1 else kl


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         scale: float, kv_len: Optional[jax.Array] = None,
         q_offset=0) -> jax.Array:
    """Plain SDPA over full heads.  q: (B,S,H,hd); k/v: (B,T,H,hd).
    ``kv_len`` is an int32 scalar or a per-request (B,) vector;
    ``q_offset`` (global index of q's first row for the causal mask) is
    an int scalar or a per-request (B,) vector — the paged continuation
    prefill decodes chunks sitting at a different offset per request."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    logits = mm("bshd,bthd->bhst", q, k) * scale
    if causal and S > 1:
        j = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
        qo = jnp.asarray(q_offset, jnp.int32)
        if qo.ndim == 1:
            i = (jax.lax.broadcasted_iota(jnp.int32, (S, T), 0)[None, None]
                 + qo[:, None, None, None])
            logits = jnp.where(j[None, None] <= i, logits, _NEG_INF)
        else:
            i = jax.lax.broadcasted_iota(jnp.int32, (S, T), 0) + q_offset
            logits = jnp.where((j <= i)[None, None], logits, _NEG_INF)
    if kv_len is not None:
        t = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, T), 3)
        logits = jnp.where(t < _kv_len_bc(kv_len), logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return mm("bhst,bthd->bshd", probs, v, out_dtype=q.dtype)


def _flash_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                scale: float, kv_len: Optional[jax.Array] = None,
                q_offset: int = 0, block: int = _FLASH_BLOCK) -> jax.Array:
    """Blockwise online-softmax attention (lax.scan over KV blocks).

    Peak transient is (B,H,S,block) f32 instead of (B,H,S,T).  Exact (same
    contract as sdpa).  k/v may carry KV < H heads: they are expanded to H
    per BLOCK inside the body, so the full K/V tensors are read from HBM at
    KV-head width (§Perf iteration: the pre-expanded form read G x the
    bytes).  ``q_offset``: global row index of q's first position (causal
    triangle splitting).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if T % block:
        pad = block - T % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.asarray(T, jnp.int32) if kv_len is None else kv_len
        T = T + pad
    if kv_len is not None:
        kv_len = _kv_len_bc(kv_len)        # (B,) vectors mask per request
    nb = T // block
    qf = (q.astype(jnp.float32) * scale)

    def body(carry, ib):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, ib * block, block, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ib * block, block, 1)
        if G > 1:  # expand grouped KV heads per block (fusion-local)
            kb = jnp.repeat(kb, G, axis=2)
            vb = jnp.repeat(vb, G, axis=2)
        s = jnp.einsum("bshd,bthd->bhst", qf, kb.astype(jnp.float32))
        col = (jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, block), 3)
               + ib * block)
        if causal and S > 1:
            row = jax.lax.broadcasted_iota(jnp.int32, (1, 1, S, 1), 2) \
                + q_offset
            s = jnp.where(col <= row, s, _NEG_INF)
        if kv_len is not None:
            s = jnp.where(col < kv_len, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    hd_v = v.shape[-1]
    m0 = jnp.full((B, H, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd_v), jnp.float32)
    # checkpoint the block body: scan's backward otherwise stacks the
    # (B,H,S,block) f32 score/prob tensors for every block (tens of GiB at
    # 32k); recomputing them leaves only the O(B*H*S) carries resident.
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # b h s d -> b s h d


def _attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, scale: float,
                      kv_len: Optional[jax.Array],
                      device: Optional[str] = None) -> Optional[jax.Array]:
    """Try the Pallas kernel path; ``None`` means "use the XLA reference".

    Dispatch happens at trace time on static shapes: ``flash_attention``
    for S > 1 (train/prefill), ``decode_attention`` for the S == 1
    KV-cache step.  Ragged shapes run via the ops-layer ``pad=True``
    path (padded keys are ``kv_len``-masked, padded query rows sliced).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    kernel = "flash_attention" if S > 1 else "decode_attention"
    if v.shape[-1] != hd:
        kdispatch.fallback(
            kernel, f"v head dim {v.shape[-1]} != query head dim {hd} "
                    "(MLA-style asymmetric heads)")
        return None
    if abs(scale * math.sqrt(hd) - 1.0) > 1e-6:
        kdispatch.fallback(
            kernel, f"custom softmax scale {scale:g} != 1/sqrt(hd)")
        return None
    sharded = current_mesh() is not None
    if S > 1:
        dec = kdispatch.decide(
            "flash_attention",
            {"B": B, "S": S, "T": T, "H": H, "KV": KV, "hd": hd},
            dtype=q.dtype, device=device, sharded=sharded)
        if not dec.use_kernel:
            return None
        # a sharded Decision's plan is per-shard: the shard_map body
        # re-resolves it on local shapes, so pass device, not plan
        return kops.flash_attention(q, k, v, causal=causal, kv_len=kv_len,
                                    plan=None if dec.sharded else dec.plan,
                                    device=device, pad=True,
                                    sharded=dec.sharded)
    dec = kdispatch.decide(
        "decode_attention", {"B": B, "T": T, "H": H, "KV": KV, "hd": hd},
        dtype=q.dtype, device=device, sharded=sharded)
    if not dec.use_kernel:
        return None
    kl = jnp.asarray(T, jnp.int32) if kv_len is None else kv_len
    return kops.decode_attention(q[:, 0], k, v, kl,
                                 plan=None if dec.sharded else dec.plan,
                                 device=device, pad=True,
                                 sharded=dec.sharded)[:, None]


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
              scale: Optional[float] = None,
              kv_len: Optional[jax.Array] = None,
              use_pallas: bool = False,
              pallas_device: Optional[str] = None) -> jax.Array:
    """Grouped attention entry point.  q: (B,S,H,hd); k/v: (B,T,KV,hd).

    KV heads are expanded to the full H before the attention math (a
    (KV, G) reshape would break head sharding whenever KV < the model
    axis — yi/jamba/qwen3 all hit that); GQA's memory win lives in the
    KV *cache*, not the transient compute tensors.  With ``use_pallas``
    the Pallas kernels are tried first (``repro.kernels.dispatch`` falls
    back here when they cannot support the op).  The XLA reference
    dispatches to the blockwise path for long KV (training/prefill);
    plain einsum otherwise (short KV, and decode where S == 1 keeps
    logits tiny).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if use_pallas:
        out = _attention_kernel(q, k, v, causal=causal, scale=scale,
                                kv_len=kv_len, device=pallas_device)
        if out is not None:
            return out
    use_flash = T >= _FLASH_MIN_T and S > 1
    if G > 1 and not use_flash:
        k = jnp.repeat(k, G, axis=2)   # flash expands per block instead
        v = jnp.repeat(v, G, axis=2)
    if S > 1:
        # train/prefill: heads-TP when divisible, else batch-only (the
        # blockwise scan slices T, so T must stay unsharded here)
        if _heads_divisible(k.shape[2]):
            k = shard(k, "batch", None, "heads", None)
            v = shard(v, "batch", None, "heads", None)
        else:
            k = shard(k, "batch", None, None, None)
            v = shard(v, "batch", None, None, None)
    # decode (S == 1): k/v keep the cache's ("batch","kv_seq") sharding —
    # XLA reduces the softmax over the sequence-sharded axis in place
    if use_flash:
        if causal and S == T and kv_len is None and S >= 2 * _FLASH_MIN_T:
            out = _causal_split_flash(q, k, v, scale=scale, depth=2)
        else:
            out = _flash_sdpa(q, k, v, causal=causal, scale=scale,
                              kv_len=kv_len)
    else:
        out = sdpa(q, k, v, causal=causal, scale=scale, kv_len=kv_len)
    return out


def _causal_split_flash(q, k, v, *, scale: float, depth: int,
                        q_offset: int = 0) -> jax.Array:
    """Causal triangle splitting (§Perf): a uniform KV scan executes every
    block, including the ~half that are fully masked.  Splitting q in two —
    the low half attends only the low half of K/V, the high half scans all
    of it — removes 25% of block work per level (31% at depth 2), exactly;
    the Pallas kernel gets the same effect from its pl.when block skip.
    """
    S = q.shape[1]
    if depth == 0 or S < 2 * _FLASH_MIN_T or S % 2:
        return _flash_sdpa(q, k, v, causal=True, scale=scale,
                           q_offset=q_offset)
    h = S // 2
    lo = _causal_split_flash(q[:, :h], k[:, :h], v[:, :h], scale=scale,
                             depth=depth - 1, q_offset=q_offset)
    hi = _flash_sdpa(q[:, h:], k, v, causal=True, scale=scale,
                     q_offset=q_offset + h)
    return jnp.concatenate([lo, hi], axis=1)


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = cdtype(cfg)
    s = 1.0 / math.sqrt(D)
    w = {
        "wq": jax.random.normal(k1, (D, H * hd), dt) * s,
        "wk": jax.random.normal(k2, (D, KV * hd), dt) * s,
        "wv": jax.random.normal(k3, (D, KV * hd), dt) * s,
        "wo": jax.random.normal(k4, (H * hd, D), dt) * (s / math.sqrt(max(1, cfg.n_layers))),
    }
    if cfg.qkv_bias:
        w["bq"] = jnp.zeros((H * hd,), dt)
        w["bk"] = jnp.zeros((KV * hd,), dt)
        w["bv"] = jnp.zeros((KV * hd,), dt)
    return w


def _qkv(cfg: ModelConfig, w, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = dense(x, w["wq"], w.get("bq")).reshape(B, S, H, hd)
    k = dense(x, w["wk"], w.get("bk")).reshape(B, S, KV, hd)
    v = dense(x, w["wv"], w.get("bv")).reshape(B, S, KV, hd)
    if cfg.pos_embed == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return _shard_q(q), _shard_kv(k), _shard_kv(v)


def attn_train(cfg: ModelConfig, w, x: jax.Array,
               positions: jax.Array, *, causal: bool = True) -> jax.Array:
    B, S, D = x.shape
    q, k, v = _qkv(cfg, w, x, positions)
    out = attention(q, k, v, causal=causal, use_pallas=cfg.use_pallas,
                    pallas_device=cfg.pallas_device)
    out = _shard_q(out)
    return dense(out.reshape(B, S, cfg.n_heads * cfg.hd), w["wo"])


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=None) -> Dict:
    dt = dtype or cdtype(cfg)
    shp = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}


def _cache_spec():
    return ("batch", "kv_seq", None, None)


def attn_decode(cfg: ModelConfig, w, x: jax.Array, cache: Dict,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, D); pos: scalar int32 — index of the new token."""
    B, S, D = x.shape
    positions = jnp.zeros((S,), jnp.int32) + pos
    q, k_new, v_new = _qkv(cfg, w, x, positions)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    k = shard(k, *_cache_spec())
    v = shard(v, *_cache_spec())
    out = attention(q, k, v, causal=False, kv_len=pos + 1,
                    use_pallas=cfg.use_pallas,
                    pallas_device=cfg.pallas_device)
    y = dense(out.reshape(B, S, cfg.n_heads * cfg.hd), w["wo"])
    return y, {"k": k, "v": v}


def _paged_attention_kernel(q, k_pool, v_pool, tables, kv_len, *,
                            device=None):
    """Try the paged Pallas kernel; ``None`` means "gather + reference"."""
    B, S, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    NB = tables.shape[1]
    dec = kdispatch.decide(
        "paged_decode_attention",
        {"B": B, "T": NB * page, "H": H, "KV": KV, "hd": hd, "page": page},
        dtype=q.dtype, device=device, sharded=current_mesh() is not None)
    if not dec.use_kernel:
        return None
    return kops.paged_decode_attention(q[:, 0], k_pool, v_pool, tables,
                                       kv_len, plan=dec.plan)[:, None]


def attn_decode_paged(cfg: ModelConfig, w, x: jax.Array, cache: Dict,
                      block_tables: jax.Array,
                      lens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One continuous-batching decode step against the shared KV pool.

    x: (B, 1, D) — each row is a *different* request's pending token;
    cache ``{"k", "v"}``: (P, page, KV, hd) block pools; block_tables:
    (B, NB) int32 physical block ids (unused tail slots must point at the
    engine's reserved null block 0); lens: (B,) int32 tokens already in
    each request's cache — both the new token's write position and its
    RoPE position.  Unlike :func:`attn_decode` there is no per-batch
    ``pos`` scalar: every request sits at its own offset.
    """
    B, S, D = x.shape
    lens = jnp.asarray(lens, jnp.int32)
    q, k_new, v_new = _qkv(cfg, w, x, lens[:, None])
    P, page, KV, hd = cache["k"].shape
    tables = jnp.asarray(block_tables, jnp.int32)
    # scatter the new K/V row into pool block table[b, lens//page] at
    # row lens%page — requests own disjoint blocks, so rows never collide
    # (idle engine slots all hit the null block, whose content is never
    # attended unmasked)
    slot = jnp.take_along_axis(tables, (lens // page)[:, None], axis=1)[:, 0]
    idx = slot * page + lens % page
    k = cache["k"].reshape(P * page, KV, hd).at[idx].set(
        k_new[:, 0]).reshape(P, page, KV, hd)
    v = cache["v"].reshape(P * page, KV, hd).at[idx].set(
        v_new[:, 0]).reshape(P, page, KV, hd)
    kv_len = lens + 1
    out = None
    if cfg.use_pallas:
        out = _paged_attention_kernel(q, k, v, tables, kv_len,
                                      device=cfg.pallas_device)
    if out is None:
        # gather the tables into a dense (B, NB*page, KV, hd) cache and
        # run the plain decode path (which may still pick the contiguous
        # kernel when cfg.use_pallas is set)
        kd = k[tables].reshape(B, -1, KV, hd)
        vd = v[tables].reshape(B, -1, KV, hd)
        out = attention(q, kd, vd, causal=False, kv_len=kv_len,
                        use_pallas=cfg.use_pallas,
                        pallas_device=cfg.pallas_device)
    y = dense(out.reshape(B, S, cfg.n_heads * cfg.hd), w["wo"])
    return y, {"k": k, "v": v}


def attn_prefill_paged(cfg: ModelConfig, w, x: jax.Array, cache: Dict,
                       block_tables: jax.Array, lens: jax.Array,
                       n_valid: jax.Array, *,
                       aligned: bool = False) -> Tuple[jax.Array, Dict]:
    """One continuation-prefill chunk against the shared KV pool.

    x: (B, C, D) — a fixed-size chunk of each request's *uncached* prompt
    suffix, right-padded past ``n_valid``; cache ``{"k", "v"}``: the
    (P, page, KV, hd) block pools; block_tables (B, NB) / lens (B,) as in
    :func:`attn_decode_paged` — ``lens`` is the number of tokens already
    in the cache, i.e. the chunk's global start position (both its write
    offset and its RoPE base).  The chunk's K/V rows are written into
    the pool first, then attention reads the whole table back as a dense
    cache — the prefix written by earlier chunks or *shared with other
    requests via the block table* is attended exactly like self-owned
    rows.  The causal mask runs at per-request global offsets, so chunked
    prefill computes the same masked logits full prefill would.

    ``aligned=True`` is a caller promise that B == 1 and every chunk
    lies inside a single block — the engine guarantees this whenever the
    chunk size divides the page, since chunks then start at multiples of
    C past a page boundary.  The write collapses to one contiguous
    ``dynamic_update_slice`` instead of a computed-index row scatter
    (~4.5x cheaper on XLA:CPU), bitwise-identical for every row that is
    ever read: padded rows past ``n_valid`` land just past the valid
    prefix inside the request's own last block (instead of the null
    block), where kv_len masks them this call and decode overwrites
    position ``s`` before any later read reaches it.
    """
    B, C, D = x.shape
    lens = jnp.asarray(lens, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)
    positions = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _qkv(cfg, w, x, positions)
    P, page, KV, hd = cache["k"].shape
    tables = jnp.asarray(block_tables, jnp.int32)
    if aligned and B == 1 and C <= page:
        # single-block chunk: one contiguous C-row window in the flat pool
        start = tables[0, lens[0] // page] * page + lens[0] % page
        k = jax.lax.dynamic_update_slice(
            cache["k"].reshape(P * page, KV, hd),
            k_new.reshape(C, KV, hd), (start, 0, 0)).reshape(P, page, KV, hd)
        v = jax.lax.dynamic_update_slice(
            cache["v"].reshape(P * page, KV, hd),
            v_new.reshape(C, KV, hd), (start, 0, 0)).reshape(P, page, KV, hd)
    else:
        # scatter the chunk's K/V rows at their global positions; rows
        # past n_valid (chunk padding) are redirected to the null block,
        # whose content is never attended unmasked
        blk = jnp.take_along_axis(tables, positions // page, axis=1)
        idx = blk * page + positions % page
        row = jnp.arange(C, dtype=jnp.int32)[None, :]
        idx = jnp.where(row < nv[:, None], idx, row % page)
        k = cache["k"].reshape(P * page, KV, hd).at[idx.reshape(-1)].set(
            k_new.reshape(B * C, KV, hd)).reshape(P, page, KV, hd)
        v = cache["v"].reshape(P * page, KV, hd).at[idx.reshape(-1)].set(
            v_new.reshape(B * C, KV, hd)).reshape(P, page, KV, hd)
    # read path: gather the table into a dense (B, NB*page, KV, hd) cache
    # (exactly the decode tick's read) and attend causally at each
    # request's own offset.  kv_len additionally masks rows the causal
    # mask cannot see when C == 1; for valid rows it masks a subset of
    # what causality already does, so the attended logits are unchanged.
    kd = k[tables].reshape(B, -1, KV, hd)
    vd = v[tables].reshape(B, -1, KV, hd)
    G = cfg.n_heads // KV
    if G > 1:
        kd = jnp.repeat(kd, G, axis=2)
        vd = jnp.repeat(vd, G, axis=2)
    out = sdpa(q, kd, vd, causal=True, scale=1.0 / math.sqrt(hd),
               kv_len=lens + nv, q_offset=lens)
    y = dense(out.reshape(B, C, cfg.n_heads * cfg.hd), w["wo"])
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2): the KV cache stores only
# the compressed latent c_kv (+ decoupled RoPE key), up-projected per use.
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key) -> Dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 5)
    dt = cdtype(cfg)
    s = 1.0 / math.sqrt(D)
    sl = 1.0 / math.sqrt(m.kv_lora_rank)
    return {
        "wq": jax.random.normal(ks[0], (D, H * qk), dt) * s,
        "w_dkv": jax.random.normal(ks[1], (D, m.kv_lora_rank + m.qk_rope_dim), dt) * s,
        "w_uk": jax.random.normal(ks[2], (m.kv_lora_rank, H * m.qk_nope_dim), dt) * sl,
        "w_uv": jax.random.normal(ks[3], (m.kv_lora_rank, H * m.v_head_dim), dt) * sl,
        "wo": jax.random.normal(ks[4], (H * m.v_head_dim, D), dt)
              * (s / math.sqrt(max(1, cfg.n_layers))),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
    }


def _mla_latent(cfg: ModelConfig, w, x, positions):
    m = cfg.mla
    dkv = dense(x, w["w_dkv"])
    c_kv, k_pe = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    c_kv = norm_apply(cfg, w["kv_norm"], c_kv)
    k_pe = rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def _mla_attend(cfg: ModelConfig, w, x, c_kv, k_rope, positions, *,
                causal, kv_len=None):
    m = cfg.mla
    B, S = x.shape[:2]
    T, H = c_kv.shape[1], cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    q = dense(x, w["wq"]).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_nope = dense(c_kv, w["w_uk"]).reshape(B, T, H, m.qk_nope_dim)
    v = dense(c_kv, w["w_uv"]).reshape(B, T, H, m.v_head_dim)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, m.qk_rope_dim))
    q_full = _shard_q(jnp.concatenate([q_nope, q_rope], axis=-1))
    k_full = _shard_kv(jnp.concatenate([k_nope, k_rope_h], axis=-1))
    v = _shard_kv(v)
    out = attention(q_full, k_full, v, causal=causal,
                    scale=1.0 / math.sqrt(qk), kv_len=kv_len,
                    use_pallas=cfg.use_pallas,
                    pallas_device=cfg.pallas_device)
    return dense(out.reshape(B, S, H * m.v_head_dim), w["wo"])


def mla_train(cfg: ModelConfig, w, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    c_kv, k_rope = _mla_latent(cfg, w, x, positions)
    return _mla_attend(cfg, w, x, c_kv, k_rope, positions, causal=True)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None) -> Dict:
    dt = dtype or cdtype(cfg)
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, max_len, m.qk_rope_dim), dt)}


def mla_decode(cfg: ModelConfig, w, x: jax.Array, cache: Dict,
               pos: jax.Array) -> Tuple[jax.Array, Dict]:
    B, S, D = x.shape
    positions = jnp.zeros((S,), jnp.int32) + pos
    c_new, kr_new = _mla_latent(cfg, w, x, positions)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_new, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], kr_new, (0, pos, 0))
    ckv = shard(ckv, "batch", "kv_seq", None)
    krope = shard(krope, "batch", "kv_seq", None)
    y = _mla_attend(cfg, w, x, ckv, krope, positions, causal=False,
                    kv_len=pos + 1)
    return y, {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# Cross-attention (VLM media layers; whisper decoder)
# ---------------------------------------------------------------------------

init_cross = init_attn  # same weight structure, no biases used


def cross_kv(cfg: ModelConfig, w, media: jax.Array):
    """Precompute K/V from media/encoder embeddings (B, M, D)."""
    B, M, _ = media.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = dense(media, w["wk"]).reshape(B, M, KV, hd)
    v = dense(media, w["wv"]).reshape(B, M, KV, hd)
    return _shard_kv(k), _shard_kv(v)


def cross_train(cfg: ModelConfig, w, x: jax.Array,
                media: jax.Array) -> jax.Array:
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = _shard_q(dense(x, w["wq"]).reshape(B, S, H, hd))
    k, v = cross_kv(cfg, w, media)
    out = attention(q, k, v, causal=False, use_pallas=cfg.use_pallas,
                    pallas_device=cfg.pallas_device)
    return dense(out.reshape(B, S, H * hd), w["wo"])


def cross_decode(cfg: ModelConfig, w, x: jax.Array,
                 kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decode-time cross-attn against precomputed media K/V."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = dense(x, w["wq"]).reshape(B, S, H, hd)
    out = attention(q, kv[0], kv[1], causal=False,
                    use_pallas=cfg.use_pallas,
                    pallas_device=cfg.pallas_device)
    return dense(out.reshape(B, S, H * hd), w["wo"])
