"""Top-level LM assembly: init / forward / loss / prefill / decode.

The layer stack is executed as ``lax.scan`` over *periods* (see blocks.py)
with per-slot weight stacks, wrapped in ``jax.checkpoint`` per the config's
remat policy.  The same code path serves:

  train_step   forward(mode="train") -> logits + aux -> CE loss
  prefill      forward(mode="prefill") -> logits + full KV/state cache
  decode_step  single token against the cache (the serve_step the
               decode_32k / long_500k shapes lower)

Encoder-decoder (whisper) runs the encoder stack first and feeds its output
as the decoder's cross-attention media.  Modality frontends are STUBS per
the assignment: inputs are precomputed frame/patch embeddings.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import (Sig, apply_layer, apply_layer_paged,
                                 apply_layer_prefill_paged, init_layer,
                                 init_layer_cache, init_norm, layer_sigs,
                                 schedule)
from repro.models.config import ModelConfig
from repro.models.layers import cdtype, embed_apply, norm_apply, unembed_apply
from repro.parallel.api import shard

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "paged_decode_step", "paged_prefill_step", "prefill",
           "param_logical_axes", "LEARNED_POS_LEN"]

LEARNED_POS_LEN = 32768  # learned-pos table length (whisper decode_32k)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(cfg: ModelConfig, key, sig: Sig, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_layer(cfg, k, sig))(keys)


def init_params(cfg: ModelConfig, key) -> Dict:
    dt = cdtype(cfg)
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    ks = jax.random.split(key, 8 + first_k + period)
    p: Dict = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), dt)
                 * (1.0 / math.sqrt(cfg.d_model)),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_size), dt) / math.sqrt(cfg.d_model)
    if cfg.pos_embed == "learned":
        p["pos_embed"] = jax.random.normal(
            ks[2], (LEARNED_POS_LEN, cfg.d_model), dt) * 0.01
    if first_k:
        p["layers0"] = [init_layer(cfg, ks[8 + i], sigs[i])
                        for i in range(first_k)]
    p["layers"] = tuple(
        _stack_init(cfg, ks[8 + first_k + s], sigs[first_k + s], n_periods)
        for s in range(period))
    if cfg.encoder:
        e = cfg.encoder
        enc_sig: Sig = ("enc_attn", False)
        p["encoder"] = {
            "pos": jax.random.normal(ks[3], (e.n_frames, cfg.d_model), dt) * 0.01,
            "layers": (_stack_init(cfg, ks[4], enc_sig, e.n_layers),),
            "norm": init_norm(cfg),
        }
    return p


# ---------------------------------------------------------------------------
# logical axes for sharding (leaf-name -> trailing-dims rule; leading
# stack/slot dims get None)
# ---------------------------------------------------------------------------

_LEAF_RULES = {
    "embed": ("vocab", "fsdp"),
    "unembed": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    "pos": (None, "fsdp"),
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"),
    "w_dkv": ("fsdp", None), "w_uk": (None, "tp"), "w_uv": (None, "tp"),
    "router": ("fsdp", None),
    "we_g": ("expert", "fsdp", None), "we_i": ("expert", "fsdp", None),
    "we_o": ("expert", None, "fsdp"),
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def param_axes_rule(path, leaf):
    """Logical axes for one parameter leaf (by leaf name + ndim; leading
    stack/slot dims get None)."""
    name = _leaf_name(path)
    core = _LEAF_RULES.get(name, ())
    nd = len(leaf.shape)
    if len(core) > nd:
        core = core[len(core) - nd:]
    return (None,) * (nd - len(core)) + tuple(core)


def param_logical_axes(params) -> Dict:
    """Pytree of logical-axis tuples matching ``params``' structure."""
    return jax.tree_util.tree_map_with_path(param_axes_rule, params)


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------

def _encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (B, F, D)."""
    enc = params["encoder"]
    h = frames.astype(cdtype(cfg)) + enc["pos"][None, :frames.shape[1]]
    h = shard(h, "batch", None, None)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(carry, ws):
        hh, = carry
        hh, _ = apply_layer(cfg, ("enc_attn", False), ws, hh, mode="train",
                            positions=positions)
        return (hh,), None

    (h,), _ = jax.lax.scan(_remat(cfg, body), (h,), enc["layers"][0])
    return norm_apply(cfg, enc["norm"], h)


def _embed_in(cfg: ModelConfig, params, tokens, pos0=None):
    h = embed_apply(cfg, params["embed"], tokens)
    if cfg.pos_embed == "learned":
        S = tokens.shape[1]
        if pos0 is None:
            h = h + params["pos_embed"][None, :S]
        else:
            pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos0, S, 0)
            h = h + pe[None]
    return h


def _logits_out(cfg: ModelConfig, params, h):
    h = norm_apply(cfg, params["final_norm"], h)
    w_un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return unembed_apply(cfg, w_un, h)


def forward(cfg: ModelConfig, params, batch: Dict, *, mode: str = "train",
            max_len: int = 0, with_hidden: bool = False, last_pos=None):
    """Returns (logits, aux) for train; (logits, aux, cache) for prefill.
    ``with_hidden`` additionally returns the final-normed hidden states
    (used by the memory-lean CE loss).  ``last_pos`` (prefill only)
    names the true last prompt position(s) — an int32 scalar or a
    per-request (B,) vector — so right-padded prompts (the paged serve
    engine pads to page multiples) slice their logits at the real last
    token instead of the padding; causal attention makes the padded
    positions inert for every earlier row."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    positions = jnp.arange(S, dtype=jnp.int32)
    media = batch.get("media")
    if cfg.encoder:
        media = _encode(cfg, params, batch["frames"])
    h = _embed_in(cfg, params, tokens)

    aux = jnp.zeros((), jnp.float32)
    caches0: List = []
    for i in range(first_k):
        out = apply_layer(cfg, sigs[i], params["layers0"][i], h, mode=mode,
                          positions=positions, media=media, max_len=max_len)
        if mode == "prefill":
            h, a, c = out
            caches0.append(c)
        else:
            h, a = out
        aux = aux + a

    slot_sigs = [sigs[first_k + s] for s in range(period)]

    if mode == "prefill":
        def body(carry, ws):
            hh, ax = carry
            slot_caches = []
            for s in range(period):
                hh, a, c = apply_layer(cfg, slot_sigs[s], ws[s], hh,
                                       mode="prefill", positions=positions,
                                       media=media, max_len=max_len)
                hh = shard(hh, "batch", "seq", None)
                ax = ax + a
                slot_caches.append(c)
            return (hh, ax), tuple(slot_caches)

        (h, aux), layer_caches = jax.lax.scan(body, (h, aux), params["layers"])
        # serving only needs the last position's logits — slice BEFORE the
        # unembed matmul so the (B, S, V) tensor is never formed
        if last_pos is None:
            h_last = h[:, -1:]
        else:
            lp = jnp.asarray(last_pos, jnp.int32)
            if lp.ndim == 0:
                h_last = jax.lax.dynamic_slice_in_dim(h, lp, 1, 1)
            else:
                h_last = jnp.take_along_axis(h, lp[:, None, None], axis=1)
        logits = _logits_out(cfg, params, h_last)
        cache = {"layers0": caches0, "layers": layer_caches}
        return logits, aux, cache

    def body(carry, ws):
        hh, ax = carry
        for s in range(period):
            hh, a = apply_layer(cfg, slot_sigs[s], ws[s], hh, mode="train",
                                positions=positions, media=media)
            hh = shard(hh, "batch", "seq", None)
            ax = ax + a
        return (hh, ax), None

    (h, aux), _ = jax.lax.scan(_remat(cfg, body), (h, aux), params["layers"])
    h = norm_apply(cfg, params["final_norm"], h)
    # constrain h (and thereby its cotangent — wsc transposes to wsc): the
    # unembed backward otherwise materialises an unsharded (B,S,D) f32 grad
    h = shard(h, "batch", "seq", None)
    if with_hidden:
        # loss path: the chunked CE computes its own (batch-sliced) logits;
        # materialising the full (B,S,V) tensor here would defeat it
        return None, aux, h
    w_un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed_apply(cfg, w_un, h)
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Next-token cross-entropy (f32) + MoE aux loss.

    CE = mean(logsumexp(logits) - logit[label]).  The correct-class logit
    is a masked sum over the (sharded) logits — compare-select-reduce fuses
    with the unembed dot and stays sharded; a take()/gather formulation
    materialises (D, V)-scale scatter-adds in the backward.
    """
    _, aux, h = forward(cfg, params, batch, mode="train", with_hidden=True)
    labels = batch["labels"]
    w_un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ce = _chunked_ce(cfg, h, w_un, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def _chunked_ce(cfg: ModelConfig, h, w_un, labels, n_chunks: int = 4):
    """Batch-chunked CE (§Perf): the (B,S,V) f32 logits chain (logits, exp,
    grads) dominates training byte traffic for large vocabs.  Chunking over
    the BATCH dim keeps sharding uniform across chunks (sequence-chunking
    would idle 15/16 devices per chunk under sequence sharding) and each
    chunk body is checkpointed so its logits are recomputed in the backward
    instead of saved: peak logits bytes drop by n_chunks.
    """
    from repro.models.layers import mm
    from repro.parallel.api import current_mesh as _cm
    B, S, D = h.shape
    V = w_un.shape[-1]
    # chunks must stay divisible by the batch-shard count, else each slice
    # lives on a subset of devices and GSPMD reshards per chunk
    mesh = _cm()
    shard_n = 1
    if mesh is not None:
        shard_n = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    while n_chunks > 1 and (B % n_chunks or (B // n_chunks) % shard_n):
        n_chunks -= 1
    bc = B // n_chunks
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)

    @jax.checkpoint
    def chunk_ce(h_c, lab_c):
        logits = mm("bsd,dv->bsv", h_c, w_un)                 # (bc, S, V) f32
        from repro.parallel.api import current_mesh
        mesh = current_mesh()
        if mesh is not None and V % mesh.shape.get("model", 1) == 0:
            logits = shard(logits, "batch", None, "vocab")
        else:
            logits = shard(logits, "batch", "seq", None)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        eq = lab_c[..., None] == vocab_iota
        correct = jnp.sum(jnp.where(eq, logits, 0.0), axis=-1)
        return jnp.sum(lse - correct)

    total = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        total = total + chunk_ce(h[i * bc:(i + 1) * bc],
                                 labels[i * bc:(i + 1) * bc])
    return total / (B * S)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               media_len: int = 0) -> Dict:
    if cfg.encoder and media_len == 0:
        media_len = cfg.encoder.n_frames
    if cfg.cross_attn and media_len == 0:
        media_len = cfg.cross_attn.n_media_tokens
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    c: Dict = {"layers0": [init_layer_cache(cfg, sigs[i], batch, max_len,
                                            media_len)
                           for i in range(first_k)]}
    stacked = []
    for s in range(period):
        one = init_layer_cache(cfg, sigs[first_k + s], batch, max_len,
                               media_len)
        stacked.append(jax.tree.map(
            lambda a: jnp.zeros((n_periods,) + a.shape, a.dtype), one))
    c["layers"] = tuple(stacked)
    return c


def cache_axes_rule(path, leaf):
    """Logical axes for one decode-cache leaf."""
    name = _leaf_name(path)
    nd = len(leaf.shape)
    if name in ("k", "v", "ck", "cv"):
        core = ("batch", "kv_seq", None, None)
    elif name in ("ckv", "krope"):
        core = ("batch", "kv_seq", None)
    elif name == "conv":
        core = ("batch", None, "tp")
    elif name == "state":
        core = ("batch", "heads", None, None)
    else:
        core = ()
    if len(core) > nd:
        core = core[len(core) - nd:]
    return (None,) * (nd - len(core)) + tuple(core)


def cache_logical_axes(cfg: ModelConfig, cache) -> Dict:
    """Logical axes for the decode cache (dry-run in_shardings)."""
    return jax.tree_util.tree_map_with_path(cache_axes_rule, cache)


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens: jax.Array,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens (B, 1) int32; pos scalar int32 (current
    write index = number of tokens already in the cache)."""
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    h = _embed_in(cfg, params, tokens, pos0=pos)

    new0: List = []
    for i in range(first_k):
        h, nc = apply_layer(cfg, sigs[i], params["layers0"][i], h,
                            mode="decode", cache=cache["layers0"][i], pos=pos)
        new0.append(nc)

    slot_sigs = [sigs[first_k + s] for s in range(period)]

    def body(h, x):
        ws, cs = x
        new_cs = []
        for s in range(period):
            h, nc = apply_layer(cfg, slot_sigs[s], ws[s], h, mode="decode",
                                cache=cs[s], pos=pos)
            new_cs.append(nc)
        return h, tuple(new_cs)

    h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    logits = _logits_out(cfg, params, h)
    return logits, {"layers0": new0, "layers": new_layers}


def prefill(cfg: ModelConfig, params, batch: Dict, max_len: int,
            last_pos=None) -> Tuple[jax.Array, Dict]:
    """Process a prompt, returning (last-position logits, filled cache).
    ``last_pos`` slices right-padded prompts at their true last token
    (see :func:`forward`)."""
    logits, _, cache = forward(cfg, params, batch, mode="prefill",
                               max_len=max_len, last_pos=last_pos)
    return logits[:, -1:], cache


def paged_decode_step(cfg: ModelConfig, params, cache: Dict,
                      tokens: jax.Array, block_tables: jax.Array,
                      lens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One continuous-batching decode tick over the block-paged cache.

    tokens (B, 1) int32 — each engine slot's pending token; block_tables
    (B, NB) int32 logical->physical pool block maps (shared across
    layers: every layer's pool is indexed by the same table); lens (B,)
    int32 per-request cache lengths (write index AND RoPE position).
    The cache pytree mirrors :func:`init_cache`'s structure but each
    layer leaf is a (P, page, KV, hd) pool — build it with
    ``repro.serve.PagedKVCache``.  Unlike :func:`decode_step` there is
    no batch-wide ``pos``: slots decode at independent offsets, which is
    what lets one compiled step serve ragged in-flight requests.
    """
    if cfg.pos_embed != "rope":
        raise NotImplementedError(
            f"paged_decode_step: per-request positions need rope "
            f"(cfg.pos_embed={cfg.pos_embed!r})")
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    h = _embed_in(cfg, params, tokens)

    new0: List = []
    for i in range(first_k):
        h, nc = apply_layer_paged(cfg, sigs[i], params["layers0"][i], h,
                                  cache["layers0"][i], block_tables, lens)
        new0.append(nc)

    slot_sigs = [sigs[first_k + s] for s in range(period)]

    def body(h, x):
        ws, cs = x
        new_cs = []
        for s in range(period):
            h, nc = apply_layer_paged(cfg, slot_sigs[s], ws[s], h, cs[s],
                                      block_tables, lens)
            new_cs.append(nc)
        return h, tuple(new_cs)

    h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    logits = _logits_out(cfg, params, h)
    return logits, {"layers0": new0, "layers": new_layers}


def paged_prefill_step(cfg: ModelConfig, params, cache: Dict,
                       tokens: jax.Array, block_tables: jax.Array,
                       lens: jax.Array, n_valid: jax.Array, *,
                       aligned: bool = False) -> Tuple[jax.Array, Dict]:
    """One continuation-prefill chunk over the block-paged cache.

    tokens (B, C) int32 — a fixed-size chunk of each request's uncached
    prompt suffix, right-padded past ``n_valid``; block_tables (B, NB)
    and lens (B,) as in :func:`paged_decode_step` (``lens`` = tokens
    already cached = the chunk's global start position).  Each layer
    scatters the chunk's K/V into the pool and attends back through the
    block table, so a chunk sees both earlier chunks of its own prompt
    AND any prefix blocks *shared* with other requests.  Returns the
    logits at each request's last valid chunk row (B, 1, V) — only
    meaningful for the final chunk, where that row is the last prompt
    token — plus the updated pool pytree.  Chunking the prompt this way
    is the incremental-admission path: one fixed compiled shape serves
    any prompt length, and long prompts interleave with decode ticks
    instead of stalling them.  ``aligned`` forwards the single-block
    fast-write promise (B == 1, chunk size divides the page) to the
    attention layers.
    """
    if cfg.pos_embed != "rope":
        raise NotImplementedError(
            f"paged_prefill_step: per-request positions need rope "
            f"(cfg.pos_embed={cfg.pos_embed!r})")
    first_k, period, n_periods = schedule(cfg)
    sigs = layer_sigs(cfg)
    nv = jnp.asarray(n_valid, jnp.int32)
    h = _embed_in(cfg, params, tokens)

    new0: List = []
    for i in range(first_k):
        h, nc = apply_layer_prefill_paged(cfg, sigs[i], params["layers0"][i],
                                          h, cache["layers0"][i],
                                          block_tables, lens, nv, aligned)
        new0.append(nc)

    slot_sigs = [sigs[first_k + s] for s in range(period)]

    def body(h, x):
        ws, cs = x
        new_cs = []
        for s in range(period):
            h, nc = apply_layer_prefill_paged(cfg, slot_sigs[s], ws[s], h,
                                              cs[s], block_tables, lens, nv,
                                              aligned)
            new_cs.append(nc)
        return h, tuple(new_cs)

    h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    # logits only at the last valid row — sliced before the unembed, like
    # prefill's last_pos path, so the (B, C, V) tensor is never formed
    h_last = jnp.take_along_axis(h, (nv - 1)[:, None, None], axis=1)
    logits = _logits_out(cfg, params, h_last)
    return logits, {"layers0": new0, "layers": new_layers}
