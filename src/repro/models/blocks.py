"""Decoder blocks + heterogeneous layer schedules.

A *layer signature* ``(mixer, is_moe)`` classifies every layer:
  mixer ∈ {"attn", "ssm", "cross", "attn_cross"}   (attn_cross = whisper dec)
  is_moe  — MoE FFN instead of dense MLP.

Architectures repeat a fixed *period* of signatures (dense: [attn]*1;
jamba: 8 layers with 1 attn + MoE every other; vlm: 4 self + 1 cross;
deepseek: 1 dense-FFN layer then homogeneous MoE).  ``model.py`` scans over
periods with per-slot weight stacks, so the compiled HLO stays small for
60-100 layer models.

Every block is pre-norm with residuals:  h += mixer(norm(h));
h += ffn(norm(h)); whisper decoder inserts a cross-attention sub-block.
Cross layers carry a learned tanh gate (llama-3.2-vision style).

Mixer execution path: the attention/SSD/MoE calls below read
``cfg.use_pallas`` — when set, each catalog-backed op dispatches to the
``repro.kernels`` Pallas layer (falling back per op, with a logged
reason, whenever the kernel contract cannot express it).  Nothing at the
block level changes: the dual path lives inside the mixers, and the
mesh context threads through ``parallel.api.set_mesh``'s trace-time
thread-local — under an active mesh the mixers plan per-shard and run
their kernels inside ``shard_map``, so blocks stay mesh-agnostic.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import cdtype, mlp_apply, norm_apply
from repro.models.moe import init_moe, moe_apply

__all__ = ["Sig", "layer_sigs", "schedule", "init_layer", "init_layer_cache",
           "apply_layer", "apply_layer_paged", "apply_layer_prefill_paged",
           "init_norm", "init_mlp"]

Sig = Tuple[str, bool]


def layer_sigs(cfg: ModelConfig) -> List[Sig]:
    sigs: List[Sig] = []
    for i in range(cfg.n_layers):
        if cfg.cross_attn and (i + 1) % cfg.cross_attn.period == 0:
            mixer = "cross"
        else:
            mixer = cfg.layer_kind(i)
        sigs.append((mixer, cfg.layer_is_moe(i)))
    return sigs


def schedule(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(first_k, period, n_periods): first_k unstacked layers, then
    n_periods repetitions of a `period`-layer cycle."""
    first_k = cfg.first_k_dense
    sigs = layer_sigs(cfg)[first_k:]
    n = len(sigs)
    for p in range(1, n + 1):
        if n % p == 0 and all(sigs[i] == sigs[i % p] for i in range(n)):
            return first_k, p, n // p
    return first_k, n, 1


def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones((d,), cdtype(cfg)),
                "bias": jnp.zeros((d,), cdtype(cfg))}
    return jnp.ones((d,), cdtype(cfg))


def init_mlp(cfg: ModelConfig, key) -> Dict:
    import math
    D, F = cfg.d_model, cfg.d_ff
    dt = cdtype(cfg)
    s = 1.0 / math.sqrt(D)
    so = 1.0 / math.sqrt(F) / math.sqrt(max(1, cfg.n_layers))
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "gelu":
        return {"wi": jax.random.normal(k1, (D, F), dt) * s,
                "bi": jnp.zeros((F,), dt),
                "wo": jax.random.normal(k2, (F, D), dt) * so,
                "bo": jnp.zeros((D,), dt)}
    return {"wg": jax.random.normal(k1, (D, F), dt) * s,
            "wi": jax.random.normal(k2, (D, F), dt) * s,
            "wo": jax.random.normal(k3, (F, D), dt) * so}


def init_layer(cfg: ModelConfig, key, sig: Sig) -> Dict:
    mixer, is_moe = sig
    ks = jax.random.split(key, 4)
    w: Dict = {"ln1": init_norm(cfg)}
    if is_moe or cfg.d_ff > 0:
        w["ln2"] = init_norm(cfg)
    if mixer in ("attn", "enc_attn"):
        w["mixer"] = (attn.init_mla(cfg, ks[0]) if cfg.mla and mixer == "attn"
                      else attn.init_attn(cfg, ks[0]))
    elif mixer == "ssm":
        w["mixer"] = ssm_mod.init_ssm(cfg, ks[0])
    elif mixer == "cross":
        w["mixer"] = attn.init_cross(cfg, ks[0])
        w["gate"] = jnp.zeros((), jnp.float32)
    elif mixer == "attn_cross":
        w["mixer"] = attn.init_attn(cfg, ks[0])
        w["lnx"] = init_norm(cfg)
        w["cross"] = attn.init_cross(cfg, ks[3])
    else:
        raise ValueError(mixer)
    if is_moe:
        w["ffn"] = init_moe(cfg, ks[1])
    elif cfg.d_ff > 0:
        w["ffn"] = init_mlp(cfg, ks[1])
    return w


def init_layer_cache(cfg: ModelConfig, sig: Sig, batch: int, max_len: int,
                     media_len: int = 0) -> Dict:
    """Zeroed decode cache for one layer (also the dry-run cache spec)."""
    mixer, _ = sig
    dt = cdtype(cfg)
    if mixer == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch)
    if mixer == "cross":
        shp = (batch, media_len, cfg.n_kv_heads, cfg.hd)
        return {"ck": jnp.zeros(shp, dt), "cv": jnp.zeros(shp, dt)}
    if mixer == "attn_cross":
        c = attn.init_attn_cache(cfg, batch, max_len)
        shp = (batch, media_len, cfg.n_kv_heads, cfg.hd)
        c["ck"] = jnp.zeros(shp, dt)
        c["cv"] = jnp.zeros(shp, dt)
        return c
    if cfg.mla:
        return attn.init_mla_cache(cfg, batch, max_len)
    return attn.init_attn_cache(cfg, batch, max_len)


def _ffn(cfg: ModelConfig, sig: Sig, w, h):
    if sig[1]:
        y, aux = moe_apply(cfg, w["ffn"], h)
    else:
        y, aux = mlp_apply(cfg, w["ffn"], h), jnp.zeros((), jnp.float32)
    return y, aux


def _pad_cache(x: jax.Array, max_len: int) -> jax.Array:
    """Right-pad a (B, S, ...) prefill tensor to cache length."""
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, max_len - x.shape[1])
    return jnp.pad(x, pad)


def apply_layer(cfg: ModelConfig, sig: Sig, w, h: jax.Array, *,
                mode: str, positions=None, media=None, cache=None,
                pos=None, max_len: int = 0):
    """Unified layer application.

    mode="train":   returns (h, aux)
    mode="prefill": returns (h, aux, cache)   — cache padded to max_len
    mode="decode":  returns (h, new_cache)    — h is (B, 1, D)
    """
    mixer, _ = sig
    hin = h
    x = norm_apply(cfg, w["ln1"], h)
    new_cache: Dict = {}

    if mixer == "enc_attn":
        y = attn.attn_train(cfg, w["mixer"], x, positions, causal=False)
    elif mixer == "attn":
        if mode == "decode":
            if cfg.mla:
                y, new_cache = attn.mla_decode(cfg, w["mixer"], x, cache, pos)
            else:
                y, new_cache = attn.attn_decode(cfg, w["mixer"], x, cache, pos)
        else:
            if cfg.mla:
                y = attn.mla_train(cfg, w["mixer"], x, positions)
            else:
                y = attn.attn_train(cfg, w["mixer"], x, positions)
            if mode == "prefill":
                new_cache = _attn_prefill_cache(cfg, w["mixer"], x, positions,
                                                max_len)
    elif mixer == "ssm":
        if mode == "decode":
            y, new_cache = ssm_mod.ssm_decode(cfg, w["mixer"], x, cache, pos)
        else:
            y = ssm_mod.ssm_train(cfg, w["mixer"], x)
            if mode == "prefill":
                new_cache = _ssm_prefill_cache(cfg, w["mixer"], x)
    elif mixer == "cross":
        if mode == "decode":
            y = attn.cross_decode(cfg, w["mixer"], x, (cache["ck"], cache["cv"]))
            new_cache = cache
        else:
            y = attn.cross_train(cfg, w["mixer"], x, media)
            if mode == "prefill":
                ck, cv = attn.cross_kv(cfg, w["mixer"], media)
                new_cache = {"ck": ck, "cv": cv}
        y = (jnp.tanh(w["gate"]) * y.astype(jnp.float32)).astype(y.dtype)
    elif mixer == "attn_cross":
        if mode == "decode":
            y, nc = attn.attn_decode(cfg, w["mixer"], x, cache, pos)
            h1 = hin + y
            xc = norm_apply(cfg, w["lnx"], h1)
            yc = attn.cross_decode(cfg, w["cross"], xc,
                                   (cache["ck"], cache["cv"]))
            nc["ck"], nc["cv"] = cache["ck"], cache["cv"]
            new_cache = nc
            y = y + yc  # combined residual below
        else:
            y = attn.attn_train(cfg, w["mixer"], x, positions)
            if mode == "prefill":
                new_cache = _attn_prefill_cache(cfg, w["mixer"], x, positions,
                                                max_len)
                ck, cv = attn.cross_kv(cfg, w["cross"], media)
                new_cache["ck"], new_cache["cv"] = ck, cv
            h1 = hin + y
            xc = norm_apply(cfg, w["lnx"], h1)
            y = y + attn.cross_train(cfg, w["cross"], xc, media)
    else:
        raise ValueError(mixer)

    h = hin + y
    if "ffn" in w:
        z = norm_apply(cfg, w["ln2"], h)
        f, aux = _ffn(cfg, sig, w, z)
        h = h + f
    else:
        aux = jnp.zeros((), jnp.float32)  # attn-free mamba2: mixer-only block
    if mode == "train":
        return h, aux
    if mode == "prefill":
        return h, aux, new_cache
    return h, new_cache


def apply_layer_paged(cfg: ModelConfig, sig: Sig, w, h: jax.Array,
                      cache: Dict, block_tables: jax.Array,
                      lens: jax.Array):
    """One layer of a continuous-batching decode tick: like
    ``apply_layer(mode="decode")`` but against the shared block-paged KV
    pool, with per-request positions (``lens``) instead of a batch-wide
    ``pos`` scalar.  Returns (h, new_cache); h is (B, 1, D).

    Only plain GQA attention layers can page — the SSM state is O(1) and
    needs no paging, and MLA/cross caches have different leaf shapes —
    so heterogeneous schedules raise rather than silently mixing cache
    layouts (``PagedKVCache`` rejects such configs up front).
    """
    mixer, _ = sig
    if mixer != "attn" or cfg.mla:
        raise NotImplementedError(
            f"apply_layer_paged: only plain GQA attention layers page "
            f"(got mixer={mixer!r}, mla={bool(cfg.mla)})")
    hin = h
    x = norm_apply(cfg, w["ln1"], h)
    y, new_cache = attn.attn_decode_paged(cfg, w["mixer"], x, cache,
                                          block_tables, lens)
    h = hin + y
    if "ffn" in w:
        z = norm_apply(cfg, w["ln2"], h)
        f, _ = _ffn(cfg, sig, w, z)
        h = h + f
    return h, new_cache


def apply_layer_prefill_paged(cfg: ModelConfig, sig: Sig, w, h: jax.Array,
                              cache: Dict, block_tables: jax.Array,
                              lens: jax.Array, n_valid: jax.Array,
                              aligned: bool = False):
    """One layer of a continuation-prefill chunk: like
    :func:`apply_layer_paged` but over a (B, C, D) chunk of prompt
    tokens instead of a single pending token — the chunk's K/V rows are
    written into the pool and attention reads the already-written
    prefix back through the block table.  Returns (h, new_cache).
    ``aligned`` passes through to :func:`attn.attn_prefill_paged`'s
    single-block fast write path.  Same paging restriction: plain GQA
    attention layers only.
    """
    mixer, _ = sig
    if mixer != "attn" or cfg.mla:
        raise NotImplementedError(
            f"apply_layer_prefill_paged: only plain GQA attention layers "
            f"page (got mixer={mixer!r}, mla={bool(cfg.mla)})")
    hin = h
    x = norm_apply(cfg, w["ln1"], h)
    y, new_cache = attn.attn_prefill_paged(cfg, w["mixer"], x, cache,
                                           block_tables, lens, n_valid,
                                           aligned=aligned)
    h = hin + y
    if "ffn" in w:
        z = norm_apply(cfg, w["ln2"], h)
        f, _ = _ffn(cfg, sig, w, z)
        h = h + f
    return h, new_cache


def _attn_prefill_cache(cfg: ModelConfig, w, x, positions, max_len):
    """Recompute K/V (cheap vs attention itself) and pad to cache length."""
    if cfg.mla:
        c_kv, k_rope = attn._mla_latent(cfg, w, x, positions)
        return {"ckv": _pad_cache(c_kv, max_len),
                "krope": _pad_cache(k_rope, max_len)}
    _, k, v = attn._qkv(cfg, w, x, positions)
    return {"k": _pad_cache(k, max_len), "v": _pad_cache(v, max_len)}


def _ssm_prefill_cache(cfg: ModelConfig, w, x):
    """Re-run the SSD scan keeping final state + conv tail."""
    import jax.numpy as jnp
    s = cfg.ssm
    B, S, D = x.shape
    d_in = ssm_mod.d_inner_of(cfg)
    nh = d_in // s.head_dim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, w["in_proj"]).astype(x.dtype)
    z, xs, Bm, Cm, dtr = ssm_mod._split_proj(cfg, zxbcdt)
    xbc_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc = ssm_mod._conv_train(w, xbc_raw, s.d_conv)
    xs2, Bm2, Cm2 = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state],
                              axis=-1)
    xh = xs2.reshape(B, S, nh, s.head_dim)
    Bg = Bm2.reshape(B, S, s.n_groups, s.d_state)
    Cg = Cm2.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"])
    A = -jnp.exp(w["A_log"])
    _, h_final = ssm_mod.ssd_chunked(xh, dt, A, Bg, Cg, s.chunk,
                                     use_pallas=cfg.use_pallas,
                                     pallas_device=cfg.pallas_device)
    return {"conv": xbc_raw[:, S - (s.d_conv - 1):, :],
            "state": h_final}
