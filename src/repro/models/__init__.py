"""Pure-JAX model zoo covering the 10 assigned architecture families."""

from repro.models.config import (ModelConfig, MoESpec, MLASpec, SSMSpec,
                                 CrossAttnSpec, EncoderSpec)  # noqa: F401
from repro.models.model import (init_params, forward, loss_fn, init_cache,
                                decode_step, prefill, param_logical_axes)  # noqa: F401
