"""Layer primitives: norms, RoPE, MLPs, embeddings.

Numerics policy (recorded in DESIGN.md): parameters and matmul operands in
``cfg.dtype`` (bf16), normalisation statistics / softmax / logits in f32,
matmul accumulation in f32 via ``preferred_element_type``.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.api import shard

__all__ = ["dense", "mm", "norm_apply", "rope", "mlp_apply", "embed_apply",
           "unembed_apply", "DTYPES", "cdtype"]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def _force_f32_dots() -> bool:
    """XLA:CPU's thunk runtime cannot execute BF16xBF16=F32 dots inside
    while bodies.  For CPU *execution* (tests, examples) we upcast operands
    to f32; the dry-run (lower/compile only) disables this via
    REPRO_CPU_F32_DOTS=0 so the lowered program keeps faithful bf16 dots."""
    env = os.environ.get("REPRO_CPU_F32_DOTS")
    if env is not None:
        return env == "1"
    return jax.default_backend() == "cpu"


def mm(subscripts: str, a: jax.Array, b: jax.Array,
       out_dtype=None) -> jax.Array:
    """Matmul-class einsum with f32 accumulation (bf16 in, f32 acc)."""
    if a.dtype == jnp.bfloat16 and _force_f32_dots():
        y = jnp.einsum(subscripts, a.astype(jnp.float32),
                       b.astype(jnp.float32))
    else:
        y = jnp.einsum(subscripts, a, b,
                       preferred_element_type=jnp.float32)
    return y if out_dtype is None else y.astype(out_dtype)


def cdtype(cfg: ModelConfig):
    return DTYPES[cfg.dtype]


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    """x @ w (+ b): bf16 operands, f32 accumulation, result in x.dtype."""
    y = mm("...k,kn->...n", x, w, out_dtype=x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def norm_apply(cfg: ModelConfig, w, x: jax.Array) -> jax.Array:
    """RMSNorm or LayerNorm in f32, cast back to x.dtype.

    ``w`` is either the scale vector (rms) or {"scale","bias"} (layer).
    """
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * w["scale"].astype(jnp.float32) + w["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (seq,)
    or (batch, seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (.., S, half)
    # broadcast over the heads axis: (..., S, 1, half)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def mlp_apply(cfg: ModelConfig, w, x: jax.Array) -> jax.Array:
    """SwiGLU (wi/wg/wo) or GELU (wi/wo) feed-forward."""
    if cfg.mlp_type == "gelu":
        h = jax.nn.gelu(dense(x, w["wi"], w.get("bi")))
    else:
        h = jax.nn.silu(dense(x, w["wg"])) * dense(x, w["wi"])
    h = shard(h, "batch", None, "tp")
    return dense(h, w["wo"], w.get("bo"))


def embed_apply(cfg: ModelConfig, w_embed: jax.Array,
                tokens: jax.Array) -> jax.Array:
    """Token embedding lookup; (B, S) int32 -> (B, S, D).

    The wsc on the *weight* shards D — the gather's PASSTHROUGH dim — so
    GSPMD partitions both the lookup and its backward scatter-add natively
    (sharding V instead leaves the (V, D) f32 gradient scatter unsharded:
    the gathered dim can't be partitioned against data-dependent indices).
    Storage stays (vocab, fsdp)-sharded; XLA inserts the reshard.
    """
    w_embed = shard(w_embed, None, "tp")
    h = jnp.take(w_embed, tokens, axis=0).astype(cdtype(cfg))
    return shard(h, "batch", "seq", None)


def unembed_apply(cfg: ModelConfig, w_unembed: jax.Array,
                  h: jax.Array) -> jax.Array:
    """(B, S, D) -> f32 logits (B, S, V).

    Vocab-sharded when V divides the model axis (TP unembed); otherwise
    sequence-sharded — an unsharded (B, S, V) f32 tensor is the single
    largest buffer in training (12+ GiB/device for mamba2/whisper whose
    vocabs are not multiples of 16).
    """
    from repro.parallel.api import current_mesh
    w_unembed = shard(w_unembed, None, "vocab")
    logits = mm("bsd,dv->bsv", h, w_unembed)
    mesh = current_mesh()
    V = w_unembed.shape[-1]
    if mesh is not None and V % mesh.shape.get("model", 1) == 0:
        return shard(logits, "batch", None, "vocab")
    return shard(logits, "batch", "seq", None)
