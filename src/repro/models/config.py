"""Architecture configuration dataclasses.

One ``ModelConfig`` describes every assigned architecture family:
dense GQA decoders, MLA, MoE, Mamba2 SSD, hybrid (jamba), enc-dec (whisper)
and VLM (cross-attention) backbones.  ``reduced()`` derives the smoke-test
variant required by the assignment (small layers/width/experts, same family).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "MoESpec", "MLASpec", "SSMSpec", "CrossAttnSpec",
           "EncoderSpec"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # always-on shared experts (deepseek)
    d_ff_shared: int = 0
    period: int = 1              # MoE every `period` layers (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0         # 0 => no query compression (V2-Lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba2 / SSD (state-space duality) mixer."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class CrossAttnSpec:
    """VLM: every `period`-th layer cross-attends to media embeddings."""
    period: int = 5
    n_media_tokens: int = 4100   # precomputed patch embeddings (stub frontend)


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Whisper-style encoder; the conv frontend is a STUB (precomputed
    frame embeddings of shape (batch, n_frames, d_model))."""
    n_layers: int = 6
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | vlm | ssm | audio | moe | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    norm_type: str = "rms"       # rms | layer
    mlp_type: str = "swiglu"     # swiglu | gelu
    pos_embed: str = "rope"      # rope | learned | none
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    cross_attn: Optional[CrossAttnSpec] = None
    encoder: Optional[EncoderSpec] = None
    # hybrid (jamba): one attention layer per `attn_period` layers at
    # `attn_offset` within the period; all other mixers are SSM.
    attn_period: int = 0
    attn_offset: int = 0
    first_k_dense: int = 0       # deepseek: first k layers use dense FFN
    dtype: str = "bfloat16"
    sub_quadratic: bool = False  # can lower long_500k (SSM/hybrid)
    remat: str = "full"          # full | dots | none  (activation ckpt policy)
    scan_layers: bool = True
    microbatches: int = 1        # train-step gradient-accumulation factor
    # route catalog-backed mixer ops (attention train+decode, SSD, MoE
    # expert matmuls) through the repro.kernels Pallas layer instead of
    # the XLA reference formulations.  Dispatch is per-op via
    # repro.kernels.dispatch: anything the kernel path cannot support
    # (mesh-sharded execution, unplannable shapes, MLA's asymmetric head
    # dims) falls back to the reference with a logged reason.
    use_pallas: bool = False
    # repro.arch registry name the kernel tile plans are derived for
    # (mxu_dim alignment + vmem_bytes budget).  None -> the planner's
    # default TPU; set this to the executing device's registry entry so
    # tiles are sized against its actual VMEM.
    pallas_device: Optional[str] = None
    # gradient-accumulation dtype: f32 default; bf16 halves the accumulator
    # buffer AND the cross-device gradient reduction wire bytes at ~3 bits
    # of accumulated-mantissa cost (used by the largest MoE config)
    grad_accum_dtype: str = "float32"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attn_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, idx: int) -> str:
        """'attn' or 'ssm' mixer for global layer index `idx` (hybrid)."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_period:
            return "attn" if idx % self.attn_period == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe is None or idx < self.first_k_dense:
            return False
        return (idx - self.first_k_dense) % self.moe.period == 0 \
            if self.moe.period > 1 else idx >= self.first_k_dense

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/wiring, tiny dims."""
        kw = dict(
            microbatches=1,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                d_ff_shared=64 if self.moe.n_shared else 0)
        if self.mla:
            kw["mla"] = MLASpec(kv_lora_rank=32, q_lora_rank=0,
                                qk_nope_dim=16, qk_rope_dim=16, v_head_dim=16)
            kw["head_dim"] = 0
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk=32)
        if self.cross_attn:
            kw["cross_attn"] = dataclasses.replace(self.cross_attn, period=2,
                                                   n_media_tokens=16)
            kw["n_layers"] = 4
        if self.encoder:
            kw["encoder"] = EncoderSpec(n_layers=2, n_frames=32)
        if self.attn_period:
            kw["attn_period"] = min(self.attn_period, 4)
            kw["attn_offset"] = min(self.attn_offset, 3)
            kw["n_layers"] = 2 * min(self.attn_period, 4)
        if self.first_k_dense:
            kw["first_k_dense"] = 1
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)
