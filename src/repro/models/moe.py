"""Mixture-of-Experts FFN: top-k router + capacity-based gather dispatch.

Dispatch design (matters for the roofline): the classic one-hot-einsum
dispatch charges O(tokens x E x C x D) *fake* matmul FLOPs to HLO, polluting
the compute roofline term by >10x on qwen3 (128 experts, top-8).  We instead
build integer slot maps from the router output (cumsum over one-hot int32 —
cheap) and move tokens with gathers:

  dispatch:  xbuf[g, e, c, :]  = x[g, src[g, e, c], :]     (take_along_axis)
  experts:   ybuf = swiglu(xbuf @ We_in) @ We_out          (E-sharded einsum)
  combine:   y[g, t]          = sum_k gate * ybuf[g, e(t,k), p(t,k), :]

Expert weights and the (G, E, C, D) buffers shard E over the "expert"
logical axis (model); the combine gather crossing the expert axis is where
GSPMD inserts the all-to-all-class collective — the EP communication the
paper's scoreboard would attribute to the interconnect, and a hillclimb
target.  Capacity drops follow Switch semantics (first-come within the
group, position >= C dropped).

Dual execution path: with ``cfg.use_pallas`` the three expert matmuls
(gate/up/down projections over the (E, C, D) slot buffers) route through
``repro.kernels.dispatch`` to the ``kernels.moe_gmm`` grouped-GEMM Pallas
kernel — the batch groups fold into the per-expert row dim, and
capacity-trimmed (non-128-multiple) C plus ragged D/F pad via the
ops-layer zero-pad/slice path, which is exact for a GEMM.  On a mesh the
GMM runs under ``shard_map`` with E sharded over the "expert" axis —
the dispatch/combine gathers (the EP collectives) stay in the
surrounding XLA program.  Unplannable (local) shapes fall back to the
einsum with a logged reason.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense, mm
from repro.parallel.api import current_mesh, shard

__all__ = ["init_moe", "moe_apply", "router_topk", "capacity"]


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor
                      / m.n_experts))
    return max(4, ((c + 3) // 4) * 4)  # pad to a multiple of 4


def init_moe(cfg: ModelConfig, key) -> Dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 6)
    dt = cdtype(cfg)
    s = 1.0 / math.sqrt(D)
    w = {
        "router": jax.random.normal(ks[0], (D, E), jnp.float32) * s,
        "we_g": jax.random.normal(ks[1], (E, D, F), dt) * s,
        "we_i": jax.random.normal(ks[2], (E, D, F), dt) * s,
        "we_o": jax.random.normal(ks[3], (E, F, D), dt)
                * (1.0 / math.sqrt(F) / math.sqrt(max(1, cfg.n_layers))),
    }
    if m.n_shared:
        Fs = m.d_ff_shared or m.n_shared * F
        w["shared"] = {
            "wg": jax.random.normal(ks[4], (D, Fs), dt) * s,
            "wi": jax.random.normal(ks[4], (D, Fs), dt) * s,
            "wo": jax.random.normal(ks[5], (Fs, D), dt) * (1.0 / math.sqrt(Fs)),
        }
    return w


def router_topk(cfg: ModelConfig, w_router, x) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: f32 softmax over experts, top-k, renormalised gates.

    x: (G, S, D) -> gates (G, S, K) f32, idx (G, S, K) i32, aux_loss scalar.
    """
    m = cfg.moe
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), w_router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32),
                  axis=(0, 1, 2))                                    # (E,)
    aux = m.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def _slot_maps(cfg: ModelConfig, idx: jax.Array, C: int):
    """Integer slot maps from expert assignments.

    idx: (G, A) expert ids (A = S*K assignments in token order).
    Returns:
      pos   (G, A)   position of each assignment within its expert (i32)
      keep  (G, A)   pos < C and valid
      src   (G, E*C) assignment index feeding each expert slot (0 if empty)
      used  (G, E*C) slot occupancy mask
    """
    m = cfg.moe
    G, A = idx.shape
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)        # (G,A,E)
    onehot = shard(onehot, "batch", None, "expert")
    pos_all = jnp.cumsum(onehot, axis=1) - 1                          # (G,A,E)
    pos_all = shard(pos_all, "batch", None, "expert")
    pos = jnp.take_along_axis(pos_all, idx[..., None], axis=-1)[..., 0]
    keep = pos < C
    # out-of-capacity assignments scatter out of bounds -> mode="drop"
    slot = jnp.where(keep, idx * C + pos, m.n_experts * C)
    src = jnp.zeros((G, m.n_experts * C), jnp.int32)
    arange = jnp.broadcast_to(jnp.arange(A, dtype=jnp.int32)[None], (G, A))
    src = src.at[jnp.arange(G)[:, None], slot].set(arange, mode="drop")
    used = jnp.zeros((G, m.n_experts * C), jnp.bool_)
    used = used.at[jnp.arange(G)[:, None], slot].set(True, mode="drop")
    return pos, keep, shard(src, "batch", "expert"), \
        shard(used, "batch", "expert")


def _expert_mm(x4: jax.Array, w3: jax.Array, *, use_pallas: bool,
               device=None, out_dtype=None) -> jax.Array:
    """Per-expert batched matmul (B, E, C, K) @ (E, K, N) -> (B, E, C, N).

    f32 accumulation either way.  With ``use_pallas`` the batch groups
    fold into the per-expert row dim and the op dispatches to the
    ``moe_gmm`` grouped-GEMM kernel (ragged C/K/N zero-pad exactly);
    otherwise (or on fallback) the E-sharded einsum runs.
    """
    if use_pallas:
        B, E, C, K = x4.shape
        N = w3.shape[2]
        dec = kdispatch.decide(
            "moe_gmm", {"E": E, "C": B * C, "K": K, "N": N},
            dtype=x4.dtype, device=device,
            sharded=current_mesh() is not None)
        if dec.use_kernel:
            xe = x4.transpose(1, 0, 2, 3).reshape(E, B * C, K)
            y = kops.moe_gmm(xe, w3,
                             plan=None if dec.sharded else dec.plan,
                             device=device, pad=True, sharded=dec.sharded)
            y = y.reshape(E, B, C, N).transpose(1, 0, 2, 3)
            # the kernel accumulates in f32 but stores in x4.dtype, so
            # (unlike mm's true-f32 output) the bf16 path takes one extra
            # rounding here before the f32 gate math — covered by the
            # bf16 parity tolerance
            return y.astype(jnp.float32 if out_dtype is None else out_dtype)
    return mm("beck,ekn->becn", x4, w3, out_dtype=out_dtype)


def moe_apply(cfg: ModelConfig, w, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).  Groups = batch rows."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = capacity(cfg, S)
    # pin x's sharding with D on the model axis: D is the PASSTHROUGH dim
    # of the dispatch/combine gathers, so GSPMD partitions them AND their
    # backward scatter-adds (S-sharding would leave unsharded (B,S,D) f32
    # gradient scatters — the gathered dim can't partition vs indices)
    x = shard(x, "batch", None, "tp")
    gates, idx, aux = router_topk(cfg, w["router"], x)

    idx_flat = idx.reshape(B, S * K)                   # assignment order: (t, k)
    pos, keep, src, used = _slot_maps(cfg, idx_flat, C)

    # token index of each assignment; gather tokens into expert slot buffers
    tok_of_src = src // K                                             # (B, E*C)
    xbuf = jnp.take_along_axis(x, tok_of_src[..., None], axis=1)      # (B,E*C,D)
    xbuf = xbuf * used[..., None].astype(x.dtype)
    xbuf = xbuf.reshape(B, E, C, D)
    xbuf = shard(xbuf, "batch", "expert", None, None)

    # expert FFN (E-sharded batched einsum, or the moe_gmm grouped-GEMM
    # kernel under cfg.use_pallas; f32 accumulation either way)
    h = jax.nn.silu(_expert_mm(xbuf, w["we_g"], use_pallas=cfg.use_pallas,
                               device=cfg.pallas_device)) \
        * _expert_mm(xbuf, w["we_i"], use_pallas=cfg.use_pallas,
                     device=cfg.pallas_device)
    h = h.astype(x.dtype)
    h = shard(h, "batch", "expert", None, None)
    ybuf = _expert_mm(h, w["we_o"], use_pallas=cfg.use_pallas,
                      device=cfg.pallas_device, out_dtype=x.dtype)
    # §Perf: reshard E@model -> D@model here (an all-to-all: each device
    # keeps 1/|model| of ybuf) so the combine gather below is LOCAL in its
    # passthrough dim.  Leaving ybuf expert-sharded makes GSPMD all-gather
    # the full (B,E,C,D) buffer to every device — measured ~1.2 TB/device
    # of all-gather wire on qwen3 train_4k vs ~E/(E-1) x local bytes here.
    ybuf = shard(ybuf, "batch", None, None, "tp")

    # combine: gather each kept assignment's slot output, weight, sum over k
    slot = jnp.where(keep, idx_flat * C + pos, 0)                     # (B,S*K)
    y_k = jnp.take_along_axis(ybuf.reshape(B, E * C, D), slot[..., None],
                              axis=1)                                 # (B,S*K,D)
    y_k = shard(y_k, "batch", None, "tp")
    gk = (gates.reshape(B, S * K) * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bad,ba->bad", y_k, gk).reshape(B, S, K, D).sum(axis=2)
    y = shard(y, "batch", "seq", None)

    if m.n_shared:
        ws = w["shared"]
        hs = jax.nn.silu(dense(x, ws["wg"])) * dense(x, ws["wi"])
        y = y + dense(hs, ws["wo"])
    return y, aux * m.router_aux_weight
