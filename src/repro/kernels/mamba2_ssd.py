"""Chunked SSD (Mamba2) Pallas kernel.

Grid (B, nh, S/chunk) with the chunk dimension sequential; the inter-chunk
SSM state (hd, ds) lives in f32 VMEM scratch across chunk steps (reset at
chunk 0).  All intra-chunk work is expressed as (Q x Q) / (Q x hd) / (Q x ds)
matmuls — MXU-shaped, which is precisely the "state-space duality" insight:
the quadratic-attention form of the SSM inside a chunk, the linear
recurrence across chunks.  Cumulative sums are computed as a
lower-triangular-ones matmul (MXU) rather than a serial scan.

B/C group tensors are indexed per-head via the BlockSpec index map
(h -> h // heads_per_group), so grouped B/C are never materialised per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import SUBLANE, validate_tiling

__all__ = ["mamba2_ssd"]


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref,
                state_ref, *, n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[0, 0]                                     # scalar f32
    x = x_ref[0, :, 0, :].astype(jnp.float32)           # (Q, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)          # (Q, ds)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)          # (Q, ds)

    dA = dt * A                                         # (Q,) log-decay <= 0
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (col <= row).astype(jnp.float32)              # inclusive lower-tri
    cum = jax.lax.dot_general(tri, dA[:, None],
                              (((1,), (0,)), ((), ())))[:, 0]   # cumsum via MXU
    total = cum[chunk - 1]

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    # mask inside the exp: anti-causal entries are positive log-decays
    # whose exp overflows (inf * 0 = NaN)
    L = jnp.exp(jnp.where(tri > 0, cum[:, None] - cum[None, :], -1e30))
    W = scores * L * dt[None, :]
    y_intra = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())))   # (Q, hd)

    h_prev = state_ref[...]                              # (hd, ds)
    y_inter = jax.lax.dot_general(Cm, h_prev,
                                  (((1,), (1,)), ((), ())))         # (Q, hd)
    y_inter = y_inter * jnp.exp(cum)[:, None]

    decay_j = jnp.exp(total - cum) * dt                  # (Q,)
    state_ref[...] = jnp.exp(total) * h_prev + jax.lax.dot_general(
        x * decay_j[:, None], Bm, (((0,), (0,)), ((), ())))         # (hd, ds)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, *, chunk: int,
               interpret: bool = False):
    """x (B,S,nh,hd); dt (B,S,nh) f32 post-softplus; A (nh,) f32 negative;
    Bm/Cm (B,S,G,ds).  Returns (y (B,S,nh,hd), state (B,nh,hd,ds) f32).

    ``chunk`` must be a sublane-aligned divisor of S (the chunked SSD
    algebra is exact at any chunk; derive one with
    ``repro.kernels.plan.plan_for``)."""
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // G
    validate_tiling("mamba2_ssd", {"S": (S, chunk)}, depth_dims=(),
                    block_names={"S": "chunk"}, quantum=SUBLANE)
    n_chunks = S // chunk
    grid = (B, nh, n_chunks)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk),
        grid=grid,
        in_specs=[
            compat.smem_block_spec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, h, c: (b, c, h // hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[compat.vmem((hd, ds), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.reshape(nh, 1).astype(jnp.float32), x, dt, Bm, Cm)
    return y, state
