"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid step, validating correctness; on a real TPU
backend the same call sites compile to Mosaic.  ``interpret=None`` (the
default) auto-detects.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import (decode_attention as _da, flash_attention as _fa,
                           mamba2_ssd as _ssd, mfma_gemm as _gemm,
                           moe_gmm as _gmm)

__all__ = ["mfma_gemm", "flash_attention", "decode_attention", "mamba2_ssd",
           "moe_gmm"]


def _interp(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def mfma_gemm(a, b, c, *, block_m=256, block_n=256, block_k=512,
              interpret: Optional[bool] = None):
    return _gemm.mfma_gemm(a, b, c, block_m=block_m, block_n=block_n,
                           block_k=block_k, interpret=_interp(interpret))


def flash_attention(q, k, v, *, causal=True, block_q=512, block_kv=512,
                    interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv,
                               interpret=_interp(interpret))


def decode_attention(q, k, v, kv_len, *, block_kv=512,
                     interpret: Optional[bool] = None):
    return _da.decode_attention(q, k, v, kv_len, block_kv=block_kv,
                                interpret=_interp(interpret))


def mamba2_ssd(x, dt, A, Bm, Cm, *, chunk=256,
               interpret: Optional[bool] = None):
    return _ssd.mamba2_ssd(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=_interp(interpret))


def moe_gmm(x, w, *, block_m=128, block_n=128, block_k=512,
            interpret: Optional[bool] = None):
    return _gmm.moe_gmm(x, w, block_m=block_m, block_n=block_n,
                        block_k=block_k, interpret=_interp(interpret))
