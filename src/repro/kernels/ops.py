"""jit'd public wrappers for the Pallas kernels — plan-driven.

Tile sizes are no longer hard-coded per call site: each wrapper derives a
:class:`~repro.kernels.plan.TilePlan` from the operand shapes and the
target :class:`~repro.arch.DeviceSpec` (``device=`` may be a registry
name, a spec, or a machine; ``None`` plans for the default TPU).  A
caller can pass a precomputed ``plan=`` (e.g. the one a perf engine
reported) or pin individual blocks (``block_m=...``), which are validated
by the same alignment contract the planner enforces.

Ragged tails: with ``pad=True`` the wrapper plans padded geometry
(``plan_for(..., pad=True)``), zero-pads the operands up to the plan's
``dims``, masks the epilogue where padding would change the math
(``kv_len``-style key masking for attention; ``dt=0`` identity steps for
the SSD; zero contraction blocks are exact for the GEMMs) and slices the
output back to the caller's shape — so non-128-multiple model shapes run
the kernel path instead of raising.  The default ``pad=False`` keeps the
strict contract: misaligned shapes raise a descriptive ``ValueError``.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs in Python per grid step, validating correctness; on a
real TPU backend the same call sites compile to Mosaic.
``interpret=None`` (the default) auto-detects via ``repro.kernels.compat``.

Mesh execution: every wrapper whose catalog entry carries a
``KernelEntry.logical`` contract accepts ``sharded=True``, which wraps
the single-device call in ``jax.shard_map`` over the active mesh
(``parallel.api.set_mesh``).  In/out specs are derived from the same
logical-axis rules the dispatcher planned against
(``parallel.api.shard_assignment``), the body re-resolves the tile plan
on its *local* shapes (always with the pad/mask/slice path, so ragged
local shards stay eligible), and any resharding collectives GSPMD needs
to honor the in-specs stay in the surrounding XLA program — the
``pallas_call`` itself only ever sees one shard.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax < 0.5 (the supported floor)
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer jax moved it to the top level
    from jax import shard_map as _shard_map

from repro.kernels import (compat, decode_attention as _da,
                           flash_attention as _fa, mamba2_ssd as _ssd,
                           mfma_gemm as _gemm, moe_gmm as _gmm)
from repro.kernels.plan import TilePlan, get_kernel, plan_for
from repro.parallel import api as _papi

__all__ = ["mfma_gemm", "flash_attention", "decode_attention",
           "paged_decode_attention", "mamba2_ssd", "moe_gmm"]


def _mesh_assignment(kernel: str, shapes: Mapping[str, int],
                     plan: Optional[TilePlan]):
    """(mesh, ShardAssignment) for a ``sharded=True`` wrapper call."""
    if plan is not None:
        raise ValueError(
            f"{kernel}: sharded=True re-resolves the plan per shard; pass "
            "device= (and block pins) instead of plan=")
    mesh = _papi.current_mesh()
    if mesh is None:
        raise ValueError(
            f"{kernel}: sharded=True requires an active mesh "
            "(parallel.api.set_mesh)")
    logical = get_kernel(kernel).logical
    if logical is None:
        raise ValueError(
            f"{kernel}: no logical-axis contract in the catalog; this "
            "kernel cannot run under shard_map")
    return mesh, _papi.shard_assignment(shapes, logical, mesh)


def _resolve(kernel: str, plan: Optional[TilePlan],
             shapes: Mapping[str, int], dtype, device,
             overrides: Dict[str, Optional[int]],
             pad: bool) -> Tuple[TilePlan, Dict[str, int]]:
    """(plan, block kwargs): explicit plan > pinned blocks > planner."""
    if plan is None:
        plan = plan_for(kernel, shapes, dtype=dtype, device=device, pad=pad,
                        **overrides)
    elif plan.kernel != kernel:
        raise ValueError(f"{kernel}: got a plan for {plan.kernel!r}; "
                         f"derive one with plan_for({kernel!r}, ...)")
    blocks = plan.kwargs()
    blocks.update({k: v for k, v in overrides.items() if v is not None})
    return plan, blocks


def _padded(plan: TilePlan, dim: str, size: int) -> int:
    """The padded size the plan tiles for ``dim`` (>= the input size)."""
    target = plan.dims.get(dim, size)
    if target < size:
        raise ValueError(
            f"{plan.kernel}: plan tiles {dim}={target} but the operand has "
            f"{dim}={size}; re-plan for the actual shapes")
    return target


def _pad_axis(x, axis: int, target: int):
    """Zero-pad ``x`` along ``axis`` up to ``target`` (no-op when equal)."""
    have = x.shape[axis]
    if have == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - have)
    return jnp.pad(x, widths)


def mfma_gemm(a, b, c, *, device=None, plan: Optional[TilePlan] = None,
              block_m: Optional[int] = None, block_n: Optional[int] = None,
              block_k: Optional[int] = None, pad: bool = False,
              interpret: Optional[bool] = None):
    M, N, K = a.shape[0], b.shape[1], a.shape[1]
    plan, blocks = _resolve("mfma_gemm", plan, {"M": M, "N": N, "K": K},
                            a.dtype, device,
                            dict(block_m=block_m, block_n=block_n,
                                 block_k=block_k), pad)
    if pad:
        # zero rows/cols and zero contraction blocks are exact
        Mp, Np, Kp = (_padded(plan, d, s)
                      for d, s in (("M", M), ("N", N), ("K", K)))
        a = _pad_axis(_pad_axis(a, 0, Mp), 1, Kp)
        b = _pad_axis(_pad_axis(b, 0, Kp), 1, Np)
        c = _pad_axis(_pad_axis(c, 0, Mp), 1, Np)
    out = _gemm.mfma_gemm(a, b, c, **blocks,
                          interpret=compat.resolve_interpret(interpret))
    return out[:M, :N] if pad else out


def flash_attention(q, k, v, *, causal=True, kv_len=None, device=None,
                    plan: Optional[TilePlan] = None,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None, pad: bool = False,
                    interpret: Optional[bool] = None, sharded: bool = False):
    B, S, H, hd = q.shape
    T = k.shape[1]
    if sharded:
        mesh, asn = _mesh_assignment(
            "flash_attention",
            {"B": B, "S": S, "T": T, "H": H, "KV": k.shape[2], "hd": hd},
            plan)
        qkv_specs = (asn.spec("B", None, "H", None),
                     asn.spec("B", None, "KV", None),
                     asn.spec("B", None, "KV", None))

        def _body(ql, kl, vl, lens=None):
            return flash_attention(ql, kl, vl, causal=causal, kv_len=lens,
                                   device=device, block_q=block_q,
                                   block_kv=block_kv, pad=True,
                                   interpret=interpret)

        if kv_len is None:
            fn = _shard_map(_body, mesh=mesh, in_specs=qkv_specs,
                            out_specs=qkv_specs[0], check_rep=False)
            return fn(q, k, v)
        lens = jnp.asarray(kv_len, jnp.int32)
        len_spec = asn.spec("B") if lens.ndim else P()
        fn = _shard_map(_body, mesh=mesh, in_specs=qkv_specs + (len_spec,),
                        out_specs=qkv_specs[0], check_rep=False)
        return fn(q, k, v, lens)
    plan, blocks = _resolve("flash_attention", plan,
                            {"B": B, "S": S, "T": T, "H": H,
                             "KV": k.shape[2], "hd": hd},
                            q.dtype, device,
                            dict(block_q=block_q, block_kv=block_kv), pad)
    if pad:
        # padded keys are masked via kv_len; padded query rows are sliced
        Sp = _padded(plan, "S", S)
        Tp = _padded(plan, "T", T)
        q = _pad_axis(q, 1, Sp)
        k = _pad_axis(k, 1, Tp)
        v = _pad_axis(v, 1, Tp)
        if kv_len is None and Tp != T:
            kv_len = T
    out = _fa.flash_attention(q, k, v, causal=causal, kv_len=kv_len,
                              **blocks,
                              interpret=compat.resolve_interpret(interpret))
    return out[:, :S] if pad else out


def decode_attention(q, k, v, kv_len, *, device=None,
                     plan: Optional[TilePlan] = None,
                     block_kv: Optional[int] = None, pad: bool = False,
                     interpret: Optional[bool] = None, sharded: bool = False):
    B, H, hd = q.shape
    T = k.shape[1]
    if sharded:
        mesh, asn = _mesh_assignment(
            "decode_attention",
            {"B": B, "T": T, "H": H, "KV": k.shape[2], "hd": hd}, plan)
        lens = jnp.asarray(kv_len, jnp.int32)
        if lens.ndim == 0:
            lens = jnp.broadcast_to(lens, (B,))

        def _body(ql, kl, vl, ll):
            return decode_attention(ql, kl, vl, ll, device=device,
                                    block_kv=block_kv, pad=True,
                                    interpret=interpret)

        fn = _shard_map(_body, mesh=mesh,
                        in_specs=(asn.spec("B", "H", None),
                                  asn.spec("B", None, "KV", None),
                                  asn.spec("B", None, "KV", None),
                                  asn.spec("B")),
                        out_specs=asn.spec("B", "H", None), check_rep=False)
        return fn(q, k, v, lens)
    plan, blocks = _resolve("decode_attention", plan,
                            {"B": B, "T": T, "H": H, "KV": k.shape[2],
                             "hd": hd},
                            q.dtype, device, dict(block_kv=block_kv), pad)
    if pad:
        # the kernel's kv_len mask already ignores the padded cache tail
        Tp = _padded(plan, "T", T)
        k = _pad_axis(k, 1, Tp)
        v = _pad_axis(v, 1, Tp)
    return _da.decode_attention(q, k, v, kv_len, **blocks,
                                interpret=compat.resolve_interpret(interpret))


def paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                           device=None, plan: Optional[TilePlan] = None,
                           block_kv: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Flash-decode over a block-paged KV pool.

    q (B, H, hd); k_pool/v_pool (P, page, KV, hd); block_tables (B, NB)
    int32 physical block ids; kv_len (B,) int32 per-request lengths.
    The pool's page size IS the kv tile, so the plan's ``block_kv`` must
    equal it — the ``shapes["page"]`` pin makes the planner agree on
    every device; there is no ``pad=`` mode (pool geometry is aligned by
    construction via :class:`~repro.serve.PagedKVCache`).
    """
    B, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    NB = block_tables.shape[1]
    plan, blocks = _resolve("paged_decode_attention", plan,
                            {"B": B, "T": NB * page, "H": H, "KV": KV,
                             "hd": hd, "page": page},
                            q.dtype, device, dict(block_kv=block_kv), False)
    if blocks["block_kv"] != page:
        raise ValueError(
            f"paged_decode_attention: plan tiles block_kv="
            f"{blocks['block_kv']} but the KV pool's page size is {page}; "
            "plan with shapes['page'] (or block_kv=) pinned to the pool's "
            "page so the gather granularity matches")
    return _da.paged_decode_attention(
        q, k_pool, v_pool, block_tables, kv_len,
        interpret=compat.resolve_interpret(interpret))


def mamba2_ssd(x, dt, A, Bm, Cm, *, device=None,
               plan: Optional[TilePlan] = None,
               chunk: Optional[int] = None, pad: bool = False,
               interpret: Optional[bool] = None, sharded: bool = False):
    B, S, nh, hd = x.shape
    if sharded:
        mesh, asn = _mesh_assignment(
            "mamba2_ssd",
            {"B": B, "S": S, "nh": nh, "hd": hd, "ds": Bm.shape[3],
             "G": Bm.shape[2]}, plan)

        def _body(xl, dtl, Al, Bl, Cl):
            return mamba2_ssd(xl, dtl, Al, Bl, Cl, device=device,
                              chunk=chunk, pad=True, interpret=interpret)

        fn = _shard_map(_body, mesh=mesh,
                        in_specs=(asn.spec("B", None, "nh", None),
                                  asn.spec("B", None, "nh"),
                                  asn.spec("nh"),
                                  asn.spec("B", None, "G", None),
                                  asn.spec("B", None, "G", None)),
                        out_specs=(asn.spec("B", None, "nh", None),
                                   asn.spec("B", "nh", None, None)),
                        check_rep=False)
        return fn(x, dt, A, Bm, Cm)
    plan, blocks = _resolve("mamba2_ssd", plan,
                            {"B": B, "S": S, "nh": nh, "hd": hd,
                             "ds": Bm.shape[3]},
                            x.dtype, device, dict(chunk=chunk), pad)
    if pad:
        # dt=0 padded steps are identity state updates (exp(0)=1 decay,
        # zero input contribution), so the final state stays exact
        Sp = _padded(plan, "S", S)
        x = _pad_axis(x, 1, Sp)
        dt = _pad_axis(dt, 1, Sp)
        Bm = _pad_axis(Bm, 1, Sp)
        Cm = _pad_axis(Cm, 1, Sp)
    y, state = _ssd.mamba2_ssd(x, dt, A, Bm, Cm, **blocks,
                               interpret=compat.resolve_interpret(interpret))
    return (y[:, :S], state) if pad else (y, state)


def moe_gmm(x, w, *, device=None, plan: Optional[TilePlan] = None,
            block_m: Optional[int] = None, block_n: Optional[int] = None,
            block_k: Optional[int] = None, pad: bool = False,
            interpret: Optional[bool] = None, sharded: bool = False):
    E, C, K = x.shape
    N = w.shape[2]
    if sharded:
        mesh, asn = _mesh_assignment(
            "moe_gmm", {"E": E, "C": C, "K": K, "N": N}, plan)

        def _body(xl, wl):
            return moe_gmm(xl, wl, device=device, block_m=block_m,
                           block_n=block_n, block_k=block_k, pad=True,
                           interpret=interpret)

        fn = _shard_map(_body, mesh=mesh,
                        in_specs=(asn.spec("E", None, None),
                                  asn.spec("E", None, None)),
                        out_specs=asn.spec("E", None, None),
                        check_rep=False)
        return fn(x, w)
    plan, blocks = _resolve("moe_gmm", plan,
                            {"E": E, "C": C, "K": K, "N": N},
                            x.dtype, device,
                            dict(block_m=block_m, block_n=block_n,
                                 block_k=block_k), pad)
    if pad:
        # zero slot rows and zero contraction blocks are exact
        Cp, Kp, Np = (_padded(plan, d, s)
                      for d, s in (("C", C), ("K", K), ("N", N)))
        x = _pad_axis(_pad_axis(x, 1, Cp), 2, Kp)
        w = _pad_axis(_pad_axis(w, 1, Kp), 2, Np)
    out = _gmm.moe_gmm(x, w, **blocks,
                       interpret=compat.resolve_interpret(interpret))
    return out[:, :C, :N] if pad else out
