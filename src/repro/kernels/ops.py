"""jit'd public wrappers for the Pallas kernels — plan-driven.

Tile sizes are no longer hard-coded per call site: each wrapper derives a
:class:`~repro.kernels.plan.TilePlan` from the operand shapes and the
target :class:`~repro.arch.DeviceSpec` (``device=`` may be a registry
name, a spec, or a machine; ``None`` plans for the default TPU).  A
caller can pass a precomputed ``plan=`` (e.g. the one a perf engine
reported) or pin individual blocks (``block_m=...``), which are validated
by the same alignment contract the planner enforces.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs in Python per grid step, validating correctness; on a
real TPU backend the same call sites compile to Mosaic.
``interpret=None`` (the default) auto-detects via ``repro.kernels.compat``.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional

from repro.kernels import (compat, decode_attention as _da,
                           flash_attention as _fa, mamba2_ssd as _ssd,
                           mfma_gemm as _gemm, moe_gmm as _gmm)
from repro.kernels.plan import TilePlan, plan_for

__all__ = ["mfma_gemm", "flash_attention", "decode_attention", "mamba2_ssd",
           "moe_gmm"]


def _blocks(kernel: str, plan: Optional[TilePlan],
            shapes: Mapping[str, int], dtype, device,
            overrides: Dict[str, Optional[int]]) -> Dict[str, int]:
    """Resolve the block kwargs: explicit plan > pinned blocks > planner."""
    if plan is not None:
        if plan.kernel != kernel:
            raise ValueError(f"{kernel}: got a plan for {plan.kernel!r}; "
                             f"derive one with plan_for({kernel!r}, ...)")
        blocks = plan.kwargs()
        blocks.update({k: v for k, v in overrides.items() if v is not None})
        return blocks
    return plan_for(kernel, shapes, dtype=dtype, device=device,
                    **overrides).kwargs()


def mfma_gemm(a, b, c, *, device=None, plan: Optional[TilePlan] = None,
              block_m: Optional[int] = None, block_n: Optional[int] = None,
              block_k: Optional[int] = None,
              interpret: Optional[bool] = None):
    blocks = _blocks("mfma_gemm", plan,
                     {"M": a.shape[0], "N": b.shape[1], "K": a.shape[1]},
                     a.dtype, device,
                     dict(block_m=block_m, block_n=block_n, block_k=block_k))
    return _gemm.mfma_gemm(a, b, c, **blocks,
                           interpret=compat.resolve_interpret(interpret))


def flash_attention(q, k, v, *, causal=True, device=None,
                    plan: Optional[TilePlan] = None,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    interpret: Optional[bool] = None):
    B, S, H, hd = q.shape
    blocks = _blocks("flash_attention", plan,
                     {"B": B, "S": S, "T": k.shape[1], "H": H,
                      "KV": k.shape[2], "hd": hd},
                     q.dtype, device,
                     dict(block_q=block_q, block_kv=block_kv))
    return _fa.flash_attention(q, k, v, causal=causal, **blocks,
                               interpret=compat.resolve_interpret(interpret))


def decode_attention(q, k, v, kv_len, *, device=None,
                     plan: Optional[TilePlan] = None,
                     block_kv: Optional[int] = None,
                     interpret: Optional[bool] = None):
    B, H, hd = q.shape
    blocks = _blocks("decode_attention", plan,
                     {"B": B, "T": k.shape[1], "H": H, "KV": k.shape[2],
                      "hd": hd},
                     q.dtype, device, dict(block_kv=block_kv))
    return _da.decode_attention(q, k, v, kv_len, **blocks,
                                interpret=compat.resolve_interpret(interpret))


def mamba2_ssd(x, dt, A, Bm, Cm, *, device=None,
               plan: Optional[TilePlan] = None,
               chunk: Optional[int] = None,
               interpret: Optional[bool] = None):
    B, S, nh, hd = x.shape
    blocks = _blocks("mamba2_ssd", plan,
                     {"B": B, "S": S, "nh": nh, "hd": hd,
                      "ds": Bm.shape[3]},
                     x.dtype, device, dict(chunk=chunk))
    return _ssd.mamba2_ssd(x, dt, A, Bm, Cm, **blocks,
                           interpret=compat.resolve_interpret(interpret))


def moe_gmm(x, w, *, device=None, plan: Optional[TilePlan] = None,
            block_m: Optional[int] = None, block_n: Optional[int] = None,
            block_k: Optional[int] = None,
            interpret: Optional[bool] = None):
    E, C, K = x.shape
    blocks = _blocks("moe_gmm", plan,
                     {"E": E, "C": C, "K": K, "N": w.shape[2]},
                     x.dtype, device,
                     dict(block_m=block_m, block_n=block_n, block_k=block_k))
    return _gmm.moe_gmm(x, w, **blocks,
                        interpret=compat.resolve_interpret(interpret))
