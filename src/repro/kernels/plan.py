"""Spec-driven tile planning: ``TilePlan`` / ``plan_for`` / the catalog.

Every Pallas kernel in this package tiles its operands into fast-memory
blocks.  Those block sizes used to be five sets of hard-coded defaults
(``block_m=256`` here, ``block_kv=512`` there) with a silent
``min(block, dim)`` clamp that happily produced non-MXU-aligned tiles for
small dims.  This module replaces all of that with one planner that
derives tiles from the :class:`repro.arch.DeviceSpec` the same way the
cost engines derive their peaks:

* the **alignment quantum** comes from the compute topology —
  ``mxu_dim`` (the 128x128 systolic array) on TPUs; on MFMA cycle-table
  GPUs the same 128 width, which an MCE assembles as an 8x8 grid of
  16x16 micro-tiles, so one plan serves both device families;
* the **working-set budget** is ``DeviceSpec.vmem_bytes`` (VMEM per TPU
  core, an L2 staging slice on GPUs), with half reserved for the
  double-buffered prefetch pipeline;
* tiles are chosen as the largest aligned divisors of the problem dims
  under per-kernel caps, then shrunk greedily until the working set fits.

:func:`plan_for` is the entry point; :class:`KernelEntry` catalog rows
make kernels enumerable by name (op + oracle + planner), which the parity
test suite and the perf pipeline both iterate.  :func:`validate_tiling`
is the shared alignment contract the kernels themselves enforce — a
sub-128 or non-dividing block now raises ``ValueError`` naming the
offending dim instead of silently clamping.

This module is deliberately JAX-free: the perf engines call it for
representative tiles without touching the compute stack.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import (Callable, Dict, Mapping, Optional, Sequence, Tuple,
                    Union)

from repro.arch.registry import get_device
from repro.arch.spec import DeviceSpec

__all__ = [
    "TilePlan",
    "KernelEntry",
    "UnknownKernelError",
    "UnknownDtypeError",
    "plan_for",
    "register_kernel",
    "get_kernel",
    "list_kernels",
    "tile_align",
    "vmem_budget",
    "validate_tiling",
    "DEFAULT_PLAN_DEVICE",
    "SUBLANE",
]

#: Planning device when the caller names none (CPU containers have no
#: backend to introspect; the base TPU is the canonical Pallas target).
DEFAULT_PLAN_DEVICE = "tpu_v5e"

#: Fallback working-set budget for specs predating ``vmem_bytes``.
_DEFAULT_VMEM_BYTES = 16 << 20

#: Quantum for sequence-chunked (non-GEMM-tiled) dims: the VPU's 8-row
#: sublane granularity, not the MXU width.
SUBLANE = 8

#: numpy/JAX-style spellings -> the canonical HLO names of
#: ``repro.perf.hlo_ir.BYTES_PER_ELEM`` (the ONE byte table).
_DTYPE_ALIASES = {
    "float64": "f64", "fp64": "f64",
    "float32": "f32", "fp32": "f32",
    "float16": "f16", "fp16": "f16",
    "bfloat16": "bf16",
    "int64": "s64", "uint64": "u64",
    "int32": "s32", "i32": "s32", "uint32": "u32",
    "int16": "s16", "uint16": "u16",
    "int8": "s8", "i8": "s8", "uint8": "u8",
    "int4": "s4", "uint4": "u4",
    "float8_e4m3fn": "f8e4m3fn", "fp8": "f8e4m3fn",
    "float8_e5m2": "f8e5m2",
    "bool": "pred",
}


def _itemsize(dtype) -> int:
    """Bytes per element for a numpy/jax dtype object or an HLO name."""
    # lazy: hlo_ir is stdlib-only, but importing it at module scope would
    # pull the whole perf package under this deliberately light module
    from repro.perf.hlo_ir import BYTES_PER_ELEM
    name = str(dtype).lower()
    size = BYTES_PER_ELEM.get(_DTYPE_ALIASES.get(name, name))
    if size is not None:
        return size
    itemsize = getattr(dtype, "itemsize", None) or getattr(
        getattr(dtype, "dtype", None), "itemsize", None)
    if itemsize:
        return int(itemsize)
    raise UnknownDtypeError(
        f"unknown dtype {dtype!r}: cannot size tiles "
        f"(known: {sorted(BYTES_PER_ELEM)} and aliases)")


class UnknownKernelError(KeyError):
    """Raised for a kernel name not in the catalog."""


class UnknownDtypeError(ValueError):
    """Raised when a dtype cannot be sized for tile planning.

    Distinct from the plain ``ValueError`` contract violations
    (misalignment, budget overflow) so callers with a fallback dtype —
    ``repro.perf.engines.plan_for_dot`` — can retry on exactly this
    failure without masking real planning errors."""


def tile_align(spec: DeviceSpec) -> int:
    """The matrix-unit alignment quantum for GEMM-tiled dims on ``spec``."""
    return spec.mxu_dim if spec.mxu_count else 128


def vmem_budget(spec: DeviceSpec) -> int:
    """Plannable working-set bytes: half the fast-memory budget (the
    other half is the double-buffered prefetch pipeline)."""
    return (spec.vmem_bytes or _DEFAULT_VMEM_BYTES) // 2


# ---------------------------------------------------------------------------
# The alignment contract (shared with the kernels themselves)
# ---------------------------------------------------------------------------

def validate_tiling(kernel: str,
                    dims: Mapping[str, Tuple[int, int]], *,
                    align: int = 128,
                    depth_dims: Sequence[str] = ("K",),
                    block_names: Optional[Mapping[str, str]] = None,
                    quantum: Optional[int] = None) -> None:
    """Enforce the matrix-unit tiling contract.

    ``dims`` maps dim name -> ``(dim, block)``; ``block_names`` maps dim
    name -> the kernel's keyword for it (default ``block_<dim>``), used
    in error messages.  Every block must divide its dim and be a multiple
    of ``align``; dims listed in ``depth_dims`` (the contraction) may
    alternatively use one full-depth step (``block == dim``), which
    streams the whole reduction in a single grid iteration and so has no
    unaligned tile boundary.  ``quantum`` overrides ``align`` for dims
    that are sublane- rather than MXU-quantised (the SSD chunk).

    Raises ``ValueError`` naming the offending dim — the silent
    ``min(block, dim)`` clamp this replaces let e.g. M=64 run with a
    64-wide, non-MXU tile.
    """
    q = quantum or align
    names = block_names or {}
    for dim_name, (dim, block) in dims.items():
        block_name = names.get(dim_name, f"block_{dim_name.lower()}")
        if block < 1:
            raise ValueError(f"{kernel}: {block_name}={block} must be >= 1")
        if dim % block:
            raise ValueError(
                f"{kernel}: {dim_name}={dim} is not divisible by "
                f"{block_name}={block}; pad {dim_name} or pick a divisor "
                "(the XLA reference path handles ragged shapes)")
        if block % q and not (dim_name in depth_dims and block == dim):
            depth_hint = (" (a single full-depth step block == "
                          f"{dim_name} is also legal)"
                          if dim_name in depth_dims else "")
            raise ValueError(
                f"{kernel}: {block_name}={block} on {dim_name}={dim} is "
                f"not a multiple of the {q}-wide matrix-unit "
                f"tile{depth_hint}; pad {dim_name} to a multiple of {q} "
                "or use the XLA reference path for small shapes")


# ---------------------------------------------------------------------------
# TilePlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One kernel's chosen tiling on one device.

    ``blocks`` holds exactly the keyword arguments the ops-layer wrapper
    forwards to the kernel (``block_m``/``block_n``/``block_k``,
    ``block_q``/``block_kv``, ``chunk``); the perf engines record the
    same mapping in ``Report.plan`` so predicted and executed tiles can
    be cross-checked.
    """

    kernel: str
    device: str
    dtype: str
    blocks: Mapping[str, int]
    grid: Tuple[int, ...]
    vmem_bytes: int              # estimated per-core working set
    vmem_budget: int             # the budget it was sized against
    align: int
    padded: bool = False         # dims were rounded up (pad=True planning)
    #: dim name -> the (possibly padded) size the blocks tile.  With
    #: ``pad=True`` these are the quantum-rounded sizes the ops-layer
    #: wrappers pad inputs to (and slice outputs back from); with
    #: ``pad=False`` they equal the problem dims.
    dims: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def kwargs(self) -> Dict[str, int]:
        """The block keyword arguments for the ops-layer call."""
        return dict(self.blocks)

    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"kernel": self.kernel, "device": self.device,
                                "dtype": self.dtype, "align": self.align,
                                "vmem_bytes": self.vmem_bytes}
        d.update(self.blocks)
        return d

    def describe(self) -> str:
        blk = " ".join(f"{k.replace('block_', 'b')}={v}"
                       for k, v in self.blocks.items())
        return (f"{self.kernel}@{self.device} {blk} "
                f"(vmem {self.vmem_bytes / 2**20:.2f}/"
                f"{self.vmem_budget / 2**20:.0f} MiB)")


# ---------------------------------------------------------------------------
# Planner internals
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Dim:
    """One plannable dim: kernel keyword, dim name, size, quantum class."""

    block_name: str
    dim_name: str
    size: int
    sublane: bool = False        # sublane- instead of MXU-quantised
    depth: bool = False          # contraction dim (full-depth step legal)


def _pad_to(dim: int, quantum: int) -> int:
    return quantum * math.ceil(dim / quantum)


def _candidates(kernel: str, d: _Dim, *, align: int,
                pad: bool) -> Tuple[int, Sequence[int]]:
    """(possibly padded size, descending quantum-aligned divisor blocks)."""
    q = SUBLANE if d.sublane else align
    size = _pad_to(d.size, q) if pad else d.size
    if size % q:
        if d.depth:
            # a single full-depth step streams the whole reduction in one
            # grid iteration: no unaligned tile boundary to misalign
            return size, [size]
        raise ValueError(
            f"{kernel}: {d.dim_name}={size} is not a multiple of the "
            f"{q}-wide tile quantum; pad {d.dim_name} (plan with pad=True "
            "to model padded execution) or use the XLA reference path")
    units = size // q
    return size, [u * q for u in range(units, 0, -1) if units % u == 0]


def _plan(kernel: str, spec: DeviceSpec, dtype, *,
          dims: Sequence[_Dim],
          caps: Mapping[str, int],
          footprint: Callable[[Mapping[str, int], int], int],
          grid: Callable[[Mapping[str, int], Mapping[str, int]], Tuple[int, ...]],
          overrides: Mapping[str, Optional[int]],
          pad: bool) -> TilePlan:
    """Shared planner body: choose quantum-aligned divisor blocks under
    the caps, shrink to the VMEM budget, validate, emit the plan."""
    align = tile_align(spec)
    budget = vmem_budget(spec)
    dsz = _itemsize(dtype)

    sizes: Dict[str, int] = {}           # dim name -> (padded) size
    cands: Dict[str, Sequence[int]] = {} # block name -> descending choices
    chosen: Dict[str, int] = {}
    for d in dims:
        size, c = _candidates(kernel, d, align=align, pad=pad)
        sizes[d.dim_name] = size
        cands[d.block_name] = c
        ov = overrides.get(d.block_name)
        if ov is not None:
            chosen[d.block_name] = ov
        else:
            cap = caps[d.block_name]
            chosen[d.block_name] = next((x for x in c if x <= cap), c[-1])

    # shrink the largest free block until the working set fits
    while footprint(chosen, dsz) > budget:
        shrinkable = [(v, k) for k, v in chosen.items()
                      if overrides.get(k) is None
                      and any(x < v for x in cands[k])]
        if not shrinkable:
            if any(v is not None for v in overrides.values()):
                break                      # caller pinned blocks: honour them
            raise ValueError(
                f"{kernel}: no tiling fits the {budget}-byte working-set "
                f"budget on {spec.name} (minimum aligned tiles need "
                f"{footprint(chosen, dsz)} bytes); raise the device's "
                "vmem_bytes or shrink the problem")
        _, k = max(shrinkable)
        chosen[k] = next(x for x in cands[k] if x < chosen[k])

    validate_tiling(
        kernel,
        {d.dim_name: (sizes[d.dim_name], chosen[d.block_name])
         for d in dims if not d.sublane},
        align=align,
        depth_dims=tuple(d.dim_name for d in dims if d.depth),
        block_names={d.dim_name: d.block_name for d in dims})
    validate_tiling(
        kernel,
        {d.dim_name: (sizes[d.dim_name], chosen[d.block_name])
         for d in dims if d.sublane},
        align=align, depth_dims=(), quantum=SUBLANE,
        block_names={d.dim_name: d.block_name for d in dims})

    return TilePlan(kernel=kernel, device=spec.name, dtype=str(dtype),
                    blocks=dict(chosen), grid=grid(sizes, chosen),
                    vmem_bytes=footprint(chosen, dsz), vmem_budget=budget,
                    align=align, padded=pad, dims=dict(sizes))


# ---------------------------------------------------------------------------
# Per-kernel planners
# ---------------------------------------------------------------------------

def _plan_mfma_gemm(shapes, dtype, spec, overrides, pad):
    M, N, K = shapes["M"], shapes["N"], shapes["K"]
    return _plan(
        "mfma_gemm", spec, dtype,
        dims=(_Dim("block_m", "M", M), _Dim("block_n", "N", N),
              _Dim("block_k", "K", K, depth=True)),
        caps={"block_m": 256, "block_n": 256, "block_k": 512},
        # A + B tiles in the operand dtype; C tile + f32 accumulator.
        footprint=lambda b, dsz: (b["block_m"] * b["block_k"] * dsz
                                  + b["block_k"] * b["block_n"] * dsz
                                  + 2 * b["block_m"] * b["block_n"] * 4),
        grid=lambda s, b: (s["M"] // b["block_m"], s["N"] // b["block_n"],
                           s["K"] // b["block_k"]),
        overrides=overrides, pad=pad)


def _plan_moe_gmm(shapes, dtype, spec, overrides, pad):
    E, C, K, N = shapes["E"], shapes["C"], shapes["K"], shapes["N"]
    return _plan(
        "moe_gmm", spec, dtype,
        dims=(_Dim("block_m", "C", C), _Dim("block_n", "N", N),
              _Dim("block_k", "K", K, depth=True)),
        caps={"block_m": 128, "block_n": 128, "block_k": 512},
        footprint=lambda b, dsz: (b["block_m"] * b["block_k"] * dsz
                                  + b["block_k"] * b["block_n"] * dsz
                                  + b["block_m"] * b["block_n"] * (dsz + 4)),
        grid=lambda s, b: (E, s["C"] // b["block_m"], s["N"] // b["block_n"],
                           s["K"] // b["block_k"]),
        overrides=overrides, pad=pad)


def _plan_flash_attention(shapes, dtype, spec, overrides, pad):
    B, S, T = shapes["B"], shapes["S"], shapes["T"]
    H, KV, hd = shapes["H"], shapes["KV"], shapes["hd"]
    return _plan(
        "flash_attention", spec, dtype,
        dims=(_Dim("block_q", "S", S), _Dim("block_kv", "T", T)),
        caps={"block_q": 512, "block_kv": 512},
        # q/o tiles + K and V tiles + f32 (acc, m, l) scratch.
        footprint=lambda b, dsz: (2 * b["block_q"] * hd * dsz
                                  + 2 * b["block_kv"] * hd * dsz
                                  + b["block_q"] * (hd + 2) * 4),
        grid=lambda s, b: (B * KV * (H // KV), s["S"] // b["block_q"],
                           s["T"] // b["block_kv"]),
        overrides=overrides, pad=pad)


def _plan_decode_attention(shapes, dtype, spec, overrides, pad):
    B, T = shapes["B"], shapes["T"]
    H, KV, hd = shapes["H"], shapes["KV"], shapes["hd"]
    G = H // KV
    return _plan(
        "decode_attention", spec, dtype,
        dims=(_Dim("block_kv", "T", T),),
        caps={"block_kv": 512},
        footprint=lambda b, dsz: (2 * G * hd * dsz
                                  + 2 * b["block_kv"] * hd * dsz
                                  + G * (hd + 2) * 4),
        grid=lambda s, b: (B * KV, s["T"] // b["block_kv"]),
        overrides=overrides, pad=pad)


def _plan_paged_decode_attention(shapes, dtype, spec, overrides, pad):
    """Same per-step geometry as ``decode_attention`` — one (G, block_kv)
    score tile and (m, l, acc) scratch — but ``block_kv`` doubles as the
    KV-pool page size.  An optional ``shapes["page"]`` pins ``block_kv``
    to an existing pool's page so plans always match pool geometry on
    every device; omit it (the :class:`~repro.serve.PagedKVCache`
    constructor does) to let the planner choose the page size."""
    B, T = shapes["B"], shapes["T"]
    H, KV, hd = shapes["H"], shapes["KV"], shapes["hd"]
    G = H // KV
    page = shapes.get("page")
    if page is not None and overrides.get("block_kv") is None:
        overrides = dict(overrides, block_kv=int(page))
    return _plan(
        "paged_decode_attention", spec, dtype,
        dims=(_Dim("block_kv", "T", T),),
        caps={"block_kv": 512},
        # q/o tiles + one K and one V page + f32 (m, l, acc) scratch.
        footprint=lambda b, dsz: (2 * G * hd * dsz
                                  + 2 * b["block_kv"] * hd * dsz
                                  + G * (hd + 2) * 4),
        grid=lambda s, b: (B * KV, s["T"] // b["block_kv"]),
        overrides=overrides, pad=pad)


def _plan_mamba2_ssd(shapes, dtype, spec, overrides, pad):
    B, S, nh = shapes["B"], shapes["S"], shapes["nh"]
    hd, ds = shapes["hd"], shapes["ds"]
    return _plan(
        "mamba2_ssd", spec, dtype,
        # the chunk feeds (Q x Q) intra-chunk matmuls; chunked SSD stays
        # exact at any chunk, so it is sublane- rather than MXU-quantised
        dims=(_Dim("chunk", "S", S, sublane=True),),
        caps={"chunk": 256},
        footprint=lambda b, dsz: (2 * b["chunk"] * hd * dsz
                                  + 2 * b["chunk"] * ds * dsz
                                  + b["chunk"] * (dsz + 4)
                                  + 3 * b["chunk"] * b["chunk"] * 4
                                  + hd * ds * 4),
        grid=lambda s, b: (B, nh, s["S"] // b["chunk"]),
        overrides=overrides, pad=pad)


# ---------------------------------------------------------------------------
# Catalog
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One enumerable kernel: op entry point, oracle, planner, blocks."""

    name: str
    op: str                      # "module:attr" of the ops-layer wrapper
    ref: str                     # "module:attr" of the jnp oracle
    planner: Callable
    block_names: Tuple[str, ...]
    doc: str = ""
    #: Mesh-eligibility contract: problem dim -> logical axis name
    #: (``parallel.api`` rules).  Dims sharing a logical axis co-shard;
    #: dims absent here stay replicated under ``shard_map``.  ``None``
    #: means the kernel has no sharded execution path and dispatch keeps
    #: the legacy whole-op fallback on a mesh.  Plain strings only — the
    #: catalog stays importable without JAX.
    logical: Optional[Mapping[str, str]] = None

    def _resolve(self, target: str):
        mod, attr = target.split(":")
        return getattr(importlib.import_module(mod), attr)

    @property
    def op_fn(self):
        return self._resolve(self.op)

    @property
    def ref_fn(self):
        return self._resolve(self.ref)


_CATALOG: Dict[str, KernelEntry] = {}


def register_kernel(entry: KernelEntry, *,
                    replace: bool = False) -> KernelEntry:
    if entry.name in _CATALOG and not replace:
        raise ValueError(f"kernel {entry.name!r} is already registered")
    _CATALOG[entry.name] = entry
    return entry


def get_kernel(name: str) -> KernelEntry:
    try:
        return _CATALOG[name]
    except KeyError:
        raise UnknownKernelError(
            f"unknown kernel {name!r}; registered: {sorted(_CATALOG)}"
        ) from None


def list_kernels() -> Sequence[str]:
    return sorted(_CATALOG)


# ---------------------------------------------------------------------------
# plan_for
# ---------------------------------------------------------------------------

def _as_spec(device) -> DeviceSpec:
    if device is None:
        return get_device(DEFAULT_PLAN_DEVICE)
    if isinstance(device, DeviceSpec):
        return device
    spec = getattr(device, "spec", None)      # MachineModel duck-type
    if isinstance(spec, DeviceSpec):
        return spec
    return get_device(str(device))


def plan_for(kernel: str, shapes: Mapping[str, int], *,
             dtype="bfloat16",
             device: Union[None, str, DeviceSpec, object] = None,
             pad: bool = False,
             **overrides: Optional[int]) -> TilePlan:
    """Derive the tile plan for ``kernel`` on ``device``.

    ``shapes`` names the kernel's problem dims (``mfma_gemm`` wants
    M/N/K, ``moe_gmm`` E/C/K/N, ``flash_attention`` B/S/T/H/KV/hd,
    ``decode_attention`` B/T/H/KV/hd, ``mamba2_ssd`` B/S/nh/hd/ds).
    ``device`` is a registry name, a :class:`DeviceSpec`, or anything
    with a ``.spec`` (a ``MachineModel``); ``None`` plans for
    ``DEFAULT_PLAN_DEVICE``.  ``pad=True`` rounds dims up to the
    alignment quantum first — the perf engines use this to model padded
    execution of arbitrary HLO dots; the execution path leaves it off so
    misaligned shapes raise.  Keyword overrides (``block_m=...``) pin
    individual blocks, which are then validated rather than chosen.
    """
    entry = get_kernel(kernel)
    spec = _as_spec(device)
    overrides = {k: v for k, v in overrides.items() if v is not None}
    unknown = set(overrides) - set(entry.block_names)
    if unknown:
        raise ValueError(f"{kernel}: unknown block override(s) "
                         f"{sorted(unknown)}; expected {entry.block_names}")
    return entry.planner(dict(shapes), dtype, spec, overrides, pad)


for _entry in (
    KernelEntry(
        name="mfma_gemm", op="repro.kernels.ops:mfma_gemm",
        ref="repro.kernels.ref:mfma_gemm_ref", planner=_plan_mfma_gemm,
        block_names=("block_m", "block_n", "block_k"),
        doc="MXU-tiled accumulate-GEMM D = C + A @ B (the MFMA contract)"),
    KernelEntry(
        name="moe_gmm", op="repro.kernels.ops:moe_gmm",
        ref="repro.kernels.ref:moe_gmm_ref", planner=_plan_moe_gmm,
        block_names=("block_m", "block_n", "block_k"),
        doc="grouped per-expert matmul (E, C, K) @ (E, K, N)",
        logical={"E": "expert"}),
    KernelEntry(
        name="flash_attention", op="repro.kernels.ops:flash_attention",
        ref="repro.kernels.ref:flash_attention_ref",
        planner=_plan_flash_attention,
        block_names=("block_q", "block_kv"),
        doc="blockwise online-softmax causal GQA attention",
        logical={"B": "batch", "H": "heads", "KV": "heads"}),
    KernelEntry(
        name="decode_attention", op="repro.kernels.ops:decode_attention",
        ref="repro.kernels.ref:decode_attention_ref",
        planner=_plan_decode_attention,
        block_names=("block_kv",),
        doc="flash-decode: one query token vs a long KV cache",
        logical={"B": "batch", "H": "heads", "KV": "heads"}),
    KernelEntry(
        name="paged_decode_attention",
        op="repro.kernels.ops:paged_decode_attention",
        ref="repro.kernels.ref:paged_decode_attention_ref",
        planner=_plan_paged_decode_attention,
        block_names=("block_kv",),
        doc="flash-decode over a block-paged KV pool via a block table"),
    KernelEntry(
        name="mamba2_ssd", op="repro.kernels.ops:mamba2_ssd",
        ref="repro.kernels.ref:mamba2_ssd_ref", planner=_plan_mamba2_ssd,
        block_names=("chunk",),
        doc="chunked SSD (Mamba2): quadratic intra-chunk, linear across",
        logical={"B": "batch", "nh": "heads", "G": "heads"}),
):
    register_kernel(_entry)
