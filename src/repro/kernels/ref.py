"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each oracle is written in the most *obviously correct* formulation —
full-softmax attention, per-time-step SSM recurrence — deliberately NOT the
blocked algorithms the kernels use, so the allclose sweeps validate the
algebra, not just the implementation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["mfma_gemm_ref", "flash_attention_ref", "decode_attention_ref",
           "paged_decode_attention_ref", "mamba2_ssd_ref", "moe_gmm_ref"]


def mfma_gemm_ref(a, b, c):
    """D = C + A @ B with f32 accumulation (the MFMA contract)."""
    d = c.astype(jnp.float32) + jnp.dot(a.astype(jnp.float32),
                                        b.astype(jnp.float32))
    return d.astype(c.dtype)


def _grouped_full_attn(q, k, v, *, causal, kv_len=None):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(T)[None, :]
        s = jnp.where((j <= i)[None, None, None], s, -jnp.inf)
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if kl.ndim == 1:                      # per-request (B,) lengths
            kl = kl[:, None, None, None, None]
        s = jnp.where(jnp.arange(T)[None, None, None, None] < kl, s,
                      -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, -1).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True):
    """Full-softmax grouped attention (no blocking)."""
    return _grouped_full_attn(q, k, v, causal=causal)


def decode_attention_ref(q, k, v, kv_len):
    """q (B, H, hd) single-token attention vs cache prefix < kv_len
    (an int32 scalar, or a per-request (B,) vector)."""
    o = _grouped_full_attn(q[:, None], k, v, causal=False, kv_len=kv_len)
    return o[:, 0]


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, kv_len):
    """Oracle for the paged kernel: gather each request's blocks from the
    (P, bs, KV, hd) pool into a dense (B, NB*bs, KV, hd) cache, then run
    the plain decode oracle with per-request lengths."""
    B = q.shape[0]
    bs, KV, hd = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    k = k_pool[block_tables].reshape(B, -1, KV, hd)
    v = v_pool[block_tables].reshape(B, -1, KV, hd)
    return decode_attention_ref(q, k, v, kv_len)


def mamba2_ssd_ref(x, dt, A, Bm, Cm):
    """Per-time-step SSM recurrence (sequential oracle; no chunking).

    h_t = exp(dt_t A) h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t
    x (B,S,nh,hd); dt (B,S,nh); A (nh,); Bm/Cm (B,S,G,ds).
    Returns (y (B,S,nh,hd), h_final (B,nh,hd,ds)) — matches mamba2_ssd.
    """
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // G

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,nh,hd),(B,nh),(B,G,ds)
        bt = jnp.repeat(bt, hpg, axis=1)
        ct = jnp.repeat(ct, hpg, axis=1)
        da = jnp.exp(dtt * A)                       # (B,nh)
        h = da[..., None, None] * h + jnp.einsum(
            "bhp,bhs->bhps", dtt[..., None] * xt.astype(jnp.float32),
            bt.astype(jnp.float32))
        y = jnp.einsum("bhs,bhps->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


def moe_gmm_ref(x, w):
    """(E, C, K) @ (E, K, N) -> (E, C, N), f32 accumulation."""
    y = jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.astype(x.dtype)
