"""Kernel-vs-reference dispatch: the model layer's entry to the kernels.

``repro.models`` mixers carry two formulations of every catalog-backed op:
the GSPMD-shardable XLA reference (the formulation the dry-run compiles)
and the Pallas kernel that embodies the MFMA contract.  This module is the
single place that picks between them.  :func:`decide` plans the kernel's
tiles for the concrete shapes (``pad=True`` by default, so ragged model
shapes — odd sequence lengths, capacity-trimmed MoE groups — stay
eligible via the ops-layer pad/mask/slice path) and returns a
:class:`Decision`; anything the kernel path cannot support falls back to
the reference with a *logged reason* instead of an exception:

* mesh-sharded execution (the kernels are single-device; GSPMD cannot
  partition a ``pallas_call``) — callers pass ``sharded=True``;
* shapes/dtypes the planner rejects even with padding (working set over
  the VMEM budget, unsizable dtype);
* op-specific contract mismatches the caller detects (a custom softmax
  scale, MLA's ``v_head_dim != qk_dim``) — reported via :func:`fallback`.

Decisions are recorded per kernel (:func:`last_decisions`) so the parity
suite can assert the kernel path actually ran rather than silently
falling back; fall-back reasons are logged once per (kernel, reason) on
the ``repro.kernels.dispatch`` logger.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Mapping, Optional, Union

from repro.arch.spec import DeviceSpec
from repro.kernels.plan import TilePlan, UnknownKernelError, plan_for

__all__ = ["Decision", "decide", "fallback", "last_decisions",
           "reset_decisions"]

log = logging.getLogger(__name__)

#: kernel name -> the most recent Decision (trace-time introspection).
_DECISIONS: Dict[str, "Decision"] = {}
#: (kernel, reason) pairs already logged — fallback log lines fire once.
_LOGGED: set = set()


@dataclasses.dataclass(frozen=True)
class Decision:
    """One dispatch outcome: kernel path (with its plan) or reference."""

    kernel: str
    use_kernel: bool
    reason: str                      # "ok" or why the reference path won
    plan: Optional[TilePlan] = None


def _record(decision: Decision) -> Decision:
    _DECISIONS[decision.kernel] = decision
    if not decision.use_kernel:
        key = (decision.kernel, decision.reason)
        if key not in _LOGGED:
            _LOGGED.add(key)
            log.info("dispatch %s -> XLA reference: %s",
                     decision.kernel, decision.reason)
    return decision


def fallback(kernel: str, reason: str) -> Decision:
    """Record a caller-detected fallback (op-specific contract mismatch)."""
    return _record(Decision(kernel=kernel, use_kernel=False, reason=reason))


def decide(kernel: str, shapes: Mapping[str, int], *,
           dtype="bfloat16",
           device: Union[None, str, DeviceSpec, object] = None,
           pad: bool = True,
           sharded: bool = False) -> Decision:
    """Pick kernel-vs-reference for ``kernel`` at ``shapes``.

    Plans tiles with ``pad=True`` so non-quantum-multiple shapes run the
    kernel via the ops-layer pad/mask/slice path; a planning failure
    (or ``sharded=True``) yields a reference Decision carrying the reason.
    Shapes are static under ``jax.jit`` tracing, so decisions are made at
    trace time and cost nothing per step.
    """
    if sharded:
        return fallback(kernel, "mesh-sharded execution: the Pallas "
                                "kernels are single-device (GSPMD cannot "
                                "partition a pallas_call)")
    try:
        plan = plan_for(kernel, shapes, dtype=dtype, device=device, pad=pad)
    except (UnknownKernelError, ValueError) as e:
        return fallback(kernel, str(e))
    return _record(Decision(kernel=kernel, use_kernel=True, reason="ok",
                            plan=plan))


def last_decisions() -> Dict[str, Decision]:
    """Most recent Decision per kernel (for tests / introspection)."""
    return dict(_DECISIONS)


def reset_decisions() -> None:
    _DECISIONS.clear()
    _LOGGED.clear()
