"""Kernel-vs-reference dispatch: the model layer's entry to the kernels.

``repro.models`` mixers carry two formulations of every catalog-backed op:
the GSPMD-shardable XLA reference (the formulation the dry-run compiles)
and the Pallas kernel that embodies the MFMA contract.  This module is the
single place that picks between them.  :func:`decide` plans the kernel's
tiles for the concrete shapes (``pad=True`` by default, so ragged model
shapes — odd sequence lengths, capacity-trimmed MoE groups — stay
eligible via the ops-layer pad/mask/slice path) and returns a
:class:`Decision`; anything the kernel path cannot support falls back to
the reference with a *logged reason* instead of an exception:

* mesh-sharded execution of a kernel with no logical-axis contract
  (``KernelEntry.logical is None``: a bare ``pallas_call`` is
  single-device and GSPMD cannot partition it) — callers pass
  ``sharded=True``;
* a mesh-sharded op whose *local* shard fails the tiling/VMEM contract
  (the planner rejects the per-shard shapes);
* shapes/dtypes the planner rejects even with padding (working set over
  the VMEM budget, unsizable dtype);
* op-specific contract mismatches the caller detects (a custom softmax
  scale, MLA's ``v_head_dim != qk_dim``) — reported via :func:`fallback`.

When ``sharded=True`` and the kernel carries a logical map, dispatch
resolves the op's *per-shard* shapes through the active mesh
(``parallel.api.local_shapes``) and plans tiles against those; the ops
layer then executes the kernel under ``shard_map`` with in/out specs
derived from the same logical rules, so collectives stay in the
surrounding XLA program and the ``pallas_call`` only ever sees its shard.

Decisions are recorded per kernel (:func:`last_decisions`) so the parity
suite can assert the kernel path actually ran rather than silently
falling back.  The log is *thread-local* and scopable: wrap a trace in
:func:`decision_scope` to capture exactly the decisions it makes without
leakage from (or into) surrounding code; fall-back reasons are logged
once per (kernel, reason) per scope on the ``repro.kernels.dispatch``
logger.
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
from typing import Dict, Iterator, Mapping, Optional, Union

from repro.arch.spec import DeviceSpec
from repro.kernels.plan import (TilePlan, UnknownKernelError, get_kernel,
                                plan_for)

__all__ = ["Decision", "decide", "decision_scope", "fallback",
           "last_decisions", "reset_decisions"]

log = logging.getLogger(__name__)


class _Log(threading.local):
    """Per-thread decision log (decisions happen at trace time, on the
    tracing thread — a global dict would interleave concurrent traces)."""

    def __init__(self):
        #: kernel name -> the most recent Decision.
        self.decisions: Dict[str, "Decision"] = {}
        #: (kernel, reason) pairs already logged — log lines fire once.
        self.logged: set = set()


_LOG = _Log()


@dataclasses.dataclass(frozen=True)
class Decision:
    """One dispatch outcome: kernel path (with its plan) or reference."""

    kernel: str
    use_kernel: bool
    reason: str                      # "ok" or why the reference path won
    plan: Optional[TilePlan] = None
    #: True when the kernel path runs under ``shard_map`` — ``plan`` is
    #: then the *per-shard* plan and ``local_dims`` the shard's shapes.
    sharded: bool = False
    local_dims: Optional[Mapping[str, int]] = None


def _record(decision: Decision) -> Decision:
    _LOG.decisions[decision.kernel] = decision
    if not decision.use_kernel:
        key = (decision.kernel, decision.reason)
        if key not in _LOG.logged:
            _LOG.logged.add(key)
            log.info("dispatch %s -> XLA reference: %s",
                     decision.kernel, decision.reason)
    return decision


def fallback(kernel: str, reason: str) -> Decision:
    """Record a caller-detected fallback (op-specific contract mismatch)."""
    return _record(Decision(kernel=kernel, use_kernel=False, reason=reason))


def _decide_sharded(kernel: str, shapes: Mapping[str, int], *,
                    dtype, device, pad, mesh, axes) -> Decision:
    from repro.parallel import api as papi

    try:
        logical = get_kernel(kernel).logical
    except UnknownKernelError as e:
        return fallback(kernel, str(e))
    if logical is None:
        return fallback(
            kernel, "mesh-sharded execution: this kernel has no "
                    "logical-axis contract, so the pallas_call stays "
                    "single-device (GSPMD cannot partition it)")
    mesh = mesh if mesh is not None else papi.current_mesh()
    if mesh is None:
        return fallback(
            kernel, "mesh-sharded execution requested without an active "
                    "mesh (no parallel.api.set_mesh context or mesh=)")
    try:
        local = papi.local_shapes(shapes, logical, mesh, axes)
        plan = plan_for(kernel, local, dtype=dtype, device=device, pad=pad)
    except (UnknownKernelError, ValueError) as e:
        return fallback(
            kernel, f"mesh-sharded local shard fails the tiling/VMEM "
                    f"contract: {e}")
    return _record(Decision(kernel=kernel, use_kernel=True, reason="ok",
                            plan=plan, sharded=True, local_dims=local))


def decide(kernel: str, shapes: Mapping[str, int], *,
           dtype="bfloat16",
           device: Union[None, str, DeviceSpec, object] = None,
           pad: bool = True,
           sharded: bool = False,
           mesh=None,
           axes=None) -> Decision:
    """Pick kernel-vs-reference for ``kernel`` at ``shapes``.

    Plans tiles with ``pad=True`` so non-quantum-multiple shapes run the
    kernel via the ops-layer pad/mask/slice path; a planning failure
    yields a reference Decision carrying the reason.  With
    ``sharded=True`` the plan is made against the op's *per-shard* shapes
    on the active mesh (or ``mesh=``/``axes=`` overrides) and the
    returned Decision has ``sharded=True`` — the ops wrapper must then be
    called with ``sharded=True`` so the kernel runs under ``shard_map``.
    Kernels without a ``KernelEntry.logical`` contract keep the legacy
    whole-op fallback.  Shapes are static under ``jax.jit`` tracing, so
    decisions are made at trace time and cost nothing per step.
    """
    if sharded:
        return _decide_sharded(kernel, shapes, dtype=dtype, device=device,
                               pad=pad, mesh=mesh, axes=axes)
    try:
        plan = plan_for(kernel, shapes, dtype=dtype, device=device, pad=pad)
    except (UnknownKernelError, ValueError) as e:
        return fallback(kernel, str(e))
    return _record(Decision(kernel=kernel, use_kernel=True, reason="ok",
                            plan=plan))


def last_decisions() -> Dict[str, Decision]:
    """Most recent Decision per kernel (for tests / introspection)."""
    return dict(_LOG.decisions)


def reset_decisions() -> None:
    _LOG.decisions.clear()
    _LOG.logged.clear()


@contextlib.contextmanager
def decision_scope() -> Iterator[Dict[str, Decision]]:
    """Capture exactly the decisions made inside the ``with`` block.

    Yields the live dict (kernel name -> Decision) that records them; the
    surrounding log is saved and restored, so scopes neither see nor
    clobber outer decisions — tests wrap one trace each instead of
    relying on global ``reset_decisions()`` hygiene.
    """
    prev = (_LOG.decisions, _LOG.logged)
    _LOG.decisions, _LOG.logged = {}, set()
    try:
        yield _LOG.decisions
    finally:
        _LOG.decisions, _LOG.logged = prev
