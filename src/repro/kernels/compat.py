"""Version-adaptive Pallas/TPU shim: the ONE place that touches ``pltpu``.

JAX has renamed pieces of the Pallas TPU surface across the 0.4.x line —
most notably the compiler-parameters dataclass, spelled
``pltpu.TPUCompilerParams`` up to ~0.4.3x and ``pltpu.CompilerParams``
afterwards.  Every kernel in ``repro.kernels`` used to call one spelling
directly, so an unpinned ``jax[cpu]`` silently killed the whole compute
layer with ``AttributeError`` at trace time (34 red tests).

All five kernels now route through this module instead:

* :func:`tpu_compiler_params` — dimension-semantics compiler params under
  either spelling, with a clear error naming the installed JAX version if
  neither exists;
* :func:`vmem` / :func:`smem_block_spec` — VMEM scratch shapes and
  SMEM-resident block specs;
* :func:`default_interpret` / :func:`resolve_interpret` — backend
  detection for interpret-mode-on-CPU (the container has no TPU; the same
  call sites compile to Mosaic on real hardware).

Nothing outside this file may import ``jax.experimental.pallas.tpu``.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "PallasCompatError",
    "tpu_compiler_params",
    "vmem",
    "smem_block_spec",
    "prefetch_grid_spec",
    "default_interpret",
    "resolve_interpret",
]

#: Spellings of the TPU compiler-params dataclass, newest first.
_COMPILER_PARAMS_NAMES = ("CompilerParams", "TPUCompilerParams")


class PallasCompatError(RuntimeError):
    """The installed JAX exposes none of the known Pallas TPU spellings."""


def _compiler_params_cls():
    for name in _COMPILER_PARAMS_NAMES:
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise PallasCompatError(
        f"jax {jax.__version__}: jax.experimental.pallas.tpu exposes "
        f"neither of {_COMPILER_PARAMS_NAMES} — repro.kernels supports "
        "jax>=0.4.30,<0.5 (see requirements.txt); install a version in "
        "that range or add the new spelling to repro.kernels.compat")


def tpu_compiler_params(*, dimension_semantics: Sequence[str]):
    """Compiler params carrying ``dimension_semantics`` for a grid.

    Each entry is ``"parallel"`` (grid dimension may be executed in any
    order / in parallel) or ``"arbitrary"`` (sequential — carries VMEM
    scratch state across steps, e.g. a K loop's accumulator).
    """
    return _compiler_params_cls()(
        dimension_semantics=tuple(dimension_semantics))


def vmem(shape: Tuple[int, ...], dtype):
    """A VMEM scratch buffer spec (``scratch_shapes=`` entry)."""
    return pltpu.VMEM(shape, dtype)


def smem_block_spec(block_shape: Optional[Tuple[int, ...]] = None,
                    index_map=None) -> pl.BlockSpec:
    """A BlockSpec placing the operand in SMEM (scalars / tiny tables)."""
    if block_shape is None and index_map is None:
        return pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.BlockSpec(block_shape, index_map, memory_space=pltpu.SMEM)


def prefetch_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                       out_specs, scratch_shapes=()):
    """A grid spec whose first ``num_scalar_prefetch`` operands are SMEM
    scalars available *before* the kernel body runs — index maps receive
    them as trailing refs, so block indices can be data-dependent (the
    paged-attention block-table gather).  Raises :class:`PallasCompatError`
    if the installed JAX predates scalar prefetch."""
    cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
    if cls is None:
        raise PallasCompatError(
            f"jax {jax.__version__}: jax.experimental.pallas.tpu has no "
            "PrefetchScalarGridSpec — repro.kernels needs jax>=0.4.30,<0.5 "
            "(see requirements.txt) for the paged decode-attention kernel")
    return cls(num_scalar_prefetch=num_scalar_prefetch, grid=tuple(grid),
               in_specs=list(in_specs), out_specs=out_specs,
               scratch_shapes=list(scratch_shapes))


def default_interpret() -> bool:
    """True when there is no TPU backend: run kernels in interpret mode
    (the kernel body executes in Python per grid step — correctness-exact,
    not performance-shaped)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> auto-detect; an explicit bool wins."""
    if interpret is None:
        return default_interpret()
    return interpret
