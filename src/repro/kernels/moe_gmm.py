"""Grouped (per-expert) matmul Pallas kernel for capacity-based MoE.

x (E, C, K) @ w (E, K, N) -> (E, C, N): one MXU-tiled GEMM per expert,
grid (E, C/bm, N/bn, K/bk) with the expert dimension outermost-parallel
(each expert's tiles are independent — on a real TPU the E axis is also
the EP shard axis, so each device runs its local experts only).  Shares
the accumulate-in-VMEM pattern with mfma_gemm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import validate_tiling

__all__ = ["moe_gmm"]


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def moe_gmm(x: jax.Array, w: jax.Array, *, block_m: int,
            block_n: int, block_k: int,
            interpret: bool = False) -> jax.Array:
    """x: (E, C, K), w: (E, K, N) -> (E, C, N) with f32 accumulation.

    Blocks tile the per-expert (C, K) @ (K, N) matmul and must be
    MXU-aligned divisors of C/N/K (block_k may be one full-depth step) —
    derive them with ``repro.kernels.plan.plan_for``.
    """
    E, C, K = x.shape
    E2, K2, N = w.shape
    if E != E2 or K != K2:
        raise ValueError(
            f"moe_gmm: incompatible operands x{x.shape} @ w{w.shape}; "
            "need x(E, C, K) and w(E, K, N) with matching expert count E "
            "and contraction depth K")
    validate_tiling("moe_gmm", {"C": (C, block_m), "N": (N, block_n),
                                "K": (K, block_k)},
                    block_names={"C": "block_m"})
    n_k = K // block_k
    grid = (E, C // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_k, block_n), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), x.dtype),
        scratch_shapes=[compat.vmem((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
