"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Grid (B*KV, T/block_kv): the KV sequence is the sequential dimension; the
G query heads of each KV group ride along inside the tile ((G, hd) query
block), so the kernel's inner product is an MXU-friendly (G, hd) x
(hd, block_kv) matmul even for G as small as 4-8.  Running (m, l, acc)
scratch identical to the prefill kernel; ``kv_len`` masks unwritten cache
slots.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import validate_tiling

__all__ = ["decode_attention"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, n_kv: int, block_kv: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]

    @pl.when(ki * block_kv < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (G, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bkv)
        col = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(col < kv_len, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ()))
        ).astype(jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, block_kv: int,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v: (B, T, KV, hd); kv_len: scalar int32.

    Returns (B, H, hd) attention output over cache positions < kv_len.
    ``block_kv`` must be an MXU-aligned divisor of the cache length T
    (derive it with ``repro.kernels.plan.plan_for``).
    """
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    validate_tiling("decode_attention", {"T": (T, block_kv)},
                    depth_dims=(), block_names={"T": "block_kv"})

    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32)[None], (1,))

    grid = (B * KV, T // block_kv)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, n_kv=T // block_kv,
                          block_kv=block_kv),
        grid=grid,
        in_specs=[
            compat.smem_block_spec(),
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)
