"""Flash-decode Pallas kernels: one query token vs a long KV cache.

Two variants share one kernel body:

* :func:`decode_attention` — the contiguous cache.  Grid (B*KV,
  T/block_kv): the KV sequence is the sequential dimension; the G query
  heads of each KV group ride along inside the tile ((G, hd) query
  block), so the kernel's inner product is an MXU-friendly (G, hd) x
  (hd, block_kv) matmul even for G as small as 4-8.  Running (m, l, acc)
  scratch identical to the prefill kernel; ``kv_len`` — a scalar or a
  per-request (B,) vector — masks unwritten cache slots.

* :func:`paged_decode_attention` — the block-paged cache the
  continuous-batching serve engine uses.  K/V live in a shared pool of
  fixed-size blocks ``(P, block_kv, KV, hd)``; each request names its
  blocks via a ``(B, NB)`` block table.  The table and the per-request
  lengths ride in as scalar-prefetch operands
  (``compat.prefetch_grid_spec``), so the K/V BlockSpec index maps
  gather ``pool[table[b, j]]`` per grid step — the same ``kv_len`` mask
  machinery handles the partial last block, and fully-masked blocks are
  skipped by ``pl.when`` exactly like the contiguous variant.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import validate_tiling

__all__ = ["decode_attention", "paged_decode_attention"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_body(kv_len, ki, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 acc_ref, *, scale: float, n_kv: int, block_kv: int):
    """Shared online-softmax step: one (G, block_kv) score tile against the
    running (m, l, acc) scratch.  ``kv_len`` masks columns past the
    request's written prefix (the partial last block and, for the paged
    variant, the whole tail of over-allocated table slots)."""

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * block_kv < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (G, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bkv)
        col = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(col < kv_len, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ()))
        ).astype(jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, n_kv: int, block_kv: int,
                   kv_heads: int):
    kv_len = len_ref[pl.program_id(0) // kv_heads]      # per-request length
    _decode_body(kv_len, pl.program_id(1), q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                 block_kv=block_kv)


def _lens_vector(kv_len, B: int) -> jax.Array:
    """Normalise ``kv_len`` to a (B,) int32 vector (scalars broadcast)."""
    kl = jnp.asarray(kv_len, jnp.int32)
    if kl.ndim == 0:
        return jnp.broadcast_to(kl[None], (B,))
    if kl.shape != (B,):
        raise ValueError(
            f"decode_attention: kv_len must be a scalar or a per-request "
            f"({B},) vector, got shape {kl.shape}")
    return kl


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, block_kv: int,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v: (B, T, KV, hd); kv_len: int32 scalar or (B,).

    Returns (B, H, hd) attention output over cache positions < kv_len —
    per request when ``kv_len`` is a (B,) vector, so mixed-length batches
    mask correctly.  ``block_kv`` must be an MXU-aligned divisor of the
    cache length T (derive it with ``repro.kernels.plan.plan_for``).
    """
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    validate_tiling("decode_attention", {"T": (T, block_kv)},
                    depth_dims=(), block_names={"T": "block_kv"})

    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    lens = _lens_vector(kv_len, B)

    grid = (B * KV, T // block_kv)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, n_kv=T // block_kv,
                          block_kv=block_kv, kv_heads=KV),
        grid=grid,
        in_specs=[
            compat.smem_block_spec(),
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, n_kv: int,
                         block_kv: int, kv_heads: int):
    # tbl_ref/len_ref are the scalar-prefetch operands; the K/V gather
    # already happened in the BlockSpec index maps below.
    kv_len = len_ref[pl.program_id(0) // kv_heads]
    _decode_body(kv_len, pl.program_id(1), q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                 block_kv=block_kv)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           kv_len: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k_pool/v_pool: (P, block_kv, KV, hd);
    block_tables: (B, NB) int32 physical block ids; kv_len: (B,) int32.

    Each request attends its first ``kv_len[b]`` cache positions, read
    from pool blocks ``block_tables[b, 0..ceil(kv_len/block_kv))`` — the
    page size IS the kv tile, so it must be MXU-aligned (the
    ``paged_decode_attention`` planner chooses it).  Table slots past a
    request's written prefix must hold valid (in-range) block ids — the
    serve engine points them at its reserved null block — because the
    gather runs before the ``pl.when`` mask skips the compute.
    """
    B, H, hd = q.shape
    P, block_kv, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    NB = block_tables.shape[1]
    G = H // KV
    T = NB * block_kv
    scale = 1.0 / math.sqrt(hd)
    validate_tiling("paged_decode_attention", {"T": (T, block_kv)},
                    depth_dims=(), block_names={"T": "block_kv"})

    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    tables = jnp.asarray(block_tables, jnp.int32)
    lens = _lens_vector(kv_len, B)

    def _kv_index(i, j, tbl_ref, len_ref):
        # gather: grid step (i, j) reads physical block table[b, j] of
        # kv head i % KV (block dims: (1, block_kv, 1, hd))
        del len_ref
        return (tbl_ref[i // KV, j], 0, i % KV, 0)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,
        grid=(B * KV, NB),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda i, j, t, n: (i, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), _kv_index),
            pl.BlockSpec((1, block_kv, 1, hd), _kv_index),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda i, j, t, n: (i, 0, 0)),
        scratch_shapes=[
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, 1), jnp.float32),
            compat.vmem((G, hd), jnp.float32),
        ],
    )

    def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref):
        _paged_decode_kernel(
            tbl_ref, len_ref, q_ref,
            k_ref.reshape(1, block_kv, hd), v_ref.reshape(1, block_kv, hd),
            o_ref, m_ref, l_ref, acc_ref, scale=scale, n_kv=NB,
            block_kv=block_kv, kv_heads=KV)

    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lens, qf, k_pool, v_pool)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)
