"""The Pallas compute layer: MFMA-contract kernels on the MXU.

Five kernels (``mfma_gemm``, ``moe_gmm``, ``flash_attention``,
``decode_attention``, ``mamba2_ssd``), each with a pure-jnp oracle in
``ref.py``.  All Pallas/TPU version differences are absorbed by
``compat``; all tile selection is derived from the device registry by
``plan`` (``plan_for`` + the kernel catalog).  Call through ``ops`` —
the wrappers resolve plans and interpret mode.
"""

from repro.kernels.plan import (KernelEntry, TilePlan, UnknownKernelError,
                                get_kernel, list_kernels, plan_for,
                                register_kernel)

__all__ = ["KernelEntry", "TilePlan", "UnknownKernelError", "get_kernel",
           "list_kernels", "plan_for", "register_kernel"]
