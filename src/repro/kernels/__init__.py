"""The Pallas compute layer: MFMA-contract kernels on the MXU.

Five kernels (``mfma_gemm``, ``moe_gmm``, ``flash_attention``,
``decode_attention``, ``mamba2_ssd``), each with a pure-jnp oracle in
``ref.py``.  All Pallas/TPU version differences are absorbed by
``compat``; all tile selection is derived from the device registry by
``plan`` (``plan_for`` + the kernel catalog).  Call through ``ops`` —
the wrappers resolve plans, interpret mode, and ragged-tail padding
(``pad=True``).  The model layer routes through ``dispatch``, which
picks kernel-vs-reference per op and falls back (with a logged reason)
when the backend or shapes cannot support the kernel.  On an active
mesh, catalog entries with a ``logical`` dim->axis contract plan
against the per-shard shapes and execute under ``shard_map``
(``ops`` wrappers' ``sharded=True`` path); entries without one keep
the whole-op reference fallback.
"""

from repro.kernels.dispatch import (Decision, decide, decision_scope,
                                    fallback, last_decisions,
                                    reset_decisions)
from repro.kernels.plan import (KernelEntry, TilePlan, UnknownKernelError,
                                get_kernel, list_kernels, plan_for,
                                register_kernel)

__all__ = ["Decision", "KernelEntry", "TilePlan", "UnknownKernelError",
           "decide", "decision_scope", "fallback", "get_kernel",
           "last_decisions", "list_kernels", "plan_for", "register_kernel",
           "reset_decisions"]
