"""MXU-tiled accumulate-GEMM Pallas kernel: D = C + A @ B.

This is the MFMA *contract* (paper Section III) adapted to TPU per the
hardware-adaptation requirement: AMD's 4x4-block micro-tiles target 64-lane
SIMD wavefronts; the TPU MXU is a 128x128 systolic array, so the kernel
tiles GEMMs into MXU-aligned VMEM blocks (multiples of 128) and carries the
``D = C + A*B`` accumulation in an f32 VMEM scratch accumulator — the MCE's
wide accumulator.  The timing layer (core.hlo_bridge) accounts the same
GEMM as MFMA micro-ops on MI200/MI300 and as 128x128 systolic passes on
the TPU machine model.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" = sequential), so each
(i, j) output tile stays resident in VMEM across the K loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import validate_tiling

__all__ = ["mfma_gemm"]


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(acc_ref.dtype)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def mfma_gemm(a: jax.Array, b: jax.Array, c: jax.Array, *,
              block_m: int, block_n: int, block_k: int,
              interpret: bool = False) -> jax.Array:
    """a: (M, K), b: (K, N), c: (M, N) -> c + a @ b (f32 accumulation).

    Block sizes must be MXU-aligned (multiples of 128; block_k may be one
    full-depth step) and divide the operand dims — derive them with
    ``repro.kernels.plan.plan_for`` or call via ``repro.kernels.ops``.
    VMEM footprint = bm*bk + bk*bn (operands) + 2*bm*bn (C tile + f32
    accumulator).
    """
    M, K = a.shape
    K2, N = b.shape
    if K != K2 or c.shape != (M, N):
        raise ValueError(
            f"mfma_gemm: incompatible operands a{a.shape} @ b{b.shape} "
            f"+ c{c.shape}; need a(M,K), b(K,N), c(M,N)")
    validate_tiling("mfma_gemm", {"M": (M, block_m), "N": (N, block_n),
                                  "K": (K, block_k)})
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), c.dtype),
        scratch_shapes=[compat.vmem((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)
