"""Blockwise online-softmax (flash) attention Pallas kernel, causal GQA.

VMEM tiling: q tile (block_q, hd), K/V tiles (block_kv, hd), running
(m, l, acc) in f32 VMEM scratch.  Grid (B*KV*G, Sq/block_q, T/block_kv)
with the KV dimension innermost/sequential; fully-masked causal blocks and
blocks past ``kv_len`` are skipped with ``pl.when`` (the XLA reference in
models/attention.py executes them — one of the kernel's perf wins on real
TPUs).

``kv_len`` (an SMEM scalar, default T) masks key positions >= kv_len —
both genuinely short caches and the ragged-tail padding the ops wrapper
applies so non-128-multiple T runs the kernel path.

The contract matches ``repro.kernels.ref.flash_attention_ref`` (and the
model's `_flash_sdpa`): grouped heads, causal, optional kv_len mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.plan import validate_tiling

__all__ = ["flash_attention"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, n_kv: int,
                  block_q: int, block_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    # skip blocks entirely past kv_len, and (causal) strictly above the
    # diagonal
    run = ki * block_kv < kv_len
    if causal:
        run = jnp.logical_and(
            run, ki * block_kv <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
        col = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        if causal:
            row = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            s = jnp.where(col <= row, s, _NEG_INF)
        s = jnp.where(col < kv_len, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ()))
        ).astype(jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int, block_kv: int,
                    kv_len=None, interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) with H = KV*G -> (B, S, H, hd).

    ``block_q``/``block_kv`` must be MXU-aligned divisors of S/T (derive
    them with ``repro.kernels.plan.plan_for``; ``ops.flash_attention``
    with ``pad=True`` pads ragged shapes onto this contract).  ``kv_len``
    (scalar int32, default T) masks key positions >= kv_len.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    validate_tiling("flash_attention", {"S": (S, block_q),
                                        "T": (T, block_kv)},
                    depth_dims=(),
                    block_names={"S": "block_q", "T": "block_kv"})
    if kv_len is None:
        kv_len = T
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32)[None], (1,))

    # (B, S, KV, G, hd) -> flat (B*KV*G, S, hd) query-major layout
    qf = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV * G, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * KV * G, T, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * KV * G, T, hd)

    grid = (B * KV * G, S // block_q, T // block_kv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          n_kv=T // block_kv, block_q=block_q,
                          block_kv=block_kv),
        grid=grid,
        in_specs=[
            compat.smem_block_spec(),
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, S, hd), q.dtype),
        scratch_shapes=[
            compat.vmem((block_q, 1), jnp.float32),   # running max
            compat.vmem((block_q, 1), jnp.float32),   # running denom
            compat.vmem((block_q, hd), jnp.float32),  # accumulator
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, H, hd)
