"""Continuous-batching serve engine over the block-paged KV cache.

Scheduler states (per request)::

    PENDING --admit--> ACTIVE --retire--> DONE
      (waits for a slot  (holds a slot +     (blocks back on the
       + enough blocks)   reserved blocks)    free list immediately)

Each scheduler *tick*:

1. **retire** — requests that emitted their last token free their slot
   and return their blocks to the pool;
2. **admit** — pending requests (arrival <= tick, FIFO) claim a free
   engine slot and an atomic upfront reservation of
   ``ceil((prompt + n_steps) / page)`` blocks, prefill their prompt
   (right-padded to a page multiple; ``last_pos`` slices the true last
   token's logits) straight into the reserved blocks, and emit their
   first token.  When the pool or the slot array is exhausted the queue
   simply waits — admission is the backpressure point;
3. **decode** — ONE jitted :func:`repro.models.paged_decode_step` call
   advances every active slot simultaneously: each slot's pending token
   is written at its own cache offset (``lens``), attention reads
   through the block table, and the next token is sampled.  Idle slots
   ride along pointing at the null block, so arrivals and retirements
   never change the compiled shapes — no recompilation mid-flight.

The old synchronous :class:`~repro.serve.engine.ServeEngine` pads every
request to a (batch, max_len) bucket and decodes the whole batch for the
longest request's step count; this engine keeps the same per-token math
(greedy decode is bit-identical on the same prompts — the parity oracle
``tests/test_serve_paged.py`` pins) while slot-filling ragged work.

Temperature sampling uses per-request key streams
(``fold_in(PRNGKey(seed), request_index)``, split once per sampled
token): a continuously-batched request has no stable batch to share the
synchronous engine's single key sequence with.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import paged_decode_step, prefill
from repro.serve.paged_cache import PagedKVCache, default_page_size

__all__ = ["PagedServeEngine", "Request", "RequestResult"]


@dataclasses.dataclass
class Request:
    """One serve request: ``prompt`` (1-D int32 tokens), ``n_steps``
    tokens to generate, ``arrival`` tick at which it may be admitted."""

    prompt: np.ndarray
    n_steps: int
    arrival: int = 0


@dataclasses.dataclass
class RequestResult:
    tokens: np.ndarray              # (n_steps,) generated tokens
    prompt_len: int
    arrival: int                    # tick the request became eligible
    admitted: int                   # tick it was admitted
    finished: int                   # tick its last token was emitted
    emit_times: List[float]         # perf_counter() per emitted token


@dataclasses.dataclass
class _Slot:
    req: int                        # index into the request list
    ids: List[int]                  # reserved pool blocks
    remaining: int
    key: jax.Array                  # per-request sampling key stream


class PagedServeEngine:
    """Continuous-batching engine: one compiled decode step, ``max_batch``
    slots, a :class:`PagedKVCache` pool shared by all in-flight requests.

    ``n_blocks=None`` sizes the pool so every slot can hold a full
    ``max_len`` request (plus the null block) — pass something smaller
    to exercise admission backpressure.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 max_batch: int = 8, n_blocks: Optional[int] = None,
                 page: Optional[int] = None, device=None):
        if page is None:
            # cap the planner's block at max_len: an uncapped probe hands
            # back the largest VMEM-admissible page (512 on every current
            # device), and short-request engines would then gather, mask
            # and convert 4x more pool rows per tick than they can use
            page = default_page_size(cfg, device, cap=max_len)
        self.page = int(page)
        self.nb_table = math.ceil(max_len / self.page)
        if n_blocks is None:
            n_blocks = max_batch * self.nb_table + 1
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self.cache = PagedKVCache(cfg, n_blocks=n_blocks, page=self.page,
                                  device=device)
        def _step(p, c, t, tbl, ln):
            # greedy tokens computed in-graph: the scheduler's hot loop
            # transfers (B,) ints per tick, not (B, V) logits + eager ops
            logits, new_c = paged_decode_step(cfg, p, c, t, tbl, ln)
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits, toks, new_c

        self._decode = jax.jit(_step)
        self._prefills: Dict[int, object] = {}
        self._writers: Dict[int, object] = {}

    # -- compiled pieces (cached per padded-length / block-count) ----------

    #: prompts prefill at this granularity, not the page: a 6-token chat
    #: turn costs a 32-row prefill, and the writer zero-pads rows up to
    #: the page before scattering (padded rows sit past ``lens``, so the
    #: kv_len mask never reads them)
    _PREFILL_BUCKET = 32

    def _prefill_fn(self, sp: int):
        if sp not in self._prefills:
            cfg = self.cfg
            self._prefills[sp] = jax.jit(
                lambda p, b, lp: prefill(cfg, p, b, max_len=sp, last_pos=lp))
        return self._prefills[sp]

    def _writer_fn(self, sp: int, nb: int):
        """Scatter a prefilled (1, sp, ...) cache into ``nb`` pool blocks,
        zero-padding the ragged tail rows up to the page boundary."""
        if (sp, nb) not in self._writers:
            page = self.page
            rows = nb * page

            def write(pools, pcache, ids):
                def wr(pool, blk):
                    # row axis: (.., B=1, sp, KV, hd) -> third from the end
                    pad = [(0, 0)] * blk.ndim
                    pad[blk.ndim - 3] = (0, rows - sp)
                    blk = jnp.pad(blk, pad)
                    if pool.ndim == 5:      # (n_periods, P, page, KV, hd)
                        b = blk.reshape((pool.shape[0], nb, page)
                                        + pool.shape[3:])
                        return pool.at[:, ids].set(b)
                    b = blk.reshape((nb, page) + pool.shape[2:])
                    return pool.at[ids].set(b)
                return jax.tree.map(wr, pools, pcache)

            self._writers[(sp, nb)] = jax.jit(write)
        return self._writers[(sp, nb)]

    def _sample(self, logits: jax.Array, key, temperature: float):
        """logits (V,) -> int token (same math as ServeEngine._sample)."""
        if temperature <= 0.0:
            return int(jnp.argmax(logits, axis=-1))
        return int(jax.random.categorical(key, logits / temperature,
                                          axis=-1))

    def _sample_tick(self, logits, greedy, keys, temperature: float):
        """One transfer for a whole decode tick -> (B,) host tokens.
        Greedy tokens were already computed in-graph (the sync engine's
        exact row-wise argmax); temperature draws one categorical per
        slot from that slot's own key stream."""
        if temperature <= 0.0:
            return np.asarray(greedy, np.int32)
        toks = jax.vmap(lambda k, l: jax.random.categorical(
            k, l / temperature, axis=-1))(jnp.stack(keys), logits)
        return np.asarray(toks, np.int32)

    # -- the scheduler -----------------------------------------------------

    def run(self, requests: Sequence[Union[Request, Tuple]], *,
            temperature: float = 0.0, seed: int = 0
            ) -> Tuple[List[RequestResult], Dict]:
        """Serve ``requests`` (Request objects or (prompt, n_steps[,
        arrival]) tuples) to completion.  Returns per-request results in
        input order plus scheduler stats (ticks, decode steps, occupancy).
        """
        reqs = [r if isinstance(r, Request) else Request(*r)
                for r in requests]
        for i, r in enumerate(reqs):
            r.prompt = np.asarray(r.prompt, np.int32).reshape(-1)
            s = r.prompt.shape[0]
            if r.n_steps < 1:
                raise ValueError(f"request {i}: n_steps={r.n_steps} < 1")
            if s + r.n_steps > self.max_len:
                raise ValueError(
                    f"request {i} does not fit: prompt length {s} + n_steps "
                    f"{r.n_steps} = {s + r.n_steps} exceeds this engine's "
                    f"max_len of {self.max_len}")

        root = jax.random.PRNGKey(seed)
        results: List[Optional[RequestResult]] = [None] * len(reqs)
        out_tokens: List[List[int]] = [[] for _ in reqs]
        emit_times: List[List[float]] = [[] for _ in reqs]
        admitted_at = [-1] * len(reqs)
        # FIFO by (arrival, submission order)
        queue = sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i))

        B, NB = self.max_batch, self.nb_table
        slots: List[Optional[_Slot]] = [None] * B
        tables = np.zeros((B, NB), np.int32)          # null block everywhere
        lens = np.zeros((B,), np.int32)
        pend = np.zeros((B,), np.int32)
        pools = self.cache.pools

        tick = 0
        decode_steps = 0
        occupancy: List[float] = []

        def emit(rid: int, tok: int) -> None:
            out_tokens[rid].append(tok)
            emit_times[rid].append(time.perf_counter())

        def retire(si: int) -> None:
            slot = slots[si]
            self.cache.free(slot.ids)
            rid = slot.req
            results[rid] = RequestResult(
                tokens=np.asarray(out_tokens[rid], np.int32),
                prompt_len=reqs[rid].prompt.shape[0],
                arrival=reqs[rid].arrival, admitted=admitted_at[rid],
                finished=tick, emit_times=emit_times[rid])
            slots[si] = None
            tables[si] = 0
            lens[si] = 0

        while queue or any(s is not None for s in slots):
            # admit: FIFO while a slot and the block reservation both fit
            while queue and reqs[queue[0]].arrival <= tick:
                free_slots = [i for i, s in enumerate(slots) if s is None]
                if not free_slots:
                    break
                rid = queue[0]
                r = reqs[rid]
                s = r.prompt.shape[0]
                need = math.ceil((s + r.n_steps) / self.page)
                ids = self.cache.alloc(need)
                if ids is None:
                    if not any(sl is not None for sl in slots):
                        raise ValueError(
                            f"request {rid} needs {need} blocks but the "
                            f"pool only has {self.cache.capacity}; grow "
                            "n_blocks or shorten the request")
                    break                     # wait for retirements
                queue.pop(0)
                si = free_slots[0]
                key = jax.random.fold_in(root, rid)
                bucket = self._PREFILL_BUCKET
                sp = bucket * math.ceil(s / bucket)
                batch = {"tokens": jnp.asarray(
                    np.pad(r.prompt, (0, sp - s))[None], jnp.int32)}
                logits, pcache = self._prefill_fn(sp)(
                    self.params, batch, jnp.int32(s - 1))
                nb_prompt = math.ceil(s / self.page)
                pools = self._writer_fn(sp, nb_prompt)(
                    pools, pcache, jnp.asarray(ids[:nb_prompt], jnp.int32))
                # same serialization as the decode tick below: don't let
                # the scatter-write overlap the next dispatch
                jax.block_until_ready(pools)
                key, sub = jax.random.split(key)
                tok = self._sample(logits[0, -1], sub, temperature)
                admitted_at[rid] = tick
                slots[si] = _Slot(req=rid, ids=ids, remaining=r.n_steps - 1,
                                  key=key)
                tables[si, :] = 0
                tables[si, :need] = ids
                lens[si] = s
                pend[si] = tok
                emit(rid, tok)
                if slots[si].remaining == 0:
                    retire(si)

            occupancy.append(self.cache.occupancy())

            active = [i for i, s in enumerate(slots) if s is not None]
            if active:
                # jnp.array (not asarray): asarray zero-copies numpy on CPU,
                # so the async decode would alias these host buffers while
                # the scheduler keeps mutating them (retire zeroes table
                # rows, lens advance) — a read/write race on real state
                logits, greedy, pools = self._decode(
                    self.params, pools, jnp.array(pend[:, None]),
                    jnp.array(tables), jnp.array(lens))
                # materialize the whole tick before dispatching anything
                # else: overlapping executions on XLA:CPU's shared thunk
                # thread pool perturb parallel-reduction numerics, and a
                # near-tie argmax flip breaks bitwise greedy parity with
                # the synchronous engine (whose single lax.scan decode
                # loop never overlaps itself).  The greedy-token transfer
                # below already serialized most of the tick; this pins
                # the pool updates too, so no computation from run() is
                # ever still in flight when the caller's next one starts.
                jax.block_until_ready((logits, greedy, pools))
                decode_steps += 1
                lens[active] += 1
                keys = None
                if temperature > 0.0:
                    keys = []
                    for si in range(B):
                        if slots[si] is not None:
                            slots[si].key, sub = jax.random.split(
                                slots[si].key)
                            keys.append(sub)
                        else:
                            keys.append(root)     # idle slot: discarded
                toks = self._sample_tick(logits[:, -1], greedy, keys,
                                         temperature)
                for si in active:
                    slot = slots[si]
                    tok = int(toks[si])
                    pend[si] = tok
                    emit(slot.req, tok)
                    slot.remaining -= 1
                    if slot.remaining == 0:
                        retire(si)
            elif not queue:
                break
            tick += 1

        self.cache.pools = pools
        stats = {
            "ticks": tick,
            "decode_steps": decode_steps,
            "requests": len(reqs),
            "tokens": sum(len(t) for t in out_tokens),
            "occupancy_mean": float(np.mean(occupancy)) if occupancy else 0.0,
            "occupancy_max": float(np.max(occupancy)) if occupancy else 0.0,
        }
        return [r for r in results if r is not None], stats

    def generate(self, tokens: np.ndarray, *, n_steps: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Batch convenience mirroring ``ServeEngine.generate``: serve the
        (B, S) prompts (all arriving at tick 0) and return (B, n_steps)."""
        tokens = np.asarray(tokens, np.int32)
        reqs = [Request(prompt=row, n_steps=n_steps) for row in tokens]
        results, _ = self.run(reqs, temperature=temperature, seed=seed)
        return np.stack([r.tokens for r in results])
