"""Continuous-batching serve engine over the block-paged KV cache.

Scheduler states (per request)::

    PENDING --admit--> PREFILLING --complete--> ACTIVE --retire--> DONE
      (waits for a slot  (one prompt chunk        (decodes one
       + enough blocks)   per tick)                token per tick)

Each scheduler *tick*:

1. **retire** — requests that emitted their last token free their slot
   and release their blocks (shared blocks just drop a reference);
2. **admit / match prefix** — pending requests (arrival <= tick, FIFO)
   claim a free engine slot and their block reservation.  With
   ``prefix_cache`` on, the longest page-aligned cached prefix is taken
   straight from the pool (:meth:`PagedKVCache.match_prefix` +
   ``acquire`` — refcount bumps, zero prefill compute) and only the
   remaining ``ceil(need) - matched`` blocks are allocated writable.
   The match is capped at ``(s - 1) // page`` pages so at least one
   prompt token always runs through prefill (the first output token's
   logits must be computed) — which also guarantees every scatter-write
   (chunk prefill at positions >= filled, decode at positions >= s)
   lands past the shared pages, so sharing never needs a
   :meth:`~PagedKVCache.fork` in steady state.  When the pool or the
   slot array is exhausted the queue waits — admission is the
   backpressure point (a matched-then-starved request releases its
   matched blocks before waiting);
3. **prefill one chunk** — every PREFILLING slot advances by one
   ``prefill_chunk``-token chunk through a single fixed-shape jitted
   :func:`repro.models.paged_prefill_step` call: the chunk's K/V
   scatter into the slot's blocks, attention reads the already-written
   prefix (shared or own) back through the block table, and completed
   full pages register in the prefix index as they land.  On the final
   chunk the request emits its first token and turns ACTIVE.  Long
   prompts therefore cost ``ceil(s / chunk)`` bounded ticks instead of
   one monolithic prompt-length prefill stall — decode ticks interleave
   below;
4. **decode** — ONE jitted :func:`repro.models.paged_decode_step` call
   advances every ACTIVE slot simultaneously: each slot's pending token
   is written at its own cache offset (``lens``), attention reads
   through the block table, and the next token is sampled.  Idle and
   still-PREFILLING slots ride along pointing at the null block with
   length 0, so arrivals, chunk progress and retirements never change
   the compiled shapes — no recompilation mid-flight.

The old synchronous :class:`~repro.serve.engine.ServeEngine` pads every
request to a (batch, max_len) bucket and decodes the whole batch for the
longest request's step count; this engine keeps the same per-token math
(greedy decode is bit-identical on the same prompts — the parity oracle
``tests/test_serve_paged.py`` pins) while slot-filling ragged work.
Bitwise parity holds because every attention contraction — sync padded
prefill, chunk prefill, both decodes — runs at the same aligned KV
length (``max_len`` = the gathered table width): XLA:CPU's blocked
reductions round identically when T is aligned, but a *ragged* T (an
exact-length prompt) orders the tail sum differently and near-tie
argmaxes flip.  The oracle therefore prefills with
``ServeEngine(prefill_pad=True)`` in the long-prompt parity tests.

Temperature sampling uses per-request key streams
(``fold_in(PRNGKey(seed), request_index)``, split once per sampled
token): a continuously-batched request has no stable batch to share the
synchronous engine's single key sequence with.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import paged_decode_step, paged_prefill_step
from repro.serve.api import Request, RequestResult, RunStats, as_requests
from repro.serve.paged_cache import PagedKVCache, default_page_size

__all__ = ["PagedServeEngine", "Request", "RequestResult"]


@dataclasses.dataclass
class _Slot:
    req: int                        # index into the request list
    ids: List[int]                  # reserved pool blocks (shared first)
    remaining: int
    key: jax.Array                  # per-request sampling key stream
    filled: int                     # prompt tokens already in the pool
    registered: int                 # full pages entered in the prefix index


class PagedServeEngine:
    """Continuous-batching engine: one compiled decode step, one compiled
    chunk-prefill step, ``max_batch`` slots, a :class:`PagedKVCache` pool
    shared by all in-flight requests.

    ``n_blocks=None`` sizes the pool so every slot can hold a full
    ``max_len`` request (plus the null block) — pass something smaller
    to exercise admission backpressure.  ``prefix_cache=False`` disables
    block sharing (every request allocates and prefills everything —
    the A/B baseline the benchmark compares against);
    ``prefill_chunk`` is the incremental-prefill granularity."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 max_batch: int = 8, n_blocks: Optional[int] = None,
                 page: Optional[int] = None, device=None,
                 prefix_cache: bool = True, prefill_chunk: int = 32):
        if page is None:
            # cap the planner's block at max_len: an uncapped probe hands
            # back the largest VMEM-admissible page (512 on every current
            # device), and short-request engines would then gather, mask
            # and convert 4x more pool rows per tick than they can use
            page = default_page_size(cfg, device, cap=max_len)
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} < 1")
        self.page = int(page)
        self.nb_table = math.ceil(max_len / self.page)
        if n_blocks is None:
            n_blocks = max_batch * self.nb_table + 1
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self.prefix_cache = prefix_cache
        self.prefill_chunk = int(prefill_chunk)
        self.cache = PagedKVCache(cfg, n_blocks=n_blocks, page=self.page,
                                  device=device)

        def _step(p, c, t, tbl, ln):
            # greedy tokens computed in-graph: the scheduler's hot loop
            # transfers (B,) ints per tick, not (B, V) logits + eager ops
            logits, new_c = paged_decode_step(cfg, p, c, t, tbl, ln)
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits, toks, new_c

        # the pool pytree is donated: run() threads one live pools value
        # through every dispatch and never reads a superseded one, so XLA
        # updates the blocks in place instead of copying the whole pool
        # (MBs per tick) to preserve an input nobody looks at again
        self._decode = jax.jit(_step, donate_argnums=(1,))

        # chunks start at multiples of prefill_chunk past a page boundary
        # (prefix matches are page-aligned), so when the chunk size
        # divides the page no chunk ever crosses a block boundary and the
        # pool write collapses to one contiguous slice (aligned=True)
        aligned = self.page % self.prefill_chunk == 0

        def _pstep(p, c, t, tbl, ln, nv):
            logits, new_c = paged_prefill_step(cfg, p, c, t, tbl, ln, nv,
                                               aligned=aligned)
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits, toks, new_c

        # ONE compiled prefill: fixed (1, prefill_chunk) tokens against
        # the full table width, whatever the prompt length — the ragged
        # final chunk pads and masks via ``nv`` instead of recompiling.
        # Pools donated for the same in-place reason as _decode.
        self._prefill = jax.jit(_pstep, donate_argnums=(1,))

    def _sample(self, logits: jax.Array, key, temperature: float):
        """logits (V,) -> int token (same math as ServeEngine._sample)."""
        if temperature <= 0.0:
            return int(jnp.argmax(logits, axis=-1))
        return int(jax.random.categorical(key, logits / temperature,
                                          axis=-1))

    def _sample_tick(self, logits, greedy, keys, temperature: float):
        """One transfer for a whole decode tick -> (B,) host tokens.
        Greedy tokens were already computed in-graph (the sync engine's
        exact row-wise argmax); temperature draws one categorical per
        slot from that slot's own key stream."""
        if temperature <= 0.0:
            return np.asarray(greedy, np.int32)
        toks = jax.vmap(lambda k, l: jax.random.categorical(
            k, l / temperature, axis=-1))(jnp.stack(keys), logits)
        return np.asarray(toks, np.int32)

    # -- the scheduler -----------------------------------------------------

    def run(self, requests: Sequence[Union[Request, Tuple]], *,
            temperature: float = 0.0, seed: int = 0
            ) -> Tuple[List[RequestResult], RunStats]:
        """Serve ``requests`` (:class:`repro.serve.Request` objects;
        legacy (prompt, n_steps[, arrival]) tuples are coerced with a
        deprecation warning) to completion.  Returns per-request results
        in input order plus :class:`repro.serve.RunStats` (ticks, decode
        steps, prefill chunks, prefix-cache hit rate, occupancy).
        """
        reqs = as_requests(requests)
        for i, r in enumerate(reqs):
            s = r.prompt.shape[0]
            if s + r.n_steps > self.max_len:
                raise ValueError(
                    f"request {i} does not fit: prompt length {s} + n_steps "
                    f"{r.n_steps} = {s + r.n_steps} exceeds this engine's "
                    f"max_len of {self.max_len}")
            # fail fast instead of deadlocking: an oversized head request
            # would otherwise sit at the queue head forever waiting for a
            # reservation the pool can never satisfy
            need = math.ceil((s + r.n_steps) / self.page)
            if need > self.cache.capacity:
                raise ValueError(
                    f"request {i} needs {need} blocks but the "
                    f"pool only has {self.cache.capacity}; grow "
                    "n_blocks or shorten the request")

        root = jax.random.PRNGKey(seed)
        results: List[Optional[RequestResult]] = [None] * len(reqs)
        out_tokens: List[List[int]] = [[] for _ in reqs]
        emit_times: List[List[float]] = [[] for _ in reqs]
        admitted_at = [-1] * len(reqs)
        admit_time = [0.0] * len(reqs)
        prefix_blocks = [0] * len(reqs)
        # FIFO by (arrival, submission order); deque: admission pops the
        # head O(1) instead of the old list.pop(0) O(n) shuffle
        queue = collections.deque(
            sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i)))

        B, NB = self.max_batch, self.nb_table
        slots: List[Optional[_Slot]] = [None] * B
        tables = np.zeros((B, NB), np.int32)          # null block everywhere
        lens = np.zeros((B,), np.int32)               # 0 while prefilling
        pend = np.zeros((B,), np.int32)
        pools = self.cache.pools

        tick = 0
        decode_steps = 0
        prefill_chunks = 0
        blocks_reused = 0
        blocks_needed = 0
        occupancy: List[float] = []

        def emit(rid: int, tok: int) -> None:
            out_tokens[rid].append(tok)
            emit_times[rid].append(time.perf_counter())

        def retire(si: int) -> None:
            slot = slots[si]
            self.cache.free(slot.ids)
            rid = slot.req
            results[rid] = RequestResult(
                tokens=np.asarray(out_tokens[rid], np.int32),
                prompt_len=reqs[rid].prompt.shape[0],
                arrival=reqs[rid].arrival, admitted=admitted_at[rid],
                finished=tick, emit_times=emit_times[rid],
                admit_time=admit_time[rid], prefix_blocks=prefix_blocks[rid])
            slots[si] = None
            tables[si] = 0
            lens[si] = 0

        while queue or any(s is not None for s in slots):
            # admit: FIFO while a slot and the block reservation both fit
            while queue and reqs[queue[0]].arrival <= tick:
                free_slots = [i for i, s in enumerate(slots) if s is None]
                if not free_slots:
                    break
                rid = queue[0]
                r = reqs[rid]
                s = r.prompt.shape[0]
                need = math.ceil((s + r.n_steps) / self.page)
                matched: List[int] = []
                if self.prefix_cache:
                    # cap: >= 1 suffix token must prefill (first-token
                    # logits), which also keeps every later write past
                    # the shared pages — see the module docstring
                    matched = self.cache.match_prefix(
                        r.prompt)[:(s - 1) // self.page]
                    self.cache.acquire(matched)
                ids = self.cache.alloc(need - len(matched))
                if ids is None:
                    if matched:
                        self.cache.free(matched)    # drop the hold, wait
                    break                           # wait for retirements
                queue.popleft()
                si = free_slots[0]
                admitted_at[rid] = tick
                admit_time[rid] = time.perf_counter()
                prefix_blocks[rid] = len(matched)
                blocks_reused += len(matched)
                blocks_needed += (s - 1) // self.page
                slots[si] = _Slot(req=rid, ids=matched + ids,
                                  remaining=r.n_steps,
                                  key=jax.random.fold_in(root, rid),
                                  filled=len(matched) * self.page,
                                  registered=len(matched))
                tables[si, :] = 0
                tables[si, :need] = slots[si].ids
                lens[si] = 0                        # ACTIVE only after prefill

            occupancy.append(self.cache.occupancy())

            # prefill: one chunk per PREFILLING slot, then decode below —
            # long prompts stall a tick by at most one chunk of compute
            C = self.prefill_chunk
            for si in range(B):
                slot = slots[si]
                if slot is None or lens[si] > 0:
                    continue
                r = reqs[slot.req]
                s = r.prompt.shape[0]
                pos = slot.filled
                nv = min(C, s - pos)
                toks = np.zeros((1, C), np.int32)
                toks[0, :nv] = r.prompt[pos:pos + nv]
                # jnp.array (not asarray): don't alias scheduler state the
                # async dispatch would race with (same rationale as decode)
                logits, greedy, pools = self._prefill(
                    self.params, pools, jnp.array(toks),
                    jnp.array(tables[si:si + 1]),
                    jnp.array([pos], np.int32), jnp.array([nv], np.int32))
                jax.block_until_ready((logits, greedy, pools))
                prefill_chunks += 1
                slot.filled = pos + nv
                if self.prefix_cache:
                    full = slot.filled // self.page
                    if full > slot.registered:
                        self.cache.register_prefix(
                            r.prompt[:full * self.page], slot.ids[:full])
                        slot.registered = full
                if slot.filled == s:                # prefill done -> ACTIVE
                    if temperature <= 0.0:
                        tok = int(greedy[0])
                    else:
                        slot.key, sub = jax.random.split(slot.key)
                        tok = self._sample(logits[0, -1], sub, temperature)
                    lens[si] = s
                    pend[si] = tok
                    emit(slot.req, tok)
                    slot.remaining -= 1
                    if slot.remaining == 0:
                        retire(si)

            active = [i for i, sl in enumerate(slots)
                      if sl is not None and lens[i] > 0]
            if active:
                # jnp.array (not asarray): asarray zero-copies numpy on CPU,
                # so the async decode would alias these host buffers while
                # the scheduler keeps mutating them (retire zeroes table
                # rows, lens advance) — a read/write race on real state.
                # PREFILLING slots already sit at lens 0 so the decode
                # masks them like idle slots; their table rows are real
                # but every read is kv_len-masked and the pend-0 write
                # lands at row 0 of their first block, which the next
                # chunk overwrites (positions are absolute).
                dec_tables = tables.copy()
                for si in range(B):
                    if slots[si] is not None and lens[si] == 0:
                        dec_tables[si] = 0          # scatter to null block
                logits, greedy, pools = self._decode(
                    self.params, pools, jnp.array(pend[:, None]),
                    jnp.array(dec_tables), jnp.array(lens))
                # materialize the whole tick before dispatching anything
                # else: overlapping executions on XLA:CPU's shared thunk
                # thread pool perturb parallel-reduction numerics, and a
                # near-tie argmax flip breaks bitwise greedy parity with
                # the synchronous engine (whose single lax.scan decode
                # loop never overlaps itself).  The greedy-token transfer
                # below already serialized most of the tick; this pins
                # the pool updates too, so no computation from run() is
                # ever still in flight when the caller's next one starts.
                jax.block_until_ready((logits, greedy, pools))
                decode_steps += 1
                lens[active] += 1
                keys = None
                if temperature > 0.0:
                    keys = []
                    active_set = set(active)
                    for si in range(B):
                        if si in active_set:
                            slots[si].key, sub = jax.random.split(
                                slots[si].key)
                            keys.append(sub)
                        else:
                            keys.append(root)     # idle slot: discarded
                toks = self._sample_tick(logits[:, -1], greedy, keys,
                                         temperature)
                for si in active:
                    slot = slots[si]
                    tok = int(toks[si])
                    pend[si] = tok
                    emit(slot.req, tok)
                    slot.remaining -= 1
                    if slot.remaining == 0:
                        retire(si)
            tick += 1

        self.cache.pools = pools
        stats = RunStats(
            requests=len(reqs),
            tokens=sum(len(t) for t in out_tokens),
            ticks=tick,
            decode_steps=decode_steps,
            prefill_chunks=prefill_chunks,
            prefix_blocks_reused=blocks_reused,
            prefix_blocks_needed=blocks_needed,
            prefix_hit_rate=(blocks_reused / blocks_needed
                             if blocks_needed else 0.0),
            occupancy_mean=float(np.mean(occupancy)) if occupancy else 0.0,
            occupancy_max=float(np.max(occupancy)) if occupancy else 0.0,
        )
        return [r for r in results if r is not None], stats

    def generate(self, tokens: np.ndarray, *, n_steps: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Batch convenience mirroring ``ServeEngine.generate``: serve the
        (B, S) prompts (all arriving at tick 0) and return (B, n_steps)."""
        tokens = np.asarray(tokens, np.int32)
        reqs = [Request(prompt=row, n_steps=n_steps) for row in tokens]
        results, _ = self.run(reqs, temperature=temperature, seed=seed)
        return np.stack([r.tokens for r in results])
