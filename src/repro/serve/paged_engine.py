"""Continuous-batching serve engine over the block-paged KV cache.

Scheduler states (per request)::

    PENDING --admit--> PREFILLING --complete--> ACTIVE --retire--> DONE
      (waits for a slot  (one prompt chunk        (decodes one        ^
       + prompt blocks)   per tick)                token per tick)    |
         ^                    |                        |              |
         |                    +-----<--preempt--<------+              |
         +--- re-queued as PENDING (tokens discarded, recompute) -----+

Each scheduler *tick* (the full order, shared verbatim with the fleet
replica ``repro.fleet.capacity.simulate_trace``):

1. **faults** — with a :class:`~repro.serve.resilience.FaultPlan`
   active: release expired block seizures, seize free blocks for
   ``exhaust`` faults firing now, note stall windows;
2. **cancel / timeout** — requests whose ``cancel_at`` has arrived
   retire ``CANCELLED``; requests whose ``deadline`` has passed retire
   ``TIMEOUT`` — queued or in-flight, partial tokens kept, blocks
   released refcount-exactly;
3. **forced preemptions** — ``preempt`` faults evict victims
   (latest-admitted first, the same rule organic exhaustion uses);
4. **shed** — the ``max_queue`` bound, then the pluggable
   :class:`~repro.serve.resilience.AdmissionPolicy`, reject waiting
   requests with a descriptive reason (terminal ``SHED``) so the
   arrival deque cannot grow without bound;
5. **admit / match prefix** — pending requests (arrival <= tick, FIFO)
   claim a free engine slot plus their **prompt** block reservation
   only (``ceil(s / page)`` blocks; decode blocks are allocated lazily
   as the sequence grows — that is what makes mid-flight exhaustion,
   and therefore preemption, possible at all).  With ``prefix_cache``
   on, the longest page-aligned cached prefix is taken straight from
   the pool (refcount bumps, zero prefill compute), capped at
   ``(s - 1) // page`` so the first-token logits always compute and
   every later write lands past the shared pages.  When the pool or
   slot array is exhausted the queue waits — admission is still the
   backpressure point;
6. **prefill one chunk** per PREFILLING slot (skipped on stalled
   ticks), exactly as before: fixed-shape ``(1, prefill_chunk)`` jitted
   chunks scatter into the slot's blocks and full pages register in the
   prefix index as they land;
7. **decode** — first each ACTIVE slot crossing a page boundary
   allocates its next block; when ``alloc`` returns ``None`` the
   scheduler **preempts-and-recomputes**: it evicts victims
   latest-admitted first (possibly the grower itself), dropping their
   pool state and re-queueing them as PENDING — a re-admitted victim
   re-prefills through the prefix cache (its already-registered pages
   make recompute cheap) and its greedy stream is bit-identical to an
   uninterrupted run (pinned by the parity suite).  A request evicted
   more than ``max_preemptions`` times retires terminal ``PREEMPTED``
   instead of livelocking.  Then ONE jitted decode advances every
   remaining ACTIVE slot as before.

Steps 5-7 are the data plane (a ``stall`` fault skips them); steps 1-4
are the control plane and always run — deadlines age through stalls.
Every terminal path goes through one retire helper that releases the
slot's blocks exactly once (shared prefix blocks just drop a
reference), so ``PagedKVCache.check_invariants()`` holds after every
tick — the chaos suite asserts it.

Bitwise-parity notes (unchanged from the pre-resilience engine): the
sync oracle runs ``ServeEngine(prefill_pad=True)`` on long prompts
(aligned-T recipe), greedy tokens are computed in-graph, every tick is
fully materialized before the next dispatch, and lazy tables point
unallocated rows at the null block — all reads are kv_len-masked, so
block-table raggedness never perturbs numerics (the stale-residue
determinism test pins this).

Temperature sampling uses per-request key streams
(``fold_in(PRNGKey(seed), request_index)``, split once per sampled
token); a preempted request's recompute replays the same stream from
the start, so sampled runs are preemption-deterministic too.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import paged_decode_step, paged_prefill_step
from repro.serve.api import Request, RequestResult, RunStats, as_requests
from repro.serve.paged_cache import PagedKVCache, default_page_size
from repro.serve.resilience import (CANCELLED, OK, PREEMPTED, SHED, TIMEOUT,
                                    AdmissionPolicy, FaultPlan,
                                    QueueCapPolicy, queue_entries)

__all__ = ["PagedServeEngine", "Request", "RequestResult"]


@dataclasses.dataclass
class _Slot:
    req: int                        # index into the request list
    ids: List[int]                  # held pool blocks (shared first)
    remaining: int
    key: jax.Array                  # per-request sampling key stream
    filled: int                     # prompt tokens already in the pool
    registered: int                 # full pages entered in the prefix index
    seq: int                        # admission order (victim selection)


class PagedServeEngine:
    """Continuous-batching engine: one compiled decode step, one compiled
    chunk-prefill step, ``max_batch`` slots, a :class:`PagedKVCache` pool
    shared by all in-flight requests.

    ``n_blocks=None`` sizes the pool so every slot can hold a full
    ``max_len`` request (plus the null block) — pass something smaller
    to exercise admission backpressure and mid-flight preemption.
    ``prefix_cache=False`` disables block sharing; ``prefill_chunk`` is
    the incremental-prefill granularity.

    Graceful-degradation knobs (see :mod:`repro.serve.resilience`):
    ``max_queue`` bounds the waiting queue (excess arrivals shed with a
    descriptive reason); ``admission`` plugs in a shed policy (e.g.
    ``DeadlineAwareShed``); ``max_preemptions`` caps how often one
    request may be evicted and recomputed before it retires terminal
    ``PREEMPTED``; ``check_invariants=True`` asserts the pool's
    conservation invariants after every tick (always on under a
    ``fault_plan``)."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 max_batch: int = 8, n_blocks: Optional[int] = None,
                 page: Optional[int] = None, device=None,
                 prefix_cache: bool = True, prefill_chunk: int = 32,
                 max_queue: Optional[int] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 max_preemptions: int = 8,
                 check_invariants: bool = False):
        if page is None:
            # cap the planner's block at max_len: an uncapped probe hands
            # back the largest VMEM-admissible page (512 on every current
            # device), and short-request engines would then gather, mask
            # and convert 4x more pool rows per tick than they can use
            page = default_page_size(cfg, device, cap=max_len)
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} < 1")
        if max_preemptions < 0:
            raise ValueError(f"max_preemptions={max_preemptions} < 0")
        self.page = int(page)
        self.nb_table = math.ceil(max_len / self.page)
        if n_blocks is None:
            n_blocks = max_batch * self.nb_table + 1
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self.prefix_cache = prefix_cache
        self.prefill_chunk = int(prefill_chunk)
        self.max_preemptions = int(max_preemptions)
        self.check_invariants = bool(check_invariants)
        # shed policies run queue-cap first (bound the deque), then the
        # user's pluggable policy — both see the same QueueEntry view
        self.policies: List[AdmissionPolicy] = []
        if max_queue is not None:
            self.policies.append(QueueCapPolicy(max_queue))
        if admission is not None:
            self.policies.append(admission)
        self.max_queue = max_queue
        self.cache = PagedKVCache(cfg, n_blocks=n_blocks, page=self.page,
                                  device=device)

        def _step(p, c, t, tbl, ln):
            # greedy tokens computed in-graph: the scheduler's hot loop
            # transfers (B,) ints per tick, not (B, V) logits + eager ops
            logits, new_c = paged_decode_step(cfg, p, c, t, tbl, ln)
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits, toks, new_c

        # the pool pytree is donated: run() threads one live pools value
        # through every dispatch and never reads a superseded one, so XLA
        # updates the blocks in place instead of copying the whole pool
        # (MBs per tick) to preserve an input nobody looks at again
        self._decode = jax.jit(_step, donate_argnums=(1,))

        # chunks start at multiples of prefill_chunk past a page boundary
        # (prefix matches are page-aligned), so when the chunk size
        # divides the page no chunk ever crosses a block boundary and the
        # pool write collapses to one contiguous slice (aligned=True)
        aligned = self.page % self.prefill_chunk == 0

        def _pstep(p, c, t, tbl, ln, nv):
            logits, new_c = paged_prefill_step(cfg, p, c, t, tbl, ln, nv,
                                               aligned=aligned)
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits, toks, new_c

        # ONE compiled prefill: fixed (1, prefill_chunk) tokens against
        # the full table width, whatever the prompt length — the ragged
        # final chunk pads and masks via ``nv`` instead of recompiling.
        # Pools donated for the same in-place reason as _decode.
        self._prefill = jax.jit(_pstep, donate_argnums=(1,))

    def _prompt_blocks(self, s: int) -> int:
        """Blocks the prompt itself occupies (>= 1); decode rows are
        allocated lazily as the sequence crosses page boundaries."""
        return max(1, math.ceil(s / self.page))

    def _sample(self, logits: jax.Array, key, temperature: float):
        """logits (V,) -> int token (same math as ServeEngine._sample)."""
        if temperature <= 0.0:
            return int(jnp.argmax(logits, axis=-1))
        return int(jax.random.categorical(key, logits / temperature,
                                          axis=-1))

    def _sample_tick(self, logits, greedy, keys, temperature: float):
        """One transfer for a whole decode tick -> (B,) host tokens.
        Greedy tokens were already computed in-graph (the sync engine's
        exact row-wise argmax); temperature draws one categorical per
        slot from that slot's own key stream."""
        if temperature <= 0.0:
            return np.asarray(greedy, np.int32)
        toks = jax.vmap(lambda k, l: jax.random.categorical(
            k, l / temperature, axis=-1))(jnp.stack(keys), logits)
        return np.asarray(toks, np.int32)

    # -- the scheduler -----------------------------------------------------

    def run(self, requests: Sequence[Union[Request, Tuple]], *,
            temperature: float = 0.0, seed: int = 0,
            fault_plan: Optional[FaultPlan] = None,
            max_ticks: Optional[int] = None
            ) -> Tuple[List[RequestResult], RunStats]:
        """Serve ``requests`` to completion: every request reaches a
        terminal status (``OK``/``TIMEOUT``/``CANCELLED``/``SHED``/
        ``PREEMPTED``) and ``results`` come back in input order.

        ``fault_plan`` injects the deterministic fault schedule (and
        turns per-tick ``check_invariants`` on); ``max_ticks`` is the
        deadlock canary — exceeding it raises ``RuntimeError`` instead
        of spinning forever (e.g. under a permanent stall fault).
        """
        reqs = as_requests(requests)
        for i, r in enumerate(reqs):
            s = r.prompt.shape[0]
            if s + r.n_steps > self.max_len:
                raise ValueError(
                    f"request {i} does not fit: prompt length {s} + n_steps "
                    f"{r.n_steps} = {s + r.n_steps} exceeds this engine's "
                    f"max_len of {self.max_len}")
            # fail fast instead of deadlocking: an oversized head request
            # would otherwise sit at the queue head forever waiting for
            # blocks the pool can never hold at once
            need = math.ceil((s + r.n_steps) / self.page)
            if need > self.cache.capacity:
                raise ValueError(
                    f"request {i} needs {need} blocks "
                    f"(prompt {s} + n_steps {r.n_steps} = {s + r.n_steps} "
                    f"tokens at page size {self.page}) but the pool's "
                    f"capacity is {self.cache.capacity} blocks "
                    f"(n_blocks={self.cache.n_blocks} minus the null "
                    f"block); construct the engine with n_blocks >= "
                    f"{need + 1} or shorten the request")

        checking = self.check_invariants or fault_plan is not None
        root = jax.random.PRNGKey(seed)
        results: List[Optional[RequestResult]] = [None] * len(reqs)
        out_tokens: List[List[int]] = [[] for _ in reqs]
        emit_times: List[List[float]] = [[] for _ in reqs]
        admitted_at = [-1] * len(reqs)
        admit_time = [0.0] * len(reqs)
        prefix_blocks = [0] * len(reqs)
        preempt_count = [0] * len(reqs)
        # FIFO by (arrival, submission order); deque: admission pops the
        # head O(1); preempted requests re-insert at their sorted spot
        queue = collections.deque(
            sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i)))

        B, NB = self.max_batch, self.nb_table
        slots: List[Optional[_Slot]] = [None] * B
        tables = np.zeros((B, NB), np.int32)          # null block everywhere
        lens = np.zeros((B,), np.int32)               # 0 while prefilling
        pend = np.zeros((B,), np.int32)
        pools = self.cache.pools
        seized: List[Tuple[int, List[int]]] = []      # (release_tick, ids)

        tick = 0
        seq_counter = 0
        decode_steps = 0
        prefill_chunks = 0
        blocks_reused = 0
        blocks_needed = 0
        n_shed = n_timeout = n_cancel = n_preempt = n_stalled = 0
        occupancy: List[float] = []

        def emit(rid: int, tok: int) -> None:
            out_tokens[rid].append(tok)
            emit_times[rid].append(time.perf_counter())

        def finish(rid: int, status: str, detail: str = "") -> None:
            results[rid] = RequestResult(
                tokens=np.asarray(out_tokens[rid], np.int32),
                prompt_len=reqs[rid].prompt.shape[0],
                arrival=reqs[rid].arrival, admitted=admitted_at[rid],
                finished=tick, emit_times=emit_times[rid],
                admit_time=admit_time[rid],
                prefix_blocks=prefix_blocks[rid], status=status,
                detail=detail, preemptions=preempt_count[rid])

        def clear_slot(si: int) -> None:
            self.cache.free(slots[si].ids)
            slots[si] = None
            tables[si] = 0
            lens[si] = 0
            pend[si] = 0

        def retire(si: int, status: str = OK, detail: str = "") -> None:
            rid = slots[si].req
            clear_slot(si)
            finish(rid, status, detail)

        def drop_queued(rids, status: str, detail_fn) -> None:
            nonlocal queue
            dropped = set(rids)
            if not dropped:
                return
            queue = collections.deque(
                r for r in queue if r not in dropped)
            for rid in rids:
                finish(rid, status, detail_fn(rid))

        def preempt(si: int, why: str) -> None:
            """Evict slot ``si``: drop its pool state and either re-queue
            it as PENDING for recompute or, past the preemption budget,
            retire it terminal PREEMPTED."""
            nonlocal n_preempt
            slot = slots[si]
            rid = slot.req
            clear_slot(si)
            preempt_count[rid] += 1
            n_preempt += 1
            if preempt_count[rid] > self.max_preemptions:
                finish(rid, PREEMPTED,
                       f"evicted {preempt_count[rid]} times "
                       f"(max_preemptions={self.max_preemptions}); last "
                       f"eviction at tick {tick}: {why}")
                return
            # recompute: discard emitted tokens and re-admit through the
            # prefix cache — the greedy re-run is bit-identical, and the
            # request's registered pages make the re-prefill cheap
            out_tokens[rid].clear()
            emit_times[rid].clear()
            admitted_at[rid] = -1
            admit_time[rid] = 0.0
            prefix_blocks[rid] = 0
            key = (reqs[rid].arrival, rid)
            pos = 0
            for pos, q in enumerate(queue):           # sorted re-insert
                if (reqs[q].arrival, q) > key:
                    break
            else:
                pos = len(queue)
            queue.insert(pos, rid)

        def victims_latest_first() -> List[int]:
            held = [(slots[si].seq, si) for si in range(B)
                    if slots[si] is not None]
            return [si for _, si in sorted(held, reverse=True)]

        while queue or any(s is not None for s in slots):
            if max_ticks is not None and tick >= max_ticks:
                raise RuntimeError(
                    f"scheduler exceeded max_ticks={max_ticks} with "
                    f"{len(queue)} queued and "
                    f"{sum(s is not None for s in slots)} in-flight "
                    "requests — deadlock canary tripped")

            # 1. faults: release expired seizures, then seize for faults
            # firing now (seizing is a real alloc, so conservation holds)
            stalled = False
            if fault_plan is not None:
                keep = []
                for release, ids in seized:
                    if release <= tick:
                        self.cache.free(ids)
                    else:
                        keep.append((release, ids))
                seized = keep
                for f in fault_plan.seizures(tick):
                    k = self.cache.free_blocks if f.n is None \
                        else min(f.n, self.cache.free_blocks)
                    ids = self.cache.alloc(k) or []
                    if ids:
                        seized.append((tick + f.duration, ids))
                stalled = fault_plan.stalled(tick)
                if stalled:
                    n_stalled += 1

            # 2. cancellations, then 3. timeouts — queued or in-flight,
            # partial tokens kept, blocks released refcount-exactly
            cancelled = [rid for rid in queue
                         if reqs[rid].cancel_at is not None
                         and tick >= reqs[rid].cancel_at]
            drop_queued(cancelled, CANCELLED,
                        lambda rid: f"cancelled at tick "
                                    f"{reqs[rid].cancel_at} while queued")
            n_cancel += len(cancelled)
            for si in range(B):
                slot = slots[si]
                if slot is None:
                    continue
                r = reqs[slot.req]
                if r.cancel_at is not None and tick >= r.cancel_at:
                    retire(si, CANCELLED,
                           f"cancelled at tick {r.cancel_at} in flight")
                    n_cancel += 1
            timed_out = [rid for rid in queue
                         if reqs[rid].deadline is not None
                         and tick > reqs[rid].deadline]
            drop_queued(timed_out, TIMEOUT,
                        lambda rid: f"deadline {reqs[rid].deadline} passed "
                                    "while queued")
            n_timeout += len(timed_out)
            for si in range(B):
                slot = slots[si]
                if slot is None:
                    continue
                r = reqs[slot.req]
                if r.deadline is not None and tick > r.deadline:
                    retire(si, TIMEOUT,
                           f"deadline {r.deadline} passed with "
                           f"{slot.remaining} tokens still to emit")
                    n_timeout += 1

            # 3b. fault-forced preemptions (same victim rule as organic)
            if fault_plan is not None:
                for si in victims_latest_first()[
                        :fault_plan.forced_preemptions(tick)]:
                    preempt(si, "forced by fault plan")

            # 4. shed: queue-cap bound first, then the pluggable policy
            if self.policies:
                for policy in self.policies:
                    waiting = [rid for rid in queue
                               if reqs[rid].arrival <= tick]
                    if not waiting:
                        break
                    entries = queue_entries(tick, waiting, reqs,
                                            self.prefill_chunk)
                    verdicts = dict(policy.shed(tick, entries))
                    drop_queued(list(verdicts), SHED, verdicts.__getitem__)
                    n_shed += len(verdicts)

            # 5. admit: FIFO while a slot and the PROMPT reservation fit
            # (decode blocks grow lazily); a stalled tick admits nothing
            while not stalled and queue \
                    and reqs[queue[0]].arrival <= tick:
                free_slots = [i for i, s in enumerate(slots) if s is None]
                if not free_slots:
                    break
                rid = queue[0]
                r = reqs[rid]
                s = r.prompt.shape[0]
                need = self._prompt_blocks(s)
                matched: List[int] = []
                if self.prefix_cache:
                    # cap: >= 1 suffix token must prefill (first-token
                    # logits), which also keeps every later write past
                    # the shared pages — see the module docstring
                    matched = self.cache.match_prefix(
                        r.prompt)[:(s - 1) // self.page]
                    self.cache.acquire(matched)
                ids = self.cache.alloc(need - len(matched))
                if ids is None:
                    if matched:
                        self.cache.free(matched)    # drop the hold, wait
                    break                           # wait for retirements
                queue.popleft()
                si = free_slots[0]
                admitted_at[rid] = tick
                admit_time[rid] = time.perf_counter()
                prefix_blocks[rid] = len(matched)
                blocks_reused += len(matched)
                blocks_needed += (s - 1) // self.page
                slots[si] = _Slot(req=rid, ids=matched + ids,
                                  remaining=r.n_steps,
                                  key=jax.random.fold_in(root, rid),
                                  filled=len(matched) * self.page,
                                  registered=len(matched),
                                  seq=seq_counter)
                seq_counter += 1
                tables[si, :] = 0
                tables[si, :len(slots[si].ids)] = slots[si].ids
                lens[si] = 0                        # ACTIVE only after prefill

            occupancy.append(self.cache.occupancy())

            # 6. prefill: one chunk per PREFILLING slot, then decode below
            # — long prompts stall a tick by at most one chunk of compute
            C = self.prefill_chunk
            for si in range(B):
                slot = slots[si]
                if stalled or slot is None or lens[si] > 0:
                    continue
                r = reqs[slot.req]
                s = r.prompt.shape[0]
                pos = slot.filled
                nv = min(C, s - pos)
                toks = np.zeros((1, C), np.int32)
                toks[0, :nv] = r.prompt[pos:pos + nv]
                # jnp.array (not asarray): don't alias scheduler state the
                # async dispatch would race with (same rationale as decode)
                logits, greedy, pools = self._prefill(
                    self.params, pools, jnp.array(toks),
                    jnp.array(tables[si:si + 1]),
                    jnp.array([pos], np.int32), jnp.array([nv], np.int32))
                jax.block_until_ready((logits, greedy, pools))
                prefill_chunks += 1
                slot.filled = pos + nv
                if self.prefix_cache:
                    full = slot.filled // self.page
                    if full > slot.registered:
                        self.cache.register_prefix(
                            r.prompt[:full * self.page], slot.ids[:full])
                        slot.registered = full
                if slot.filled == s:                # prefill done -> ACTIVE
                    if temperature <= 0.0:
                        tok = int(greedy[0])
                    else:
                        slot.key, sub = jax.random.split(slot.key)
                        tok = self._sample(logits[0, -1], sub, temperature)
                    lens[si] = s
                    pend[si] = tok
                    emit(slot.req, tok)
                    slot.remaining -= 1
                    if slot.remaining == 0:
                        retire(si)

            # 7a. grow: each ACTIVE slot writing into a fresh page this
            # tick allocates its next block; exhaustion preempts victims
            # latest-admitted first (possibly the grower itself) instead
            # of deadlocking the tick
            for si in range(B):
                if stalled:
                    break
                slot = slots[si]
                if slot is None or lens[si] == 0:
                    continue
                if int(lens[si]) < len(slot.ids) * self.page:
                    continue                        # page not full yet
                got = self.cache.alloc(1)
                if got is None:
                    for vi in victims_latest_first():
                        victim_is_self = vi == si
                        preempt(vi, "pool exhausted growing slot "
                                    f"{si} at length {int(lens[si])}")
                        if victim_is_self:
                            break
                        got = self.cache.alloc(1)
                        if got is not None:
                            break
                if got is None or slots[si] is None:
                    continue                        # grower was evicted
                slot.ids.append(got[0])
                tables[si, len(slot.ids) - 1] = got[0]

            active = [] if stalled else \
                [i for i, sl in enumerate(slots)
                 if sl is not None and lens[i] > 0]
            if active:
                # jnp.array (not asarray): asarray zero-copies numpy on CPU,
                # so the async decode would alias these host buffers while
                # the scheduler keeps mutating them (retire zeroes table
                # rows, lens advance) — a read/write race on real state.
                # PREFILLING slots already sit at lens 0 so the decode
                # masks them like idle slots; their table rows are real
                # but every read is kv_len-masked and the pend-0 write
                # lands at row 0 of their first block, which the next
                # chunk overwrites (positions are absolute).
                dec_tables = tables.copy()
                for si in range(B):
                    if slots[si] is not None and lens[si] == 0:
                        dec_tables[si] = 0          # scatter to null block
                logits, greedy, pools = self._decode(
                    self.params, pools, jnp.array(pend[:, None]),
                    jnp.array(dec_tables), jnp.array(lens))
                # materialize the whole tick before dispatching anything
                # else: overlapping executions on XLA:CPU's shared thunk
                # thread pool perturb parallel-reduction numerics, and a
                # near-tie argmax flip breaks bitwise greedy parity with
                # the synchronous engine (whose single lax.scan decode
                # loop never overlaps itself).  The greedy-token transfer
                # below already serialized most of the tick; this pins
                # the pool updates too, so no computation from run() is
                # ever still in flight when the caller's next one starts.
                jax.block_until_ready((logits, greedy, pools))
                decode_steps += 1
                lens[active] += 1
                keys = None
                if temperature > 0.0:
                    keys = []
                    active_set = set(active)
                    for si in range(B):
                        if si in active_set:
                            slots[si].key, sub = jax.random.split(
                                slots[si].key)
                            keys.append(sub)
                        else:
                            keys.append(root)     # idle slot: discarded
                toks = self._sample_tick(logits[:, -1], greedy, keys,
                                         temperature)
                for si in active:
                    slot = slots[si]
                    tok = int(toks[si])
                    pend[si] = tok
                    emit(slot.req, tok)
                    slot.remaining -= 1
                    if slot.remaining == 0:
                        retire(si)
            tick += 1
            if checking:
                self.cache.check_invariants()
                self._assert_refcount_exact(slots, seized)

        # the run can end inside a seizure window (every request already
        # terminal); hand the fault-held blocks back so the pool drains
        for _, ids in seized:
            self.cache.free(ids)
        seized = []
        if checking:
            self.cache.check_invariants()
            self._assert_refcount_exact(slots, seized)

        self.cache.pools = pools
        n_ok = sum(1 for r in results if r is not None and r.status == OK)
        stats = RunStats(
            requests=len(reqs),
            tokens=sum(len(t) for t in out_tokens),
            ticks=tick,
            decode_steps=decode_steps,
            prefill_chunks=prefill_chunks,
            prefix_blocks_reused=blocks_reused,
            prefix_blocks_needed=blocks_needed,
            prefix_hit_rate=(blocks_reused / blocks_needed
                             if blocks_needed else 0.0),
            occupancy_mean=float(np.mean(occupancy)) if occupancy else 0.0,
            occupancy_max=float(np.max(occupancy)) if occupancy else 0.0,
            completed=n_ok, shed=n_shed, timeouts=n_timeout,
            cancelled=n_cancel, preemptions=n_preempt,
            stalled_ticks=n_stalled,
        )
        return [r for r in results if r is not None], stats

    def _assert_refcount_exact(self, slots, seized) -> None:
        """Every reference the pool counts must be owned by exactly one
        holder the scheduler knows: a slot's block list or a fault
        seizure.  (Parked prefix blocks sit at refcount 0 and are the
        cache's own business — ``check_invariants`` covers them.)"""
        expected: Dict[int, int] = collections.Counter()
        for slot in slots:
            if slot is not None:
                expected.update(slot.ids)
        for _, ids in seized:
            expected.update(ids)
        for b in range(1, self.cache.n_blocks):
            if self.cache.ref_count(b) != expected.get(b, 0):
                raise AssertionError(
                    f"refcount drift on block {b}: cache counts "
                    f"{self.cache.ref_count(b)} but the scheduler holds "
                    f"{expected.get(b, 0)} references")

    def generate(self, tokens: np.ndarray, *, n_steps: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Batch convenience mirroring ``ServeEngine.generate``: serve the
        (B, S) prompts (all arriving at tick 0) and return (B, n_steps)."""
        tokens = np.asarray(tokens, np.int32)
        reqs = [Request(prompt=row, n_steps=n_steps) for row in tokens]
        results, _ = self.run(reqs, temperature=temperature, seed=seed)
        return np.stack([r.tokens for r in results])
