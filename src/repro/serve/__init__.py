"""Serving layer: batched engine over prefill + decode steps."""

from repro.serve.engine import ServeEngine, GenerateResult  # noqa: F401
