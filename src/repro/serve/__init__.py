"""Serving layer: synchronous batched engine (the parity oracle) and the
continuous-batching engine over the block-paged KV cache, behind the
shared typed ``run(trace)`` protocol in :mod:`repro.serve.api`."""

from repro.serve.api import (Request, RequestResult,  # noqa: F401
                             RunStats, ServeAPI, as_requests)
from repro.serve.engine import ServeEngine, GenerateResult  # noqa: F401
from repro.serve.paged_cache import (PagedKVCache,  # noqa: F401
                                     default_page_size, prefix_digests)
from repro.serve.paged_engine import PagedServeEngine  # noqa: F401
from repro.serve.resilience import (CANCELLED, OK, PREEMPTED,  # noqa: F401
                                    SHED, STATUSES, TIMEOUT,
                                    AdmissionPolicy, DeadlineAwareShed,
                                    Fault, FaultPlan, FIFOPolicy,
                                    QueueCapPolicy, QueueEntry,
                                    min_service_ticks)
from repro.serve.traces import (get_trace, list_traces,  # noqa: F401
                                register_trace)
