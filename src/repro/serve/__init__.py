"""Serving layer: synchronous batched engine (the parity oracle) and the
continuous-batching engine over the block-paged KV cache."""

from repro.serve.engine import ServeEngine, GenerateResult  # noqa: F401
from repro.serve.paged_cache import (PagedKVCache,  # noqa: F401
                                     default_page_size, prefix_digests)
from repro.serve.paged_engine import (PagedServeEngine,  # noqa: F401
                                      Request, RequestResult)
