"""Batched serving engine: continuous prefill + greedy/temperature decode.

The engine jits one ``prefill`` and one ``decode_step`` per (batch, length)
bucket and runs synchronous batched generation — the serve-side driver for
the decode_32k / long_500k dry-run cells, and example ``serve_demo.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill
from repro.serve.api import Request, RequestResult, RunStats, as_requests

__all__ = ["ServeEngine", "GenerateResult"]


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, n_generated)
    prompt_len: int
    steps: int


class ServeEngine:
    """``prefill_pad=True`` right-pads every prompt to the ``max_len``
    bucket before prefilling (``last_pos`` slices the true last token's
    logits).  Semantically identity — padded rows are causal-masked away
    and overwritten by decode — but it pins the attention KV length to
    the aligned ``max_len`` for *every* request: XLA:CPU's blocked
    reductions only round bit-identically across engines when T matches,
    so the paged parity tests run their oracle in this mode (the paged
    engine always attends over the full gathered table width)."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 prefill_pad: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.prefill_pad = prefill_pad
        if prefill_pad:
            self._prefill = jax.jit(
                lambda p, b, lp: prefill(cfg, p, b, max_len=max_len,
                                         last_pos=lp))
        else:
            self._prefill = jax.jit(
                lambda p, b: prefill(cfg, p, b, max_len=max_len))
        self._decode = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t,
                                                                pos))

    def _sample(self, logits, key, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature,
                                      axis=-1).astype(jnp.int32)

    def generate(self, tokens: np.ndarray, *, n_steps: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extras: Optional[Dict] = None) -> GenerateResult:
        """tokens: (B, S) int32 prompt batch -> greedy/temperature decode."""
        B, S = tokens.shape
        if S + n_steps > self.max_len:
            raise ValueError(
                f"request does not fit its bucket: prompt length {S} + "
                f"n_steps {n_steps} = {S + n_steps} exceeds this engine's "
                f"max_len bucket of {self.max_len} (prefill/decode are "
                "jitted per (batch, max_len) bucket; build a ServeEngine "
                f"with max_len >= {S + n_steps} or shorten the request)")
        if self.prefill_pad:
            batch = {"tokens": jnp.asarray(
                np.pad(tokens, ((0, 0), (0, self.max_len - S))), jnp.int32)}
            if extras:
                batch.update(extras)
            logits, cache = self._prefill(self.params, batch,
                                          jnp.int32(S - 1))
        else:
            batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
            if extras:
                batch.update(extras)
            logits, cache = self._prefill(self.params, batch)
        # split BEFORE the first sample: the root key is only ever split,
        # never consumed, so the first token's subkey is independent of
        # the step subkeys derived from the same root
        key, sub = jax.random.split(jax.random.PRNGKey(seed))
        out: List[np.ndarray] = []
        tok = self._sample(logits[:, -1], sub, temperature)[:, None]
        for i in range(n_steps):
            out.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(S + i))
            tok = self._sample(logits[:, -1], sub, temperature)[:, None]
        return GenerateResult(tokens=np.concatenate(out, axis=1),
                              prompt_len=S, steps=n_steps)

    # -- shared serve protocol (repro.serve.api.ServeAPI) -------------------

    def run(self, requests: Sequence[Union[Request, Tuple]], *,
            temperature: float = 0.0, seed: int = 0, batch: int = 1
            ) -> Tuple[List[RequestResult], RunStats]:
        """Replay a trace synchronously: FIFO groups of up to ``batch``
        requests, every prompt right-padded to the group max, every
        request decoded for the group-max step count and sliced to its
        own ``n_steps`` — the padding/convoy semantics this engine has
        always had, behind the same ``run(trace)`` protocol the paged
        engine speaks.

        ``batch=1`` (the default) serves each request solo and is the
        bit-exact greedy oracle: request *i*'s tokens equal
        ``generate(prompt[None], n_steps=r.n_steps)``.  Arrival ticks
        are ignored beyond FIFO order — a synchronous bucket engine has
        no scheduler clock, so ``admitted``/``finished`` report the
        group index and every token's emit time is the group's
        completion time (tokens only materialize at batch end).
        """
        if batch < 1:
            raise ValueError(f"batch={batch} < 1")
        reqs = as_requests(requests)
        order = sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i))
        results: List[Optional[RequestResult]] = [None] * len(reqs)
        decode_steps = 0
        groups = [order[i:i + batch] for i in range(0, len(order), batch)]
        for gi, group in enumerate(groups):
            s_max = max(reqs[i].prompt.shape[0] for i in group)
            n_max = max(reqs[i].n_steps for i in group)
            padded = np.stack(
                [np.pad(reqs[i].prompt,
                        (0, s_max - reqs[i].prompt.shape[0]))
                 for i in group])
            t_admit = time.perf_counter()
            gen = self.generate(padded, n_steps=n_max,
                                temperature=temperature, seed=seed)
            t_done = time.perf_counter()
            decode_steps += n_max
            for row, i in enumerate(group):
                r = reqs[i]
                results[i] = RequestResult(
                    tokens=np.asarray(gen.tokens[row, :r.n_steps], np.int32),
                    prompt_len=r.prompt.shape[0],
                    arrival=r.arrival, admitted=gi, finished=gi,
                    emit_times=[t_done] * r.n_steps, admit_time=t_admit)
        stats = RunStats(
            requests=len(reqs),
            tokens=sum(r.n_steps for r in reqs),
            decode_steps=decode_steps,
            batches=len(groups),
        )
        return [r for r in results if r is not None], stats
