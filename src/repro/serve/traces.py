"""Registry of serve trace generators.

These used to live as module-level helpers in
``benchmarks/serve_bench.py``; the fleet planner's traffic scenarios
need to replay the *same* request mixes the bench measures, so the
generators are promoted here behind a tiny registry and both consumers
draw from it.  The rng draw order of every generator is kept exactly as
the bench had it — the committed ``BENCH_serve.json`` trend rows stay
comparable across the move.

A trace generator has the signature::

    fn(n_requests, vocab, seed=0, **kw) -> List[repro.serve.Request]

Register your own with :func:`register_trace` (see the ROADMAP recipe)::

    @register_trace("my_mix")
    def make_my_mix(n_requests, vocab, seed=0):
        ...
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.serve.api import Request

__all__ = ["register_trace", "get_trace", "list_traces",
           "make_trace", "make_shared_trace", "make_longprompt_trace",
           "make_overload_trace"]

# defaults shared with benchmarks/serve_bench.py: requests are clamped
# to a 128-token engine bucket; the shared-prefix recipe fixes a
# 256-token (2-page) system prompt inside a 384-token bucket
TRACE_MAX_LEN = 128
SHARED_PREFIX_LEN = 256

_REGISTRY: Dict[str, Callable[..., List[Request]]] = {}


def register_trace(name: str):
    """Decorator: register a trace generator under ``name``."""
    def deco(fn: Callable[..., List[Request]]):
        if name in _REGISTRY:
            raise ValueError(f"trace {name!r} is already registered")
        _REGISTRY[name] = fn
        return fn
    return deco


def get_trace(name: str) -> Callable[..., List[Request]]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown trace {name!r}; registered: {list_traces()}") from None


def list_traces() -> Sequence[str]:
    return sorted(_REGISTRY)


@register_trace("base")
def make_trace(n_requests: int, vocab: int, seed: int = 0,
               max_len: int = TRACE_MAX_LEN) -> List[Request]:
    """Ragged request mix: mostly short chat turns, a heavy tail of long
    generations, Poisson-ish arrivals in scheduler ticks."""
    rng = np.random.default_rng(seed)
    reqs = []
    tick = 0
    for i in range(n_requests):
        tick += int(rng.poisson(1))
        s = int(rng.integers(6, 72))
        if rng.random() < 0.2:                     # long-tail generations
            n = int(rng.integers(48, 96))
        else:
            n = int(rng.integers(4, 16))
        n = min(n, max_len - s)
        prompt = rng.integers(0, vocab, (s,)).astype(np.int32)
        reqs.append(Request(prompt=prompt, n_steps=n, arrival=tick))
    return reqs


@register_trace("shared_prefix")
def make_shared_trace(n_requests: int, vocab: int, seed: int = 0,
                      prefix_len: int = SHARED_PREFIX_LEN) -> List[Request]:
    """Shared-system-prompt recipe: one fixed ``prefix_len``-token prefix
    (page-aligned so its pages hash into the prefix index), a short
    unique tail per request, staggered arrivals."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, (prefix_len,)).astype(np.int32)
    reqs = []
    tick = 0
    for i in range(n_requests):
        tick += int(rng.poisson(1))
        tail = rng.integers(0, vocab,
                            (int(rng.integers(8, 48)),)).astype(np.int32)
        n = int(rng.integers(6, 20))
        reqs.append(Request(prompt=np.concatenate([prefix, tail]),
                            n_steps=n, arrival=tick))
    return reqs


@register_trace("overload")
def make_overload_trace(n_requests: int, vocab: int, seed: int = 0,
                        max_len: int = TRACE_MAX_LEN,
                        burst: int = 6,
                        deadline_frac: float = 0.5) -> List[Request]:
    """Offered load past capacity: requests arrive in bursts of
    ``burst`` per gap (far faster than a small engine drains them), and
    ``deadline_frac`` of them carry a deadline a few times their own
    service time — tight enough that sustained queueing blows it.  The
    graceful-degradation scenario: without shedding the queue and TTFT
    grow without bound; with a ``max_queue`` bound plus a deadline-aware
    policy the engine sheds doomed work and keeps the rest inside SLO."""
    rng = np.random.default_rng(seed)
    reqs = []
    tick = 0
    for i in range(n_requests):
        if i % burst == 0 and i:
            tick += int(rng.poisson(2))             # bursts, not a stream
        # draws scale with max_len so a 256-token bucket gets multi-page
        # requests (sequence growth past a 128-row page is what makes
        # pool exhaustion — and therefore preemption — reachable)
        s = int(rng.integers(6, max(7, min(120, max_len - 40))))
        n = int(rng.integers(8, 64))
        n = min(n, max_len - s)
        deadline = None
        if rng.random() < deadline_frac:
            # ~3-5x the request's own ticks of work: generous alone,
            # hopeless behind a deep queue
            deadline = tick + int((s // 32 + n) * rng.uniform(3.0, 5.0))
        prompt = rng.integers(0, vocab, (s,)).astype(np.int32)
        reqs.append(Request(prompt=prompt, n_steps=n, arrival=tick,
                            deadline=deadline))
    return reqs


@register_trace("long_prompt")
def make_longprompt_trace(n_requests: int, vocab: int,
                          seed: int = 0) -> List[Request]:
    """Long-prompt-under-load: every 4th request drags a multi-page
    prompt through admission while short decode-heavy requests stream —
    the monolithic-prefill stall lands on *their* token gaps."""
    rng = np.random.default_rng(seed)
    reqs = []
    tick = 0
    for i in range(n_requests):
        tick += int(rng.poisson(1))
        if i % 4 == 1:
            s = int(rng.integers(200, 340))
            n = int(rng.integers(4, 10))
        else:
            s = int(rng.integers(8, 48))
            n = int(rng.integers(12, 32))
        prompt = rng.integers(0, vocab, (s,)).astype(np.int32)
        reqs.append(Request(prompt=prompt, n_steps=n, arrival=tick))
    return reqs
