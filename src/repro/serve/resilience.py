"""Graceful-degradation policies for the paged serve engine.

The scheduler in :mod:`repro.serve.paged_engine` used to have exactly
one failure behavior: oversized requests fail fast at validation.
Everything else — pool exhaustion mid-flight, unbounded arrival queues,
slow requests holding blocks forever — either backpressured silently or
degraded every other request's latency.  This module holds the three
host-side pieces that give the scheduler *terminal states other than
OK*, plus the deterministic fault-injection harness the chaos suite
drives them with:

* **Terminal statuses** — every request ends in exactly one of
  :data:`OK` / :data:`TIMEOUT` / :data:`CANCELLED` / :data:`SHED` /
  :data:`PREEMPTED`, carried on ``RequestResult.status`` with a
  human-readable ``detail``.  ``PREEMPTED`` is terminal only when a
  request exceeds the engine's ``max_preemptions`` re-admission budget;
  an ordinarily preempted request re-queues as PENDING and finishes
  ``OK`` with bit-identical tokens (the greedy-parity suite pins it).

* **Admission policies** — a pluggable :class:`AdmissionPolicy` decides,
  each tick, which *waiting* requests to shed before admission runs.
  :class:`FIFOPolicy` never sheds (the pre-resilience baseline);
  :class:`QueueCapPolicy` bounds the arrival deque (newest arrivals
  shed first — FIFO fairness for the requests already waiting);
  :class:`DeadlineAwareShed` sheds requests whose deadline is already
  unreachable even on an idle engine (``tick + min_service_ticks - 1 >
  deadline``) so doomed work never occupies a slot.  Policies are pure
  host logic over :class:`QueueEntry` views, so the fleet planner's
  scheduler replica (:func:`repro.fleet.capacity.simulate_trace`) runs
  the *same* policy objects tick-for-tick.

* **FaultPlan** — a deterministic schedule of injected faults:
  ``exhaust`` (seize free blocks from the allocator for a window),
  ``preempt`` (force victim preemptions), ``stall`` (the engine loses
  whole ticks of data-plane work while deadlines keep aging).  Effects
  are a pure function of the tick, so the same plan replays identically
  on the real engine and on the host replica, and
  ``PagedKVCache.check_invariants()`` can be asserted after every tick
  under test.

The scheduler's tick order with resilience enabled (shared verbatim by
``PagedServeEngine.run`` and ``simulate_trace``)::

    1. faults      release expired seizures; seize blocks for exhaust
                   faults firing now; note stall/forced-preempt effects
    2. cancel      cancel_at <= tick   -> CANCELLED (queued or in-flight)
    3. timeout     deadline  <  tick   -> TIMEOUT   (queued or in-flight)
    4. force-preempt   victims latest-admitted-first (fault-injected)
    5. shed        queue-cap bound, then the pluggable policy -> SHED
    6. admit       FIFO while a slot + the PROMPT block reservation fit
    7. prefill     one chunk per PREFILLING slot        [skipped if stalled]
    8. decode      grow each ACTIVE slot's block on page boundary —
                   alloc None preempts victims latest-admitted-first —
                   then one decode step for all actives [skipped if stalled]

Steps 6-8 are the data plane (a stalled tick skips them); steps 1-5 are
the control plane and always run, which is what makes deadlines honest
under stalls.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["OK", "TIMEOUT", "CANCELLED", "SHED", "PREEMPTED", "STATUSES",
           "QueueEntry", "AdmissionPolicy", "FIFOPolicy", "QueueCapPolicy",
           "DeadlineAwareShed", "Fault", "FaultPlan", "min_service_ticks"]

# -- terminal states --------------------------------------------------------

OK = "OK"                  # all requested tokens emitted
TIMEOUT = "TIMEOUT"        # deadline passed before the last token
CANCELLED = "CANCELLED"    # client gave up (Request.cancel_at)
SHED = "SHED"              # rejected by admission control, never ran
PREEMPTED = "PREEMPTED"    # evicted past the max_preemptions budget

STATUSES = (OK, TIMEOUT, CANCELLED, SHED, PREEMPTED)


def min_service_ticks(prompt_len: int, n_steps: int, chunk: int) -> int:
    """Ticks a request needs on an otherwise idle engine: one tick per
    prefill chunk (the last chunk's tick also emits the first token)
    plus one decode tick per remaining token.  The deadline-aware shed
    policy uses this as its feasibility bound — a request whose deadline
    precedes even this can never finish and is shed instead of admitted."""
    chunks = max(1, math.ceil(max(1, prompt_len) / chunk))
    return chunks + max(0, n_steps - 1)


# -- admission policies -----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueueEntry:
    """One waiting request as the admission policies see it."""

    rid: int                    # index into the run's request list
    arrival: int
    deadline: Optional[int]
    prompt_len: int
    n_steps: int
    est_ticks: int              # min_service_ticks for this request
    waited: int                 # tick - arrival


class AdmissionPolicy:
    """Decides which waiting requests to shed before admission.

    ``shed(tick, queue)`` sees the waiting queue (arrival <= tick, FIFO
    order) and returns ``(rid, reason)`` pairs to reject this tick.  The
    base class sheds nothing.  Policies must be deterministic functions
    of their inputs — the fleet replica replays them tick-for-tick.
    """

    name = "fifo"

    def shed(self, tick: int, queue: Sequence[QueueEntry]
             ) -> List[Tuple[int, str]]:
        return []


class FIFOPolicy(AdmissionPolicy):
    """The pre-resilience baseline: wait forever, shed nothing."""


class QueueCapPolicy(AdmissionPolicy):
    """Bound the waiting queue at ``max_queue`` entries.

    Newest arrivals shed first: the requests already waiting keep their
    FIFO claim, and the rejection names the bound so operators can size
    it from the error alone.
    """

    name = "queue_cap"

    def __init__(self, max_queue: int):
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} < 1")
        self.max_queue = int(max_queue)

    def shed(self, tick: int, queue: Sequence[QueueEntry]
             ) -> List[Tuple[int, str]]:
        excess = len(queue) - self.max_queue
        if excess <= 0:
            return []
        newest = sorted(queue, key=lambda e: (e.arrival, e.rid))[-excess:]
        return [(e.rid,
                 f"queue length {len(queue)} exceeds max_queue "
                 f"{self.max_queue} at tick {tick} (newest arrivals shed "
                 "first)") for e in newest]


class DeadlineAwareShed(AdmissionPolicy):
    """Shed waiting requests whose deadline is already unreachable.

    A request needing ``min_service_ticks`` cannot finish before
    ``tick + min_service_ticks - 1`` even on an idle engine; if that
    beats its deadline (plus ``slack`` grace ticks) it is shed *now*
    rather than admitted, run, and timed out — overload capacity goes
    to requests that can still meet their SLO.
    """

    name = "deadline_shed"

    def __init__(self, slack: int = 0):
        self.slack = int(slack)

    def shed(self, tick: int, queue: Sequence[QueueEntry]
             ) -> List[Tuple[int, str]]:
        out = []
        for e in queue:
            if e.deadline is None:
                continue
            finish = tick + e.est_ticks - 1
            if finish > e.deadline + self.slack:
                out.append((e.rid,
                            f"deadline {e.deadline} unreachable: earliest "
                            f"finish is tick {finish} (+{self.slack} slack) "
                            f"given {e.est_ticks} service ticks"))
        return out


def queue_entries(tick: int, waiting: Sequence[int], reqs,
                  chunk: int) -> List[QueueEntry]:
    """Policy view of the waiting queue (arrival <= tick), FIFO order.
    Shared by the engine and the fleet replica so both hand policies
    byte-identical inputs."""
    out = []
    for rid in waiting:
        r = reqs[rid]
        s = int(np.asarray(r.prompt).shape[0])
        out.append(QueueEntry(
            rid=rid, arrival=r.arrival, deadline=r.deadline,
            prompt_len=s, n_steps=r.n_steps,
            est_ticks=min_service_ticks(s, r.n_steps, chunk),
            waited=tick - r.arrival))
    return out


# -- deterministic fault injection ------------------------------------------

_FAULT_KINDS = ("exhaust", "preempt", "stall")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``kind``:

    * ``"exhaust"`` — seize ``n`` free blocks (``None`` = every free
      block) from the allocator at ``tick``; they return after
      ``duration`` ticks.  Seized blocks are real allocations, so the
      conservation invariant keeps holding while they are out.
    * ``"preempt"`` — force ``n`` victim preemptions at ``tick``
      (latest-admitted first, the same victim rule organic exhaustion
      uses).
    * ``"stall"`` — the engine loses ``duration`` whole ticks of
      data-plane work starting at ``tick``; deadlines keep aging.

    ``every``/``until`` make a fault periodic: it re-fires each
    ``every`` ticks from ``tick`` through ``until`` (inclusive;
    ``None`` = forever).
    """

    kind: str
    tick: int
    n: Optional[int] = None
    duration: int = 1
    every: Optional[int] = None
    until: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_FAULT_KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick {self.tick} < 0")
        if self.duration < 1:
            raise ValueError(f"fault duration {self.duration} < 1")
        if self.every is not None and self.every < 1:
            raise ValueError(f"fault every={self.every} < 1")

    def fires_at(self, tick: int) -> bool:
        if self.every is None:
            return tick == self.tick
        if tick < self.tick or (self.until is not None
                                and tick > self.until):
            return False
        return (tick - self.tick) % self.every == 0


class FaultPlan:
    """A deterministic, replayable schedule of injected faults.

    Effects are a pure function of the tick — the plan holds no run
    state — so one plan drives the real engine and the fleet replica
    identically, and re-running a plan reproduces the failure
    bit-for-bit.  ``seed`` only matters to :meth:`random`, which draws
    a reproducible chaos schedule from it.
    """

    def __init__(self, seed: int = 0, faults: Sequence[Fault] = ()):
        self.seed = int(seed)
        self.faults = tuple(faults)
        for f in self.faults:
            if not isinstance(f, Fault):
                raise TypeError(f"FaultPlan faults must be Fault objects, "
                                f"got {type(f).__name__}")

    def seizures(self, tick: int) -> List[Fault]:
        """Exhaust faults firing this tick."""
        return [f for f in self.faults
                if f.kind == "exhaust" and f.fires_at(tick)]

    def forced_preemptions(self, tick: int) -> int:
        """Victim count to force-preempt this tick."""
        return sum((f.n or 1) for f in self.faults
                   if f.kind == "preempt" and f.fires_at(tick))

    def stalled(self, tick: int) -> bool:
        """True when any stall fault's window covers this tick."""
        for f in self.faults:
            if f.kind != "stall":
                continue
            if f.every is None:
                if f.tick <= tick < f.tick + f.duration:
                    return True
            else:
                if tick >= f.tick and (f.until is None or tick <= f.until) \
                        and (tick - f.tick) % f.every < f.duration:
                    return True
        return False

    @classmethod
    def random(cls, seed: int, *, horizon: int, n_faults: int = 6,
               max_seize: int = 4) -> "FaultPlan":
        """A reproducible chaos schedule: ``n_faults`` faults of random
        kind/tick/size drawn from ``seed`` over ``[0, horizon)`` ticks.
        The chaos suite sweeps seeds; any failure names its seed, so
        every red run replays exactly."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = _FAULT_KINDS[int(rng.integers(0, len(_FAULT_KINDS)))]
            tick = int(rng.integers(0, max(1, horizon)))
            if kind == "exhaust":
                faults.append(Fault(kind, tick,
                                    n=int(rng.integers(1, max_seize + 1)),
                                    duration=int(rng.integers(1, 6))))
            elif kind == "preempt":
                faults.append(Fault(kind, tick,
                                    n=int(rng.integers(1, 3))))
            else:
                faults.append(Fault(kind, tick,
                                    duration=int(rng.integers(1, 4))))
        return cls(seed=seed, faults=faults)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, faults={list(self.faults)})"
