"""The one serve request/result schema both engines speak.

Before this module, :class:`~repro.serve.engine.ServeEngine` consumed
padded ``(B, S)`` token matrices while :class:`PagedServeEngine.run`
took bare ``(prompt, n_steps, arrival)`` tuples and returned an ad-hoc
stats dict — every consumer (bench, demo, tests, and now the fleet
planner) re-invented the conversion.  The typed surface is:

* :class:`Request` — one serve request: prompt tokens, tokens to
  generate, arrival tick;
* :class:`RequestResult` — per-request outcome: generated tokens plus
  the scheduling record (admitted/finished ticks, per-token emit
  wall-times, prefix-cache pages taken);
* :class:`RunStats` — the run-level accounting every engine returns.
  It is a dataclass but stays **dict-compatible** (``stats["tokens"]``,
  ``.get``, ``.keys``) so the pre-existing consumers keep working;
* ``run(trace, *, temperature=0.0, seed=0)`` — the shared protocol:
  both engines take a sequence of :class:`Request` (or legacy tuples,
  coerced by :func:`as_requests` for one more release) and return
  ``(List[RequestResult], RunStats)``.

The tuple form is deprecated: :func:`as_requests` emits a one-shot
:class:`DeprecationWarning` the first time it coerces one, and the shim
is dropped once external traces have moved to :class:`Request`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import (Any, Dict, Iterator, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import numpy as np

__all__ = ["Request", "RequestResult", "RunStats", "ServeAPI",
           "as_requests"]


@dataclasses.dataclass
class Request:
    """One serve request: ``prompt`` (1-D int32 tokens), ``n_steps``
    tokens to generate, ``arrival`` tick at which it may be admitted.

    ``deadline`` is the absolute tick the request must have *finished*
    by — crossing it retires the request with status ``TIMEOUT``
    (partial tokens kept).  ``cancel_at`` is the tick the client gives
    up, queued or in-flight, retiring with ``CANCELLED``.  Both are
    optional; ``None`` means the pre-resilience wait-forever behavior.
    """

    prompt: np.ndarray
    n_steps: int
    arrival: int = 0
    deadline: Optional[int] = None
    cancel_at: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    tokens: np.ndarray              # (n_steps,) generated tokens
    prompt_len: int
    arrival: int                    # tick the request became eligible
    admitted: int                   # tick it was admitted (-1: never)
    finished: int                   # tick its last token was emitted
    emit_times: List[float]         # perf_counter() per emitted token
    admit_time: float = 0.0         # perf_counter() at admission (TTFT base)
    prefix_blocks: int = 0          # pages taken from the prefix cache
    status: str = "OK"              # terminal state (repro.serve.resilience)
    detail: str = ""                # human-readable reason for non-OK ends
    preemptions: int = 0            # times this request was evicted/requeued


@dataclasses.dataclass
class RunStats:
    """Run-level accounting shared by every engine.

    Fields an engine has no notion of stay at their zero defaults (the
    synchronous bucket engine has no block pool, so its occupancy and
    prefix counters are 0; it reports ``batches`` instead).  Mapping-
    style access (``stats["tokens"]``) is kept for the consumers that
    predate this schema.
    """

    requests: int = 0
    tokens: int = 0                 # requested tokens actually emitted
    ticks: int = 0
    decode_steps: int = 0
    prefill_chunks: int = 0
    batches: int = 0                # sync bucket replay only
    prefix_blocks_reused: int = 0
    prefix_blocks_needed: int = 0
    prefix_hit_rate: float = 0.0
    occupancy_mean: float = 0.0
    occupancy_max: float = 0.0
    # -- graceful-degradation accounting (repro.serve.resilience) ----------
    completed: int = 0              # requests that ended with status OK
    shed: int = 0                   # rejected by admission control
    timeouts: int = 0               # deadline crossed before completion
    cancelled: int = 0              # client cancel_at reached
    preemptions: int = 0            # evictions (incl. re-queues that ran OK)
    stalled_ticks: int = 0          # data-plane ticks lost to stall faults

    # -- dict compatibility -------------------------------------------------

    def __getitem__(self, key: str) -> Any:
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key: str, default: Any = None) -> Any:
        return getattr(self, key, default)

    def keys(self) -> Iterator[str]:
        return iter(f.name for f in dataclasses.fields(self))

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@runtime_checkable
class ServeAPI(Protocol):
    """The shared serve protocol: replay a trace to completion."""

    def run(self, requests: Sequence[Union[Request, Tuple]], *,
            temperature: float = 0.0, seed: int = 0
            ) -> Tuple[List[RequestResult], RunStats]:
        ...


_WARNED: set = set()


def _warn_once(key: str, msg: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, DeprecationWarning, stacklevel=3)


def as_requests(trace: Sequence[Union[Request, Tuple]]) -> List[Request]:
    """Coerce a trace to typed :class:`Request` objects.

    Accepts ``Request`` instances (normalised in place: prompt flattened
    to 1-D int32) and, for one more release, bare
    ``(prompt, n_steps[, arrival])`` tuples/lists — the legacy form
    every caller used before :mod:`repro.serve.api` existed.  Coercing a
    tuple emits a one-shot :class:`DeprecationWarning`.
    """
    reqs: List[Request] = []
    for i, r in enumerate(trace):
        if not isinstance(r, Request):
            if not isinstance(r, (tuple, list)) or not 2 <= len(r) <= 3:
                raise TypeError(
                    f"request {i}: expected a repro.serve.Request or a "
                    f"legacy (prompt, n_steps[, arrival]) tuple, got "
                    f"{type(r).__name__}")
            _warn_once(
                "tuple-trace",
                "passing (prompt, n_steps[, arrival]) tuples to run() is "
                "deprecated; build repro.serve.Request objects (e.g. via "
                "the repro.serve.traces generators) instead")
            r = Request(*r)
        r.prompt = np.asarray(r.prompt, np.int32).reshape(-1)
        r.n_steps = int(r.n_steps)
        r.arrival = int(r.arrival)
        if r.deadline is not None:
            r.deadline = int(r.deadline)
        if r.cancel_at is not None:
            r.cancel_at = int(r.cancel_at)
        if r.n_steps < 1:
            raise ValueError(f"request {i}: n_steps={r.n_steps} < 1")
        reqs.append(r)
    return reqs
