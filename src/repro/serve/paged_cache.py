"""Block-paged KV cache: a shared pool of fixed-size KV blocks.

Cache layout
------------
Every attention layer owns two pools ``k``/``v`` of shape
``(P, page, KV, hd)``: ``P`` physical blocks of ``page`` token rows.  A
request's cache is the *logical* concatenation of the blocks its row of
the (B, NB) block table names — the table is shared across layers, so
one allocation covers the whole model.  ``page`` is the MXU-aligned
``block_kv`` the ``paged_decode_attention`` planner derives from the
target :class:`~repro.arch.DeviceSpec` (the pool's gather granularity
IS the kernel's kv tile), overridable for tests.

Physical block 0 is the reserved **null block**: it is never allocated,
idle engine slots point their whole table at it, and their masked
scatter-writes land there harmlessly — so one compiled decode step can
run over a fixed-size slot array with any subset active.

The allocator is a host-side free list: :meth:`alloc` hands out blocks
(``None`` when the pool cannot cover the request — the scheduler's
admission signal), :meth:`free` returns a retired request's blocks
immediately.  Device state is only the pool pytree itself
(:attr:`pools`), shaped exactly like ``repro.models.init_cache`` so
``paged_decode_step``'s scan consumes it directly.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.plan import plan_for
from repro.models.blocks import layer_sigs, schedule
from repro.models.config import ModelConfig
from repro.models.layers import cdtype

__all__ = ["PagedKVCache", "default_page_size"]

#: T the page-size probe plans for: the planner cap, so the chosen page
#: is the largest aligned block the device's VMEM budget admits.
_PROBE_T = 512


def default_page_size(cfg: ModelConfig, device=None, *,
                      cap: Optional[int] = None) -> int:
    """The page size the planner picks for ``cfg``'s heads on ``device``.

    ``cap`` bounds the probe length (an engine passes its ``max_len``):
    without it the planner returns its largest VMEM-admissible block,
    and a pool paged coarser than the requests it serves makes every
    decode tick gather and attend over rows that can never hold data.
    """
    probe_t = _PROBE_T if cap is None else min(_PROBE_T, max(1, cap))
    plan = plan_for("paged_decode_attention",
                    {"B": 1, "T": probe_t, "H": cfg.n_heads,
                     "KV": cfg.n_kv_heads, "hd": cfg.hd},
                    dtype=cfg.dtype, device=device)
    return plan.blocks["block_kv"]


class PagedKVCache:
    """Pool pytree + free-list allocator for one model's KV blocks.

    ``n_blocks`` counts physical blocks *including* the reserved null
    block 0, so ``n_blocks - 1`` are allocatable.  ``page=None`` asks
    the planner (:func:`default_page_size`); an explicit page is
    validated against the same tiling contract (it must be MXU-aligned,
    or the paged kernel could never run on it).
    """

    def __init__(self, cfg: ModelConfig, *, n_blocks: int,
                 page: Optional[int] = None, device=None):
        if n_blocks < 2:
            raise ValueError(f"n_blocks={n_blocks}: need at least the null "
                             "block plus one allocatable block")
        sigs = layer_sigs(cfg)
        bad = [f"layer {i}: {s[0]}" for i, s in enumerate(sigs)
               if s[0] != "attn"]
        if cfg.mla:
            bad.append("mla latent cache")
        if bad:
            raise NotImplementedError(
                "PagedKVCache: only plain GQA attention layers page "
                f"(config {cfg.name!r} has {', '.join(bad)})")
        if page is None:
            page = default_page_size(cfg, device)
        else:
            # pinning block_kv re-runs the tiling contract: a misaligned
            # page raises here, not inside the first decode step
            plan_for("paged_decode_attention",
                     {"B": 1, "T": page, "H": cfg.n_heads,
                      "KV": cfg.n_kv_heads, "hd": cfg.hd, "page": page},
                     dtype=cfg.dtype, device=device)
        self.cfg = cfg
        self.page = int(page)
        self.n_blocks = int(n_blocks)
        self.pools = self._init_pools(cfg)
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))

    def _init_pools(self, cfg: ModelConfig) -> Dict:
        dt = cdtype(cfg)
        shp = (self.n_blocks, self.page, cfg.n_kv_heads, cfg.hd)
        first_k, period, n_periods = schedule(cfg)

        def pool():
            return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}

        return {
            "layers0": [pool() for _ in range(first_k)],
            "layers": tuple(
                jax.tree.map(lambda a: jnp.zeros((n_periods,) + a.shape,
                                                 a.dtype), pool())
                for _ in range(period)),
        }

    # -- allocator ---------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable blocks (the null block excluded)."""
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.capacity - len(self._free)

    def occupancy(self) -> float:
        """Fraction of allocatable blocks currently held by requests."""
        return self.used_blocks / max(1, self.capacity)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Claim ``n`` blocks, or ``None`` if the pool cannot cover them
        (the all-or-nothing contract keeps admission atomic)."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        return ids

    def free(self, ids: Sequence[int]) -> None:
        """Return a retired request's blocks to the free list."""
        for b in ids:
            if not 1 <= b < self.n_blocks:
                raise ValueError(f"free: block id {b} outside the "
                                 f"allocatable range [1, {self.n_blocks})")
            if b in self._free:
                raise ValueError(f"free: block {b} double-freed")
        self._free.extend(ids)
