"""Block-paged KV cache: a shared, reference-counted pool of KV blocks.

Cache layout
------------
Every attention layer owns two pools ``k``/``v`` of shape
``(P, page, KV, hd)``: ``P`` physical blocks of ``page`` token rows.  A
request's cache is the *logical* concatenation of the blocks its row of
the (B, NB) block table names — the table is shared across layers, so
one allocation covers the whole model.  ``page`` is the MXU-aligned
``block_kv`` the ``paged_decode_attention`` planner derives from the
target :class:`~repro.arch.DeviceSpec` (the pool's gather granularity
IS the kernel's kv tile), overridable for tests.

Physical block 0 is the reserved **null block**: it is never allocated,
idle engine slots point their whole table at it, and their masked
scatter-writes land there harmlessly — so one compiled decode step can
run over a fixed-size slot array with any subset active.

Block sharing (copy-on-write)
-----------------------------
Blocks carry reference counts, so several requests may name the same
physical block in their tables.  A *prefix index* maps a chained
content hash of each page-aligned token run (``sha1(parent_digest ||
page_tokens)``, vLLM-style) to the block holding its K/V: a new request
whose prompt starts with an already-cached prefix takes the matching
blocks for free — :meth:`match_prefix` + :meth:`acquire` are pure
host-side bookkeeping, no prefill compute.

Freeing a *registered* block (one the index knows) does not scrub it:
at refcount zero it parks on a revival list, still matchable, and is
only evicted — deregistered and handed out as writable — when the
allocator runs out of never-written blocks (oldest-parked first, and
only ever at refcount zero).  :meth:`fork` is the copy-on-write escape
hatch: give a writer its own copy of a shared block.  The serve engine
never needs it in steady state — prefix matches are capped so writes
land past every shared page — but the cache keeps the operation (and
its tests) so the invariant is enforceable, not incidental.

The free structures are O(1) end to end: a fresh-block stack, an
insertion-ordered dict for parked revivable blocks (O(1) membership,
removal, and oldest-first eviction), and refcounts make the
double-free check a single array lookup instead of the old free-list
scan.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.plan import plan_for
from repro.models.blocks import layer_sigs, schedule
from repro.models.config import ModelConfig
from repro.models.layers import cdtype

__all__ = ["PagedKVCache", "default_page_size", "prefix_digests"]

#: T the page-size probe plans for: the planner cap, so the chosen page
#: is the largest aligned block the device's VMEM budget admits.
_PROBE_T = 512


def default_page_size(cfg: ModelConfig, device=None, *,
                      cap: Optional[int] = None) -> int:
    """The page size the planner picks for ``cfg``'s heads on ``device``.

    ``cap`` bounds the probe length (an engine passes its ``max_len``):
    without it the planner returns its largest VMEM-admissible block,
    and a pool paged coarser than the requests it serves makes every
    decode tick gather and attend over rows that can never hold data.
    """
    probe_t = _PROBE_T if cap is None else min(_PROBE_T, max(1, cap))
    plan = plan_for("paged_decode_attention",
                    {"B": 1, "T": probe_t, "H": cfg.n_heads,
                     "KV": cfg.n_kv_heads, "hd": cfg.hd},
                    dtype=cfg.dtype, device=device)
    return plan.blocks["block_kv"]


def prefix_digests(tokens: np.ndarray, page: int) -> List[bytes]:
    """Chained content hashes of ``tokens``' full pages.

    ``digest[i] = sha1(digest[i-1] || tokens[i*page:(i+1)*page])`` — the
    chain means a digest identifies the whole prefix up to and including
    its page, so equal digests imply bitwise-equal cache contents (K/V
    of a causal model depend only on the tokens at and before a row).
    """
    toks = np.asarray(tokens, np.int32).reshape(-1)
    out: List[bytes] = []
    h = b""
    for i in range(toks.shape[0] // page):
        h = hashlib.sha1(h + toks[i * page:(i + 1) * page].tobytes()).digest()
        out.append(h)
    return out


class PagedKVCache:
    """Pool pytree + refcounting allocator for one model's KV blocks.

    ``n_blocks`` counts physical blocks *including* the reserved null
    block 0, so ``n_blocks - 1`` are allocatable.  ``page=None`` asks
    the planner (:func:`default_page_size`); an explicit page is
    validated against the same tiling contract (it must be MXU-aligned,
    or the paged kernel could never run on it).
    """

    def __init__(self, cfg: ModelConfig, *, n_blocks: int,
                 page: Optional[int] = None, device=None):
        if n_blocks < 2:
            raise ValueError(f"n_blocks={n_blocks}: need at least the null "
                             "block plus one allocatable block")
        sigs = layer_sigs(cfg)
        bad = [f"layer {i}: {s[0]}" for i, s in enumerate(sigs)
               if s[0] != "attn"]
        if cfg.mla:
            bad.append("mla latent cache")
        if bad:
            raise NotImplementedError(
                "PagedKVCache: only plain GQA attention layers page "
                f"(config {cfg.name!r} has {', '.join(bad)})")
        if page is None:
            page = default_page_size(cfg, device)
        else:
            # pinning block_kv re-runs the tiling contract: a misaligned
            # page raises here, not inside the first decode step
            plan_for("paged_decode_attention",
                     {"B": 1, "T": page, "H": cfg.n_heads,
                      "KV": cfg.n_kv_heads, "hd": cfg.hd, "page": page},
                     dtype=cfg.dtype, device=device)
        self.cfg = cfg
        self.page = int(page)
        self.n_blocks = int(n_blocks)
        self.pools = self._init_pools(cfg)
        self._refs: List[int] = [0] * self.n_blocks
        # LIFO stack of never-registered writable blocks
        self._fresh: List[int] = list(range(self.n_blocks - 1, 0, -1))
        # refcount-0 blocks still in the prefix index, oldest-parked
        # first (dict preserves insertion order: O(1) park/revive/evict)
        self._parked: Dict[int, None] = {}
        self._index: Dict[bytes, int] = {}      # digest -> block
        self._digest: Dict[int, bytes] = {}     # block  -> digest

    def _init_pools(self, cfg: ModelConfig) -> Dict:
        dt = cdtype(cfg)
        shp = (self.n_blocks, self.page, cfg.n_kv_heads, cfg.hd)
        first_k, period, n_periods = schedule(cfg)

        def pool():
            return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}

        return {
            "layers0": [pool() for _ in range(first_k)],
            "layers": tuple(
                jax.tree.map(lambda a: jnp.zeros((n_periods,) + a.shape,
                                                 a.dtype), pool())
                for _ in range(period)),
        }

    # -- allocator ---------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable blocks (the null block excluded)."""
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._fresh) + len(self._parked)

    @property
    def used_blocks(self) -> int:
        return self.capacity - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks parked in the prefix index (revivable)."""
        return len(self._parked)

    def occupancy(self) -> float:
        """Fraction of allocatable blocks currently held by requests."""
        return self.used_blocks / max(1, self.capacity)

    def ref_count(self, b: int) -> int:
        return self._refs[b]

    def _check_range(self, b: int, op: str) -> None:
        if not 1 <= b < self.n_blocks:
            raise ValueError(f"{op}: block id {b} outside the "
                             f"allocatable range [1, {self.n_blocks})")

    def _deregister(self, b: int) -> None:
        d = self._digest.pop(b, None)
        if d is not None:
            del self._index[d]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Claim ``n`` writable blocks at refcount 1, or ``None`` if the
        pool cannot cover them (all-or-nothing keeps admission atomic).
        Never-written blocks go first; then parked index entries are
        evicted oldest-first (deregistered — only refcount-0 blocks are
        ever reclaimed, so no live request ever loses a block)."""
        if n > self.free_blocks:
            return None
        ids: List[int] = []
        for _ in range(n):
            if self._fresh:
                b = self._fresh.pop()
            else:
                b = next(iter(self._parked))
                del self._parked[b]
                self._deregister(b)
            self._refs[b] = 1
            ids.append(b)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        """Drop one reference per listed block.  At refcount 0 a block
        returns to the fresh stack, or — if the prefix index knows it —
        parks for revival.  Raises before touching anything if any id is
        out of range or would go below zero (double free)."""
        counts: Dict[int, int] = {}
        for b in ids:
            self._check_range(b, "free")
            counts[b] = counts.get(b, 0) + 1
        for b, c in counts.items():
            if self._refs[b] < c:
                raise ValueError(f"free: block {b} double-freed")
        for b, c in counts.items():
            self._refs[b] -= c
            if self._refs[b] == 0:
                if b in self._digest:
                    self._parked[b] = None
                else:
                    self._fresh.append(b)

    def acquire(self, ids: Sequence[int]) -> None:
        """Add one reference per listed block (a prefix-cache hit taking
        shared ownership).  Parked blocks revive; a block that is neither
        live nor parked is not acquirable — that would hand out a fresh
        block without initialising it."""
        for b in ids:
            self._check_range(b, "acquire")
            if self._refs[b] == 0 and b not in self._parked:
                raise ValueError(f"acquire: block {b} is not live or "
                                 "cached (alloc writable blocks instead)")
        for b in ids:
            self._parked.pop(b, None)
            self._refs[b] += 1

    # -- prefix index ------------------------------------------------------

    def match_prefix(self, tokens: np.ndarray) -> List[int]:
        """Longest run of cached blocks covering ``tokens``' page-aligned
        prefix.  Pure lookup — call :meth:`acquire` on the result before
        the next alloc/free, or the blocks may be evicted under you."""
        out: List[int] = []
        for d in prefix_digests(tokens, self.page):
            b = self._index.get(d)
            if b is None:
                break
            out.append(b)
        return out

    def register_prefix(self, tokens: np.ndarray, ids: Sequence[int]) -> None:
        """Enter ``tokens``' full pages — held in ``ids`` in order — into
        the prefix index.  Already-indexed digests are skipped (first
        writer wins; duplicate content in another block stays private),
        as are blocks already registered under some digest (a fork)."""
        ds = prefix_digests(tokens, self.page)
        if len(ds) > len(ids):
            raise ValueError(
                f"register_prefix: {len(ds)} full pages but only "
                f"{len(ids)} blocks")
        for d, b in zip(ds, ids):
            self._check_range(b, "register_prefix")
            if d in self._index or b in self._digest:
                continue
            if self._refs[b] == 0 and b not in self._parked:
                raise ValueError(f"register_prefix: block {b} is not live")
            self._index[d] = b
            self._digest[b] = d

    def check_invariants(self) -> None:
        """Assert the allocator's conservation and bookkeeping invariants.

        The chaos suite calls this after **every scheduler tick** under
        fault injection; any violation raises :class:`AssertionError`
        naming the broken invariant.  The contract:

        * **conservation** — every allocatable block is in exactly one
          of three states: *fresh* (never-registered free list), *parked*
          (refcount 0 but still in the prefix index), or *live*
          (refcount > 0): ``fresh + parked + live == n_blocks - 1`` with
          the three sets disjoint — no leaked and no double-owned block;
        * the **null block** (0) is never fresh, parked, live, or
          indexed;
        * refcounts are non-negative; parked blocks sit at exactly 0;
        * the **prefix index** is an exact bijection with the reverse
          map and only names live or parked blocks (an indexed fresh
          block would serve stale K/V to a future prefix match).
        """
        P = self.n_blocks
        fresh = list(self._fresh)
        fresh_set = set(fresh)
        parked = set(self._parked)
        if len(fresh) != len(fresh_set):
            raise AssertionError(f"fresh list holds duplicates: {fresh}")
        for name, ids in (("fresh", fresh_set), ("parked", parked)):
            bad = [b for b in ids if not 1 <= b < P]
            if bad:
                raise AssertionError(f"{name} holds out-of-range or null "
                                     f"blocks: {bad}")
        neg = [b for b in range(P) if self._refs[b] < 0]
        if neg:
            raise AssertionError(f"negative refcounts on blocks {neg}")
        if self._refs[0] != 0:
            raise AssertionError(f"null block has refcount {self._refs[0]}")
        live = {b for b in range(1, P) if self._refs[b] > 0}
        if fresh_set & parked:
            raise AssertionError("blocks both fresh and parked: "
                                 f"{sorted(fresh_set & parked)}")
        if live & fresh_set:
            raise AssertionError("live blocks on the fresh list: "
                                 f"{sorted(live & fresh_set)}")
        if live & parked:
            raise AssertionError("live blocks parked: "
                                 f"{sorted(live & parked)}")
        if len(fresh_set) + len(parked) + len(live) != P - 1:
            missing = (set(range(1, P)) - fresh_set - parked - live)
            raise AssertionError(
                f"block conservation broken: fresh {len(fresh_set)} + "
                f"parked {len(parked)} + live {len(live)} != {P - 1} "
                f"(leaked blocks: {sorted(missing)})")
        bad_parked = [b for b in parked if self._refs[b] != 0]
        if bad_parked:
            raise AssertionError(f"parked blocks with nonzero refcount: "
                                 f"{bad_parked}")
        unindexed = [b for b in parked if b not in self._digest]
        if unindexed:
            raise AssertionError(f"parked blocks missing from the prefix "
                                 f"index: {unindexed}")
        if len(self._index) != len(self._digest):
            raise AssertionError(
                f"prefix index ({len(self._index)}) and reverse map "
                f"({len(self._digest)}) disagree")
        for d, b in self._index.items():
            if self._digest.get(b) != d:
                raise AssertionError(
                    f"prefix index names block {b} but the reverse map "
                    f"holds {self._digest.get(b)!r} != {d!r}")
            if b not in live and b not in parked:
                raise AssertionError(
                    f"prefix index names block {b}, which is neither "
                    "live nor parked (stale K/V would be served)")

    def fork(self, b: int) -> int:
        """Copy-on-write: give the caller a private copy of shared block
        ``b``, moving one of its references onto the copy.  Returns the
        new block id (unregistered — the forker is about to overwrite
        it).  The copy is an on-device row copy across every layer pool;
        the other holders' view of ``b`` is untouched."""
        self._check_range(b, "fork")
        if self._refs[b] == 0:
            raise ValueError(f"fork: block {b} has no references")
        got = self.alloc(1)
        if got is None:
            raise ValueError("fork: pool exhausted (no block for the copy)")
        dst = got[0]

        def cp(pool):
            if pool.ndim == 5:          # (n_periods, P, page, KV, hd)
                return pool.at[:, dst].set(pool[:, b])
            return pool.at[dst].set(pool[b])

        self.pools = jax.tree.map(cp, self.pools)
        self._refs[b] -= 1
        if self._refs[b] == 0:
            if b in self._digest:
                self._parked[b] = None
            else:
                self._fresh.append(b)
        return dst
