"""repro.fleet — fleet-scale capacity planning over the perf pipeline.

The paper's question — "how do MCE optimizations impact the behavior of
future systems" — answered at serving-fleet granularity: replay a
declarative traffic scenario through the perf engines and the serve
layer's tick-accounting cost model, and render a throughput / latency /
cost-per-token frontier per registered device (optionally under
``repro.arch`` overlay what-ifs: "what does a 2x MCE buy the fleet").

  scenario  — TrafficScenario (request rate, length mix, SLO) + registry
              with ``chat`` / ``long_context`` / ``bursty_batch`` built-ins
  capacity  — per-request cost via ``perf.predict`` + the queueing model
              calibrated against ``PagedServeEngine`` tick accounting
              -> max sustainable QPS per device under the SLO
  frontier  — scenario x device x overlay sweep -> FleetReport rows
              (devices-needed, p99 vs SLO, tokens/s/device, cost proxy)
  cli       — ``python -m repro.fleet --scenario chat --devices ...``

See ROADMAP.md "repro.fleet" for the architecture and the <20-line
"adding a traffic scenario" recipe.
"""

from repro.fleet.scenario import (SLO, TrafficScenario,  # noqa: F401
                                  get_scenario, list_scenarios,
                                  register_scenario)
from repro.fleet.capacity import (ServeCost, SimStats,  # noqa: F401
                                  TickCosts, fit_tick_costs,
                                  max_sustainable_qps, p99_latency_s,
                                  serve_cost, simulate_trace,
                                  token_latency_s)
from repro.fleet.frontier import (DEVICE_COST, FleetReport,  # noqa: F401
                                  FleetRow, frontier)

__all__ = [
    "SLO", "TrafficScenario", "register_scenario", "get_scenario",
    "list_scenarios",
    "ServeCost", "serve_cost", "token_latency_s", "p99_latency_s",
    "max_sustainable_qps", "SimStats", "simulate_trace", "TickCosts",
    "fit_tick_costs",
    "FleetRow", "FleetReport", "frontier", "DEVICE_COST",
]
