"""The fleet frontier: scenario x device x overlay -> FleetReport rows.

:func:`frontier` runs every requested cell through ONE ``perf.sweep``
call (the deterministic dev -> workload -> engine -> overlay iteration
order lets rows be recovered by positional arithmetic — no re-predicts,
and HLO-sourced workloads hit the content-hashed ``perf.cache`` once),
then sizes the fleet per cell with the queueing model:

* ``max_qps`` — largest per-replica request rate meeting the SLO;
* ``replicas`` / ``devices_needed`` — ceil(offered / max_qps), times
  the scenario's tensor-parallel ways;
* ``p99_token_ms`` — latency at the *operating point* (offered load
  spread over the sized fleet), vs the SLO target;
* ``tokens_per_s_device`` — decode tokens per second per device at the
  replica's sustainable rate;
* ``cost_per_mtok`` — the relative-price proxy :data:`DEVICE_COST`
  turned into $/Mtok at sustained rate (prices are *relative* units for
  ranking devices, not a bill).

Rows are plain dataclasses; :class:`FleetReport` renders the markdown
table the CLI and ``examples/fleet_planning.py`` print.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.arch.overlay import IDENTITY, Overlay
from repro.fleet.capacity import (ServeCost, analytic_graphs,
                                  max_sustainable_qps, p99_latency_s)
from repro.fleet.scenario import TrafficScenario, get_scenario
from repro.perf.pipeline import sweep

__all__ = ["DEVICE_COST", "FleetRow", "FleetReport", "frontier"]

#: Relative hourly price per *device* (dimensionless ranking units —
#: roughly normalised so one mid-range accelerator-hour is 1.0).  Used
#: only to turn tokens/s into a cost-per-token ordering; devices not
#: listed default to 1.0.
DEVICE_COST: Dict[str, float] = {
    "mi200": 1.0,
    "mi300": 1.6,
    "mi300x": 2.0,
    "tpu_v5e": 0.6,
    "tpu_v5p": 2.1,
}


@dataclasses.dataclass(frozen=True)
class FleetRow:
    """One (scenario, device, overlay) cell of the frontier."""

    scenario: str
    device: str
    overlay: str                    # Overlay.describe() label
    engine: str
    feasible: bool                  # can ANY replica count meet the SLO?
    max_qps: float                  # sustainable requests/s per replica
    replicas: int                   # replicas to absorb the offered QPS
    devices_needed: int             # replicas * tp
    p99_token_ms: float             # at the operating point
    slo_p99_ms: float
    ttft_ms: float
    tokens_per_s_device: float      # decode tokens/s per device, sustained
    cost_per_mtok: float            # relative units (DEVICE_COST proxy)
    bound: str                      # decode-graph bottleneck
    decode_tick_ms: float
    prefill_chunk_ms: float

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FleetReport:
    """All frontier rows of one planning run, renderable as a table."""

    rows: List[FleetRow]

    _COLS = ("scenario", "device", "overlay", "qps/rep", "reps", "devs",
             "p99 ms", "slo", "ttft ms", "tok/s/dev", "$/Mtok", "bound")

    def table(self) -> str:
        """Markdown frontier table, one row per cell."""
        out = ["| " + " | ".join(self._COLS) + " |",
               "|" + "|".join("---" for _ in self._COLS) + "|"]
        for r in self.rows:
            cells = [r.scenario, r.device, r.overlay[:24],
                     f"{r.max_qps:.2f}" if r.feasible else "-",
                     str(r.replicas) if r.feasible else "inf",
                     str(r.devices_needed) if r.feasible else "inf",
                     f"{r.p99_token_ms:.1f}" if r.feasible else "inf",
                     f"{r.slo_p99_ms:g}",
                     f"{r.ttft_ms:.0f}" if r.feasible else "inf",
                     f"{r.tokens_per_s_device:.1f}",
                     f"{r.cost_per_mtok:.2f}" if r.feasible else "inf",
                     r.bound]
            out.append("| " + " | ".join(cells) + " |")
        return "\n".join(out)

    def as_dict(self) -> Dict[str, Any]:
        return {"rows": [r.as_dict() for r in self.rows]}

    def best(self, scenario: str) -> Optional[FleetRow]:
        """Cheapest feasible device for a scenario (None if none is)."""
        cands = [r for r in self.rows
                 if r.scenario == scenario and r.feasible]
        return min(cands, key=lambda r: r.cost_per_mtok) if cands else None


def _row(scn: TrafficScenario, cost: ServeCost, ov: Overlay,
         engine: str) -> FleetRow:
    from repro.fleet.capacity import ttft_s
    max_qps = max_sustainable_qps(scn, cost)
    feasible = max_qps > 0 and math.isfinite(max_qps)
    price = DEVICE_COST.get(cost.device, 1.0)
    if feasible:
        replicas = max(1, math.ceil(scn.qps / max_qps))
        op_qps = scn.qps / replicas          # per-replica operating point
        p99_ms = p99_latency_s(op_qps, scn, cost) * 1e3
        ttft_ms = ttft_s(op_qps, scn, cost) * 1e3
        tok_s_dev = max_qps * scn.output_mean / scn.tp
        cost_mtok = price * scn.tp / (max_qps * scn.output_mean * 3600) * 1e6
    else:
        replicas = 0
        p99_ms = ttft_ms = math.inf
        # decode-only ceiling still ranks devices that miss the SLO
        tok_s_dev = cost.peak_tokens_per_s / scn.tp \
            if math.isfinite(cost.peak_tokens_per_s) else 0.0
        cost_mtok = math.inf
    return FleetRow(
        scenario=scn.name, device=cost.device, overlay=ov.describe(),
        engine=engine, feasible=feasible, max_qps=max_qps,
        replicas=replicas, devices_needed=replicas * scn.tp,
        p99_token_ms=p99_ms, slo_p99_ms=scn.slo.p99_token_ms,
        ttft_ms=ttft_ms, tokens_per_s_device=tok_s_dev,
        cost_per_mtok=cost_mtok, bound=cost.decode_bound,
        decode_tick_ms=cost.decode_tick_s * 1e3,
        prefill_chunk_ms=cost.prefill_chunk_s * 1e3)


def frontier(scenarios: Union[str, TrafficScenario,
                              Sequence[Union[str, TrafficScenario]]],
             devices: Sequence[str], *,
             overlays: Iterable[Overlay] = (IDENTITY,),
             engine: str = "roofline") -> FleetReport:
    """Plan every scenario on every device under every overlay.

    All perf predictions run through one ``perf.sweep`` call; its
    iteration order (device -> workload -> engine -> overlay) is
    documented and deterministic, so each cell's decode/prefill Reports
    are recovered by index arithmetic rather than re-prediction.
    """
    if isinstance(scenarios, (str, TrafficScenario)):
        scenarios = [scenarios]
    scns = [get_scenario(s) if isinstance(s, str) else s for s in scenarios]
    devices = list(devices)
    ovs = list(overlays)
    if not scns or not devices or not ovs:
        raise ValueError("frontier needs >= 1 scenario, device and overlay")

    workloads: Dict[str, Any] = {}
    for scn in scns:
        graphs = analytic_graphs(scn)
        workloads[f"{scn.name}/decode"] = graphs["decode"]
        workloads[f"{scn.name}/prefill"] = graphs["prefill"]

    reports = sweep(workloads, devices=devices, engines=[engine],
                    overlays=ovs)
    n_w, n_o = len(workloads), len(ovs)

    def rep(d_i: int, w_i: int, o_i: int):
        return reports[(d_i * n_w + w_i) * n_o + o_i]

    rows: List[FleetRow] = []
    for d_i, dev in enumerate(devices):
        for s_i, scn in enumerate(scns):
            for o_i, ov in enumerate(ovs):
                dec = rep(d_i, 2 * s_i, o_i)
                pre = rep(d_i, 2 * s_i + 1, o_i)
                cost = ServeCost(
                    scenario=scn.name, device=dec.device,
                    decode_tick_s=dec.total_time_s,
                    prefill_chunk_s=pre.total_time_s,
                    decode_bound=dec.bound, prefill_bound=pre.bound,
                    max_batch=scn.max_batch,
                    prefill_chunks_per_request=scn.prefill_chunks_per_request,
                    decode_report=dec, prefill_report=pre)
                rows.append(_row(scn, cost, ov, engine))
    return FleetReport(rows=rows)
