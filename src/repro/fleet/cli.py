"""``python -m repro.fleet`` — the capacity-planner command line.

Examples::

    python -m repro.fleet --scenario chat --devices mi300x,tpu_v5p
    python -m repro.fleet --scenario chat --devices mi300 \\
        --slo-p99-ms 100 --qps 50
    python -m repro.fleet --devices mi300x --overlay mfma_scale=2 --json

``--overlay`` takes ``knob=value`` pairs (``mfma_scale``,
``clock_scale``, ``mem_latency_scale``, ``bw_scale``) and always plans
the identity baseline alongside, so the what-if is a visible delta.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro.arch.overlay import IDENTITY, Overlay
from repro.fleet.frontier import frontier
from repro.fleet.scenario import get_scenario, list_scenarios

_OVERLAY_KNOBS = ("mfma_scale", "clock_scale", "mem_latency_scale",
                  "bw_scale")


def parse_overlay(spec: str) -> Overlay:
    """'mfma_scale=2,bw_scale=1.5' -> Overlay(...)."""
    kw = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"overlay knob {part!r} is not knob=value")
        k, v = part.split("=", 1)
        k = k.strip()
        if k not in _OVERLAY_KNOBS:
            raise ValueError(f"unknown overlay knob {k!r}; "
                             f"choose from {_OVERLAY_KNOBS}")
        kw[k] = float(v)
    return Overlay(**kw)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Fleet capacity planning over the perf engines")
    p.add_argument("--scenario", default=None,
                   help="comma-separated scenario names "
                        f"(registered: {','.join(list_scenarios())}; "
                        "default: all)")
    p.add_argument("--devices", default="mi300,mi300x,tpu_v5p",
                   help="comma-separated repro.arch device names")
    p.add_argument("--slo-p99-ms", type=float, default=None,
                   help="override every scenario's p99 token-latency SLO")
    p.add_argument("--qps", type=float, default=None,
                   help="override every scenario's offered fleet QPS")
    p.add_argument("--overlay", default=None,
                   help="what-if overlay, e.g. mfma_scale=2,bw_scale=1.5 "
                        "(planned alongside the identity baseline)")
    p.add_argument("--engine", default="roofline",
                   help="perf cost engine (default: roofline)")
    p.add_argument("--json", action="store_true",
                   help="emit rows as JSON instead of the table")
    p.add_argument("--small", action="store_true",
                   help="CI smoke: chat scenario only, first two devices")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    names = (args.scenario.split(",") if args.scenario
             else list_scenarios())
    devices = [d.strip() for d in args.devices.split(",") if d.strip()]
    if args.small:
        names = names[:1]
        devices = devices[:2]

    scns = []
    for name in names:
        scn = get_scenario(name.strip())
        if args.qps is not None:
            scn = dataclasses.replace(scn, qps=args.qps)
        if args.slo_p99_ms is not None:
            scn = dataclasses.replace(
                scn, slo=scn.slo.with_p99(args.slo_p99_ms))
        scns.append(scn)

    overlays = [IDENTITY]
    if args.overlay:
        overlays.append(parse_overlay(args.overlay))

    report = frontier(scns, devices, overlays=overlays, engine=args.engine)

    if args.json:
        print(json.dumps(report.as_dict(), indent=2, sort_keys=True))
        return 0

    for scn in scns:
        print(f"# {scn.describe()}")
    print()
    print(report.table())
    for scn in scns:
        best = report.best(scn.name)
        if best is None:
            print(f"\n{scn.name}: NO device meets the SLO "
                  f"(p99 <= {scn.slo.p99_token_ms:g} ms) — relax the SLO "
                  "or shrink max_batch")
        else:
            print(f"\n{scn.name}: cheapest feasible device is "
                  f"{best.device} [{best.overlay}] — "
                  f"{best.devices_needed} device(s), "
                  f"{best.cost_per_mtok:.2f} $/Mtok (relative)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
