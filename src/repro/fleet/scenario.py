"""Declarative traffic scenarios for the fleet capacity planner.

A :class:`TrafficScenario` is everything the capacity model needs to
know about a workload *without* running it: which catalog model is
served, the offered request rate, the prompt/output length mix, arrival
burstiness, the serving configuration (batch slots, prefill chunk,
tensor-parallel ways) and the SLO the fleet must meet.  Scenarios live
in a registry (:func:`register_scenario`) mirroring the serve-trace and
perf-engine registries, with three built-ins:

* ``chat``          — short interactive turns, tight per-token SLO;
* ``long_context``  — document-stuffing prompts on a bigger model,
  tensor-parallel serving (the collectives show up in the cost graphs);
* ``bursty_batch``  — offline-ish batch traffic with bursty arrivals
  and a loose SLO.

Each scenario also names the :mod:`repro.serve.traces` generator whose
request mix it abstracts (``trace``), so the calibration layer can
replay the *same* traffic through the real ``PagedServeEngine``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

__all__ = ["SLO", "TrafficScenario", "register_scenario", "get_scenario",
           "list_scenarios"]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objectives the planner sizes the fleet against."""

    p99_token_ms: float = 200.0     # p99 inter-token latency target
    ttft_p99_ms: float = math.inf   # p99 time-to-first-token target

    def with_p99(self, p99_token_ms: float) -> "SLO":
        return dataclasses.replace(self, p99_token_ms=float(p99_token_ms))


@dataclasses.dataclass(frozen=True)
class TrafficScenario:
    """One traffic mix, declaratively.

    ``qps`` is the *offered* fleet-wide request rate the planner sizes
    devices for; ``prompt_mean`` / ``output_mean`` summarise the length
    mix in tokens; ``burstiness`` scales the queueing-delay term (1.0 ~
    Poisson arrivals, >1 heavier bursts).  ``max_batch`` /
    ``prefill_chunk`` / ``tp`` describe how one replica serves the
    model (``tp`` > 1 shards every layer ``tp`` ways and puts the
    tensor-parallel all-reduces into the cost graph).  ``trace`` names
    the :mod:`repro.serve.traces` generator this mix abstracts.
    """

    name: str
    arch: str = "qwen2-7b"          # repro.configs catalog model served
    qps: float = 10.0               # offered fleet-wide requests/s
    prompt_mean: float = 512.0      # mean prompt tokens
    output_mean: float = 256.0      # mean generated tokens
    burstiness: float = 1.0         # arrival burstiness multiplier
    slo: SLO = dataclasses.field(default_factory=SLO)
    max_batch: int = 8              # concurrent decode slots per replica
    prefill_chunk: int = 256        # incremental-prefill chunk tokens
    tp: int = 1                     # tensor-parallel ways per replica
    trace: str = "base"             # repro.serve.traces generator name

    def __post_init__(self):
        if self.qps <= 0:
            raise ValueError(f"scenario {self.name!r}: qps must be > 0")
        if self.prompt_mean < 1 or self.output_mean < 1:
            raise ValueError(f"scenario {self.name!r}: prompt_mean and "
                             "output_mean must be >= 1 token")
        if self.max_batch < 1 or self.prefill_chunk < 1 or self.tp < 1:
            raise ValueError(f"scenario {self.name!r}: max_batch, "
                             "prefill_chunk and tp must be >= 1")

    @property
    def context_mean(self) -> float:
        """Mean attention context during decode: the whole prompt plus
        half the output already generated."""
        return self.prompt_mean + self.output_mean / 2.0

    @property
    def prefill_chunks_per_request(self) -> int:
        return math.ceil(self.prompt_mean / self.prefill_chunk)

    def describe(self) -> str:
        return (f"{self.name}: {self.arch}, {self.qps:g} qps, "
                f"s={self.prompt_mean:g} n={self.output_mean:g}, "
                f"slo p99={self.slo.p99_token_ms:g}ms, "
                f"batch={self.max_batch} chunk={self.prefill_chunk} "
                f"tp={self.tp}")


_REGISTRY: Dict[str, TrafficScenario] = {}


def register_scenario(scenario: TrafficScenario) -> TrafficScenario:
    """Add a scenario to the registry (returns it, for chaining)."""
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} is already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> TrafficScenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{list_scenarios()}") from None


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------

register_scenario(TrafficScenario(
    name="chat",
    arch="qwen2-7b",
    qps=20.0,
    prompt_mean=512, output_mean=256,
    burstiness=1.0,
    slo=SLO(p99_token_ms=200.0),
    max_batch=8, prefill_chunk=256, tp=1,
    trace="base",
))

register_scenario(TrafficScenario(
    name="long_context",
    arch="yi-34b",
    qps=2.0,
    prompt_mean=8192, output_mean=512,
    burstiness=1.0,
    slo=SLO(p99_token_ms=400.0),
    # a 34B model at 8k context is served tensor-parallel: the per-layer
    # all-reduces land in the cost graph and can become the bound under
    # interconnect what-ifs
    max_batch=4, prefill_chunk=512, tp=4,
    trace="long_prompt",
))

register_scenario(TrafficScenario(
    name="bursty_batch",
    arch="qwen2-7b",
    qps=40.0,
    prompt_mean=256, output_mean=128,
    burstiness=4.0,
    slo=SLO(p99_token_ms=500.0),
    max_batch=16, prefill_chunk=256, tp=1,
    trace="shared_prefix",
))
