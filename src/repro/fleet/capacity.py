"""Per-device serving capacity: perf-engine cost -> queueing model -> QPS.

Three layers, each testable on its own:

1. **Cost graphs** — :func:`analytic_graphs` builds two synthetic
   :class:`~repro.perf.hlo_ir.KernelGraph` modules per scenario from the
   FULL catalog :class:`ModelConfig` (no jax, no compile): one *decode
   tick* (``max_batch`` slots advance one token through every layer at
   the scenario's mean context) and one *prefill chunk*
   (``prefill_chunk`` prompt tokens through every layer).  Dots carry
   real B/M/N/K so the MFMA/MXU engines can cost them; weight + KV-cache
   streaming is a ``memory`` op; ``tp > 1`` shards the per-layer dims
   and adds the tensor-parallel all-reduces as ``collective`` ops.
   :func:`hlo_graphs` is the opt-in compiled alternative (reduced
   config, real XLA text through the content-hashed ``perf.cache``).

2. **ServeCost** — :func:`serve_cost` runs both graphs through
   ``repro.perf.predict`` on a device (optionally under an overlay) and
   records the two primitive times the scheduler is made of:
   ``decode_tick_s`` (whole batch, one token each) and
   ``prefill_chunk_s`` (one chunk of one prompt), plus what bounds each.

3. **Queueing model** — closed-form and *strictly monotonic in QPS* by
   construction, so :func:`max_sustainable_qps` can bisect.  With
   per-device rate :math:`q`, mean prompt cost :math:`P` (chunks x
   chunk time), mean decode cost per request :math:`D = \\bar n \\cdot
   t_{tick} / B`:

   * server utilisation  :math:`\\rho = q (P + D)`; the prefill share
     :math:`\\phi = q P < \\rho`;
   * a decode token waits for the interleaved prefill chunks:
     token latency :math:`= t_{tick} / (1 - \\phi)`;
   * bursts queue requests: :math:`p99 = ` token latency
     :math:`\\times (1 + burstiness \\cdot \\rho / (1 - \\rho))`;
   * TTFT :math:`= P \\cdot (1 + burstiness \\cdot \\rho / (1-\\rho))`.

   :math:`\\rho \\ge 1` is overload (infinite latency).  The shape —
   service time stretched by interference, queueing growth
   :math:`\\rho/(1-\\rho)` — is the standard M/G/1-flavoured model; the
   *constants* come from the perf engines, not from hand-waving.

Calibration: :func:`simulate_trace` is a deterministic host-side
replica of the ``PagedServeEngine`` scheduler (same tick structure:
retire -> admit -> one prefill chunk per prefilling slot -> one decode
step for all actives) whose tick/step/chunk counts match the real
engine *exactly* on any trace; :func:`fit_tick_costs` turns measured
walls into per-primitive costs so predicted and measured per-token
latency can be compared within a tolerance band
(``tests/test_fleet.py``).
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.arch.overlay import Overlay
from repro.configs import get_config
from repro.fleet.scenario import SLO, TrafficScenario
from repro.models.config import ModelConfig
from repro.perf.hlo_ir import BYTES_PER_ELEM, KernelGraph, KernelOp
from repro.perf.pipeline import predict
from repro.perf.report import Report
from repro.serve.api import Request, as_requests

__all__ = ["analytic_graphs", "hlo_graphs", "ServeCost", "serve_cost",
           "request_work_s", "token_latency_s", "ttft_s", "p99_latency_s",
           "max_sustainable_qps", "SimStats", "simulate_trace",
           "TickCosts", "fit_tick_costs"]


# ---------------------------------------------------------------------------
# 1. Cost graphs
# ---------------------------------------------------------------------------

_DTYPE = {"bfloat16": "bf16", "float16": "f16", "float32": "f32",
          "float8_e4m3fn": "f8e4m3fn"}


def _elem_bytes(cfg: ModelConfig) -> int:
    return BYTES_PER_ELEM[_DTYPE.get(cfg.dtype, "bf16")]


def _layer_ff(cfg: ModelConfig, idx: int) -> int:
    """Active FFN width of layer ``idx`` (MoE: only routed + shared
    experts run per token — that is what is computed AND streamed)."""
    if cfg.layer_is_moe(idx):
        moe = cfg.moe
        return moe.top_k * moe.d_ff_expert + moe.n_shared * moe.d_ff_shared
    return cfg.d_ff


def _mixer_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(attention layers, non-attention mixer layers)."""
    attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    return attn, cfg.n_layers - attn


def _sharded(x: int, tp: int) -> int:
    return max(1, x // tp)


def _per_token_dots(cfg: ModelConfig, m: int, ctx: float, tp: int,
                    n_mlp: int) -> List[KernelOp]:
    """The dot ops for ``m`` tokens advancing one step through every
    layer at mean attention context ``ctx``.  ``n_mlp`` is the layer
    multiplier carried on the MLP ops (collapsed MoE/dense mean width).

    Non-attention mixers (SSM/hybrid layers) are approximated as their
    in/out projections — their scan is memory-shaped, which the memory
    op already carries; built-in scenarios all serve attention archs.
    """
    d = cfg.d_model
    H = _sharded(cfg.n_heads, tp)
    KV = _sharded(cfg.n_kv_heads, tp)
    hd = cfg.hd
    dt = _DTYPE.get(cfg.dtype, "bf16")
    n_attn, n_ssm = _mixer_counts(cfg)
    ctx_i = max(1, int(round(ctx)))
    mean_ff = sum(_layer_ff(cfg, i) for i in range(cfg.n_layers)) \
        / max(1, cfg.n_layers)
    ffs = max(1, int(round(mean_ff / tp)))
    ops = [
        # attention projections
        KernelOp(kind="dot", opcode="dot", count=float(n_attn), dtype=dt,
                 batch=1, m=m, n=(H + 2 * KV) * hd, k=d),
        KernelOp(kind="dot", opcode="dot", count=float(n_attn), dtype=dt,
                 batch=1, m=m, n=d, k=H * hd),
        # attention score / value contractions at the mean context
        KernelOp(kind="dot", opcode="dot", count=float(n_attn), dtype=dt,
                 batch=m * H if m == 1 else H, m=m if m > 1 else 1,
                 n=ctx_i, k=hd),
        KernelOp(kind="dot", opcode="dot", count=float(n_attn), dtype=dt,
                 batch=m * H if m == 1 else H, m=m if m > 1 else 1,
                 n=hd, k=ctx_i),
        # MLP (gate/up + down; gelu archs just have a fatter mean width)
        KernelOp(kind="dot", opcode="dot", count=float(n_mlp), dtype=dt,
                 batch=1, m=m, n=2 * ffs if cfg.mlp_type == "swiglu" else ffs,
                 k=d),
        KernelOp(kind="dot", opcode="dot", count=float(n_mlp), dtype=dt,
                 batch=1, m=m, n=d, k=ffs),
        # LM head (the decode graph emits one token per slot per tick)
        KernelOp(kind="dot", opcode="dot", count=1.0, dtype=dt,
                 batch=1, m=m, n=_sharded(cfg.vocab_size, tp), k=d),
    ]
    if n_ssm:
        e = cfg.ssm.expand if cfg.ssm else 2
        ops.append(KernelOp(kind="dot", opcode="dot", count=float(n_ssm),
                            dtype=dt, batch=1, m=m,
                            n=_sharded(2 * e * d, tp), k=d))
        ops.append(KernelOp(kind="dot", opcode="dot", count=float(n_ssm),
                            dtype=dt, batch=1, m=m, n=d,
                            k=_sharded(e * d, tp)))
    return [op for op in ops if op.m > 0 and op.n > 0 and op.k > 0]


def _param_bytes(cfg: ModelConfig, tp: int) -> float:
    """Per-device bytes of *active* weights one token's forward streams
    (MoE counts routed+shared experts only; LM head included, embedding
    gather negligible)."""
    d = cfg.d_model
    H = _sharded(cfg.n_heads, tp)
    KV = _sharded(cfg.n_kv_heads, tp)
    hd = cfg.hd
    n_attn, n_ssm = _mixer_counts(cfg)
    n_mats = 3 if cfg.mlp_type == "swiglu" else 2
    total = 0.0
    total += n_attn * (d * (H + 2 * KV) * hd + H * hd * d)
    for i in range(cfg.n_layers):
        total += n_mats * d * (_layer_ff(cfg, i) / tp)
    if n_ssm:
        e = cfg.ssm.expand if cfg.ssm else 2
        total += n_ssm * (_sharded(2 * e * d, tp) * d
                          + d * _sharded(e * d, tp))
    total += _sharded(cfg.vocab_size, tp) * d        # LM head
    return total * _elem_bytes(cfg)


def _tp_collectives(cfg: ModelConfig, m: int, tp: int) -> List[KernelOp]:
    """Two all-reduces per layer (post-attention, post-MLP) of the
    activation rows, ring wire accounting as in perf.hlo_ir."""
    if tp <= 1:
        return []
    result = float(m * cfg.d_model * _elem_bytes(cfg))
    wire = result * 2.0 * (tp - 1) / tp              # ring all-reduce
    return [KernelOp(kind="collective", opcode="all-reduce",
                     count=2.0 * cfg.n_layers, dtype="",
                     bytes=result, wire_bytes=wire, group=tp)]


def _finish(ops: List[KernelOp], mem_bytes: float, key: str) -> KernelGraph:
    ops = list(ops)
    ops.append(KernelOp(kind="memory", opcode="hbm-stream", count=1.0,
                        bytes=mem_bytes))
    return KernelGraph(
        ops=ops,
        flops=float(sum(op.count * op.flops for op in ops)),
        bytes_accessed=mem_bytes,
        collective_wire=float(sum(op.count * op.wire_bytes for op in ops)),
        key=key, source="totals")


def analytic_graphs(scn: TrafficScenario,
                    cfg: Optional[ModelConfig] = None
                    ) -> Dict[str, KernelGraph]:
    """``{"decode": ..., "prefill": ...}`` cost graphs for a scenario.

    Deterministic and compile-free: realistic fleet numbers come from
    the FULL catalog config's dimensions, not from running the model.
    """
    cfg = cfg or get_config(scn.arch)
    tp, B, C = scn.tp, scn.max_batch, scn.prefill_chunk
    eb = _elem_bytes(cfg)
    n_attn, _ = _mixer_counts(cfg)
    KV = _sharded(cfg.n_kv_heads, tp)

    # decode tick: every slot advances one token; m=1 dots are batched
    # over the B slots via count (each slot is its own tiny GEMM)
    dec_ops = []
    for op in _per_token_dots(cfg, 1, scn.context_mean, tp,
                              n_mlp=cfg.n_layers):
        dec_ops.append(dataclasses.replace(op, count=op.count * B))
    dec_ops += _tp_collectives(cfg, B, tp)
    kv_read = B * scn.context_mean * KV * cfg.hd * 2 * eb * n_attn
    dec_mem = _param_bytes(cfg, tp) + kv_read
    decode = _finish(
        dec_ops, dec_mem,
        key=(f"fleet:{scn.name}:{cfg.name}:decode:B{B}"
             f":ctx{int(scn.context_mean)}:tp{tp}"))

    # prefill chunk: C prompt tokens of ONE request; mean attended
    # context over a prompt's chunks is half the prompt
    ctx_p = max(float(C), scn.prompt_mean / 2.0)
    pre_ops = _per_token_dots(cfg, C, ctx_p, tp, n_mlp=cfg.n_layers)
    pre_ops += _tp_collectives(cfg, C, tp)
    kv_write = C * KV * cfg.hd * 2 * eb * n_attn
    kv_reread = ctx_p * KV * cfg.hd * 2 * eb * n_attn
    pre_mem = _param_bytes(cfg, tp) + kv_write + kv_reread
    prefill = _finish(
        pre_ops, pre_mem,
        key=(f"fleet:{scn.name}:{cfg.name}:prefill:C{C}"
             f":ctx{int(ctx_p)}:tp{tp}"))
    return {"decode": decode, "prefill": prefill}


def hlo_graphs(scn: TrafficScenario) -> Dict[str, KernelGraph]:
    """Opt-in compiled cost source: lower + compile one decode step and
    one prefill on the *reduced* config and parse the real XLA text via
    the content-hashed ``perf.cache``.  Slower (jax compile) and sized
    to the smoke config — use the analytic graphs for catalog-scale
    planning numbers and this path to sanity-check graph *structure*.
    """
    import jax

    from repro.models import init_params
    from repro.models.model import decode_step, init_cache, prefill
    from repro.perf.cache import parse_cached

    cfg = get_config(scn.arch).reduced()
    B = scn.max_batch
    T = min(512, 1 << max(4, int(math.ceil(
        math.log2(max(2.0, scn.context_mean / 16.0))))))
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    def dec(p, tok):
        cache = init_cache(cfg, B, T)
        return decode_step(cfg, p, cache, tok, T // 2)[0]

    tok = jax.ShapeDtypeStruct((B, 1), jax.numpy.int32)
    dec_txt = jax.jit(dec).lower(params, tok).compile().as_text()

    C = min(scn.prefill_chunk, T // 2)

    def pre(p, batch):
        return prefill(cfg, p, batch, max_len=T)[0]

    batch = {"tokens": jax.ShapeDtypeStruct((1, C), jax.numpy.int32)}
    pre_txt = jax.jit(pre).lower(params, batch).compile().as_text()
    return {"decode": parse_cached(dec_txt),
            "prefill": parse_cached(pre_txt)}


# ---------------------------------------------------------------------------
# 2. ServeCost
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeCost:
    """The two scheduler primitives, costed on one device."""

    scenario: str
    device: str
    decode_tick_s: float            # whole batch advances one token
    prefill_chunk_s: float          # one chunk of one prompt
    decode_bound: str               # Report.bound of the decode graph
    prefill_bound: str
    max_batch: int
    prefill_chunks_per_request: int
    decode_report: Report = dataclasses.field(repr=False, default=None)
    prefill_report: Report = dataclasses.field(repr=False, default=None)

    @property
    def peak_tokens_per_s(self) -> float:
        """Decode-only ceiling: a full batch every tick."""
        if self.decode_tick_s <= 0:
            return math.inf
        return self.max_batch / self.decode_tick_s


def serve_cost(scenario: Union[TrafficScenario, str],
               device: str, *,
               overlay: Optional[Overlay] = None,
               engine: str = "roofline",
               source: str = "analytic") -> ServeCost:
    """Cost one scenario's scheduler primitives on one device."""
    from repro.fleet.scenario import get_scenario
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    graphs = analytic_graphs(scn) if source == "analytic" \
        else hlo_graphs(scn)
    reps = {}
    for kind, g in graphs.items():
        reps[kind] = predict(g, device=device, engine=engine,
                             overlays=overlay,
                             workload_name=f"{scn.name}/{kind}")
    return ServeCost(
        scenario=scn.name, device=reps["decode"].device,
        decode_tick_s=reps["decode"].total_time_s,
        prefill_chunk_s=reps["prefill"].total_time_s,
        decode_bound=reps["decode"].bound,
        prefill_bound=reps["prefill"].bound,
        max_batch=scn.max_batch,
        prefill_chunks_per_request=scn.prefill_chunks_per_request,
        decode_report=reps["decode"], prefill_report=reps["prefill"])


# ---------------------------------------------------------------------------
# 3. Queueing model (all rates are per device/replica)
# ---------------------------------------------------------------------------

def request_work_s(scn: TrafficScenario, cost: ServeCost) -> float:
    """Server-seconds one mean request occupies a replica."""
    prefill = scn.prefill_chunks_per_request * cost.prefill_chunk_s
    decode = scn.output_mean * cost.decode_tick_s / scn.max_batch
    return prefill + decode


def _rho(qps: float, scn: TrafficScenario, cost: ServeCost) -> float:
    return qps * request_work_s(scn, cost)


def token_latency_s(qps: float, scn: TrafficScenario,
                    cost: ServeCost) -> float:
    """Mean inter-token latency at per-device rate ``qps``: the decode
    tick, stretched by the prefill chunks interleaved between ticks."""
    phi = qps * scn.prefill_chunks_per_request * cost.prefill_chunk_s
    if phi >= 1.0:
        return math.inf
    return cost.decode_tick_s / (1.0 - phi)


def ttft_s(qps: float, scn: TrafficScenario, cost: ServeCost) -> float:
    """p99-flavoured time to first token: the full prompt's prefill,
    inflated by queueing growth."""
    rho = _rho(qps, scn, cost)
    if rho >= 1.0:
        return math.inf
    prefill = scn.prefill_chunks_per_request * cost.prefill_chunk_s
    return prefill * (1.0 + scn.burstiness * rho / (1.0 - rho))


def p99_latency_s(qps: float, scn: TrafficScenario,
                  cost: ServeCost) -> float:
    """p99 inter-token latency at per-device rate ``qps``.  Strictly
    increasing in ``qps`` (every factor is), infinite at overload."""
    rho = _rho(qps, scn, cost)
    if rho >= 1.0:
        return math.inf
    lat = token_latency_s(qps, scn, cost)
    return lat * (1.0 + scn.burstiness * rho / (1.0 - rho))


def max_sustainable_qps(scn: TrafficScenario, cost: ServeCost, *,
                        slo: Optional[SLO] = None,
                        tol: float = 1e-6) -> float:
    """Largest per-device QPS meeting the SLO (0.0 if even an idle
    device misses it — e.g. the decode tick alone exceeds the p99
    target).  Bisection is exact here because the latency model is
    strictly monotonic in QPS by construction.
    """
    slo = slo or scn.slo
    p99_t = slo.p99_token_ms / 1e3
    ttft_t = slo.ttft_p99_ms / 1e3

    def ok(q: float) -> bool:
        return (p99_latency_s(q, scn, cost) <= p99_t
                and ttft_s(q, scn, cost) <= ttft_t)

    if cost.decode_tick_s <= 0:
        return math.inf
    if not ok(0.0):
        return 0.0
    lo, hi = 0.0, 1.0 / request_work_s(scn, cost)    # rho = 1 at hi
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if ok(mid):
            lo = mid
        else:
            hi = mid
        if hi - lo <= tol * max(1.0, hi):
            break
    return lo


# ---------------------------------------------------------------------------
# Calibration: deterministic replica of the paged scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimStats:
    """Tick accounting of one simulated trace (field names match the
    serve layer's RunStats where they overlap).  The resilience counters
    default to 0 so pre-resilience constructor calls keep working."""

    requests: int
    tokens: int
    ticks: int
    decode_steps: int
    prefill_chunks: int
    occupancy_mean: float
    occupancy_max: float
    completed: int = 0              # requests that ended with status OK
    shed: int = 0
    timeouts: int = 0
    cancelled: int = 0
    preemptions: int = 0
    stalled_ticks: int = 0


def simulate_trace(trace: Sequence[Union[Request, Tuple]], *,
                   max_len: int, max_batch: int, page: int,
                   n_blocks: Optional[int] = None,
                   prefill_chunk: int = 32,
                   max_queue: Optional[int] = None,
                   admission=None, max_preemptions: int = 8,
                   fault_plan=None,
                   max_ticks: Optional[int] = None) -> SimStats:
    """Replay the ``PagedServeEngine`` scheduler on the host — no model,
    no jax — and return its tick accounting.

    Models a ``prefix_cache=False`` engine (the calibration baseline:
    block sharing changes *which* chunks run, not the tick structure's
    cost shape).  The tick loop mirrors ``PagedServeEngine.run``
    step-for-step — the canonical 8-step order documented in
    :mod:`repro.serve.resilience`: faults, cancellations, timeouts,
    forced preemptions, shed (queue cap then the pluggable policy), FIFO
    admission under slot + *prompt*-block backpressure, one prefill
    chunk per prefilling slot, then block growth (exhaustion preempts
    victims latest-admitted first) and one decode step for all actives.
    ``ticks`` / ``decode_steps`` / ``prefill_chunks`` and every
    resilience counter match the real engine exactly on any trace —
    including overload traces with deadlines, bounded queues, and a
    ``FaultPlan`` — pinned by ``tests/test_fleet.py``.  Pass the *same*
    ``admission`` policy and ``fault_plan`` objects the engine ran with:
    both are pure host logic whose effects are functions of the tick, so
    sharing the instances is safe.
    """
    from repro.serve.resilience import (OK, PREEMPTED, QueueCapPolicy,
                                        queue_entries)
    reqs = as_requests(trace)
    nb_table = math.ceil(max_len / page)
    if n_blocks is None:
        n_blocks = max_batch * nb_table + 1
    capacity = n_blocks - 1                          # null block reserved
    for i, r in enumerate(reqs):
        s = r.prompt.shape[0]
        if s + r.n_steps > max_len:
            raise ValueError(f"request {i} does not fit max_len {max_len}")
        if math.ceil((s + r.n_steps) / page) > capacity:
            raise ValueError(
                f"request {i} needs {math.ceil((s + r.n_steps) / page)} "
                f"blocks but the pool's capacity is {capacity} blocks")

    policies = []
    if max_queue is not None:
        policies.append(QueueCapPolicy(max_queue))
    if admission is not None:
        policies.append(admission)

    queue = collections.deque(
        sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i)))
    # slot state: None or [req_idx, filled, remaining, blocks, kv_len, seq]
    slots: List[Optional[list]] = [None] * max_batch
    free_blocks = capacity
    seized: List[list] = []                          # [release_tick, k]
    seq_counter = 0
    emitted = [0] * len(reqs)                        # tokens kept per request
    preempt_count = [0] * len(reqs)
    done = [False] * len(reqs)

    tick = decode_steps = prefill_chunks = 0
    n_ok = n_shed = n_timeout = n_cancel = n_preempt = n_stalled = 0
    occupancy: List[float] = []

    def finish(rid: int, status: str) -> None:
        done[rid] = True
        nonlocal n_ok
        if status == OK:
            n_ok += 1

    def clear_slot(si: int) -> None:
        nonlocal free_blocks
        free_blocks += slots[si][3]
        slots[si] = None

    def drop_queued(rids, status: str) -> None:
        nonlocal queue
        dropped = set(rids)
        if not dropped:
            return
        queue = collections.deque(r for r in queue if r not in dropped)
        for rid in rids:
            finish(rid, status)

    def preempt(si: int) -> None:
        nonlocal n_preempt
        rid = slots[si][0]
        clear_slot(si)
        preempt_count[rid] += 1
        n_preempt += 1
        if preempt_count[rid] > max_preemptions:
            finish(rid, PREEMPTED)                   # partial tokens kept
            return
        emitted[rid] = 0                             # recompute from scratch
        key = (reqs[rid].arrival, rid)
        pos = 0
        for pos, q in enumerate(queue):              # sorted re-insert
            if (reqs[q].arrival, q) > key:
                break
        else:
            pos = len(queue)
        queue.insert(pos, rid)

    def victims_latest_first() -> List[int]:
        held = [(slots[si][5], si) for si in range(max_batch)
                if slots[si] is not None]
        return [si for _, si in sorted(held, reverse=True)]

    while queue or any(s is not None for s in slots):
        if max_ticks is not None and tick >= max_ticks:
            raise RuntimeError(
                f"simulated scheduler exceeded max_ticks={max_ticks} — "
                "deadlock canary tripped")

        # 1. faults: release expired seizures, seize for faults firing now
        stalled = False
        if fault_plan is not None:
            keep = []
            for rel in seized:
                if rel[0] <= tick:
                    free_blocks += rel[1]
                else:
                    keep.append(rel)
            seized = keep
            for f in fault_plan.seizures(tick):
                k = free_blocks if f.n is None else min(f.n, free_blocks)
                if k:
                    free_blocks -= k
                    seized.append([tick + f.duration, k])
            stalled = fault_plan.stalled(tick)
            if stalled:
                n_stalled += 1

        # 2. cancellations, then 3. timeouts (queued, then in-flight)
        cancelled = [rid for rid in queue
                     if reqs[rid].cancel_at is not None
                     and tick >= reqs[rid].cancel_at]
        drop_queued(cancelled, "CANCELLED")
        n_cancel += len(cancelled)
        for si in range(max_batch):
            if slots[si] is None:
                continue
            r = reqs[slots[si][0]]
            if r.cancel_at is not None and tick >= r.cancel_at:
                rid = slots[si][0]
                clear_slot(si)
                finish(rid, "CANCELLED")
                n_cancel += 1
        timed_out = [rid for rid in queue
                     if reqs[rid].deadline is not None
                     and tick > reqs[rid].deadline]
        drop_queued(timed_out, "TIMEOUT")
        n_timeout += len(timed_out)
        for si in range(max_batch):
            if slots[si] is None:
                continue
            r = reqs[slots[si][0]]
            if r.deadline is not None and tick > r.deadline:
                rid = slots[si][0]
                clear_slot(si)
                finish(rid, "TIMEOUT")
                n_timeout += 1

        # 3b. fault-forced preemptions (latest-admitted first)
        if fault_plan is not None:
            for si in victims_latest_first()[
                    :fault_plan.forced_preemptions(tick)]:
                preempt(si)

        # 4. shed: queue-cap bound first, then the pluggable policy
        if policies:
            for policy in policies:
                waiting = [rid for rid in queue if reqs[rid].arrival <= tick]
                if not waiting:
                    break
                entries = queue_entries(tick, waiting, reqs, prefill_chunk)
                verdicts = dict(policy.shed(tick, entries))
                drop_queued(list(verdicts), "SHED")
                n_shed += len(verdicts)

        # 5. admit (FIFO while a slot and the PROMPT reservation fit)
        while not stalled and queue and reqs[queue[0]].arrival <= tick:
            free_slots = [i for i, s in enumerate(slots) if s is None]
            if not free_slots:
                break
            rid = queue[0]
            r = reqs[rid]
            need = max(1, math.ceil(r.prompt.shape[0] / page))
            if need > free_blocks:
                break                                # wait for retirements
            queue.popleft()
            free_blocks -= need
            si = free_slots[0]
            slots[si] = [rid, 0, r.n_steps, need, 0, seq_counter]
            seq_counter += 1

        occupancy.append((capacity - free_blocks) / capacity
                         if capacity else 0.0)

        # 6. one prefill chunk per PREFILLING slot
        for si in range(max_batch):
            slot = slots[si]
            if stalled or slot is None or slot[4] > 0:
                continue
            rid = slot[0]
            s = reqs[rid].prompt.shape[0]
            slot[1] = min(s, slot[1] + prefill_chunk)
            prefill_chunks += 1
            if slot[1] == s:                         # prefill done -> ACTIVE
                emitted[rid] += 1
                slot[2] -= 1
                slot[4] = s
                if slot[2] == 0:
                    clear_slot(si)
                    finish(rid, OK)

        # 7a. grow: ACTIVE slots crossing a page boundary allocate their
        # next block; exhaustion preempts latest-admitted first
        for si in range(max_batch):
            if stalled:
                break
            slot = slots[si]
            if slot is None or slot[4] == 0:
                continue
            if slot[4] < slot[3] * page:
                continue                             # page not full yet
            got = free_blocks >= 1
            if not got:
                for vi in victims_latest_first():
                    victim_is_self = vi == si
                    preempt(vi)
                    if victim_is_self:
                        break
                    if free_blocks >= 1:
                        got = True
                        break
            if not got or slots[si] is None:
                continue                             # grower was evicted
            free_blocks -= 1
            slot[3] += 1

        # 7b. one decode step for every ACTIVE slot
        actives = [] if stalled else \
            [si for si in range(max_batch)
             if slots[si] is not None and slots[si][4] > 0]
        if actives:
            decode_steps += 1
            for si in actives:
                slot = slots[si]
                rid = slot[0]
                emitted[rid] += 1
                slot[2] -= 1
                slot[4] += 1
                if slot[2] == 0:
                    clear_slot(si)
                    finish(rid, OK)
        tick += 1

    # mirror the engine: a run ending inside a seizure window hands the
    # fault-held blocks back before the pool accounting is reported
    for rel in seized:
        free_blocks += rel[1]
    seized = []

    return SimStats(
        requests=len(reqs), tokens=sum(emitted), ticks=tick,
        decode_steps=decode_steps, prefill_chunks=prefill_chunks,
        occupancy_mean=float(np.mean(occupancy)) if occupancy else 0.0,
        occupancy_max=float(np.max(occupancy)) if occupancy else 0.0,
        completed=n_ok, shed=n_shed, timeouts=n_timeout,
        cancelled=n_cancel, preemptions=n_preempt, stalled_ticks=n_stalled)


@dataclasses.dataclass(frozen=True)
class TickCosts:
    """Per-primitive wall costs of the real engine, fitted from runs."""

    decode_s: float
    prefill_s: float
    overhead_s: float                # per-tick scheduler overhead

    def wall_s(self, stats) -> float:
        """Predicted wall for any stats carrier with ``decode_steps`` /
        ``prefill_chunks`` / ``ticks`` (RunStats or SimStats)."""
        return (self.decode_s * stats.decode_steps
                + self.prefill_s * stats.prefill_chunks
                + self.overhead_s * stats.ticks)

    def token_latency_s(self, stats) -> float:
        return self.wall_s(stats) / max(1, stats.tokens)


def fit_tick_costs(observations: Iterable[Tuple[object, float]]
                   ) -> TickCosts:
    """Least-squares fit of (decode_s, prefill_s, overhead_s) from
    ``(stats, measured_wall_s)`` pairs (>= 3 runs with linearly
    independent tick mixes).  Costs are clamped at >= 0 — a negative
    fitted primitive means the probe mixes were degenerate."""
    rows, walls = [], []
    for stats, wall in observations:
        rows.append([stats.decode_steps, stats.prefill_chunks, stats.ticks])
        walls.append(wall)
    if len(rows) < 3:
        raise ValueError("need >= 3 observations to fit 3 tick costs")
    sol, *_ = np.linalg.lstsq(np.asarray(rows, float),
                              np.asarray(walls, float), rcond=None)
    d, p, o = (max(0.0, float(v)) for v in sol)
    return TickCosts(decode_s=d, prefill_s=p, overhead_s=o)
