"""Entry point for ``python -m repro.fleet``."""

import sys

from repro.fleet.cli import main

sys.exit(main())
