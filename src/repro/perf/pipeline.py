"""The unified prediction pipeline: ``predict(workload, device, engine)``.

One entry point for every cost question — any workload form (HLO/StableHLO
text, a parsed :class:`~repro.perf.hlo_ir.KernelGraph`, or a dry-run JSON
artifact path), any registered device, any engine, any overlay scenario
list — returning the shared :class:`~repro.perf.report.Report` schema.
:func:`sweep` runs the full cartesian product while the content-hashed
cache guarantees each module text is parsed exactly once.

Engines are looked up in a registry; :func:`register_engine` makes a new
cost model available to every consumer (roofline CLI, what-if grids,
benchmarks) in one call — see ROADMAP.md for the <30-line recipe.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Union

from repro.arch.overlay import IDENTITY, Overlay
from repro.core.machine import MachineModel, as_machine
from repro.perf import cache
from repro.perf.engines import (CostEngine, MfmaAnalyticEngine,
                                RooflineEngine, ScoreboardEngine)
from repro.perf.hlo_ir import KernelGraph
from repro.perf.report import Report, format_reports

__all__ = ["predict", "sweep", "as_graph", "register_engine", "get_engine",
           "list_engines", "format_reports"]

_ENGINES: Dict[str, Callable[[], CostEngine]] = {
    "roofline": RooflineEngine,
    "mfma": MfmaAnalyticEngine,
    "scoreboard": ScoreboardEngine,
}


def register_engine(name: str, factory: Callable[[], CostEngine]) -> None:
    """Add a cost engine to the registry (``factory()`` -> engine)."""
    _ENGINES[name] = factory


def list_engines() -> List[str]:
    return list(_ENGINES)


def get_engine(engine: Union[str, CostEngine]) -> CostEngine:
    """Coerce an engine name or instance to an instance."""
    if isinstance(engine, str):
        factory = _ENGINES.get(engine)
        if factory is None:
            raise KeyError(f"unknown engine {engine!r}; registered: "
                           f"{sorted(_ENGINES)}")
        return factory()
    return engine


def as_graph(workload, *, tpu_correct: bool = True) -> KernelGraph:
    """Coerce any workload form to a :class:`KernelGraph`.

    * ``KernelGraph``         — passed through;
    * ``str`` HLO text        — parsed via the content-hashed cache;
    * path to dry-run ``.json`` — recorded aggregates (roofline-grade).
    """
    if isinstance(workload, KernelGraph):
        return workload
    if isinstance(workload, os.PathLike):
        workload = os.fspath(workload)
    if isinstance(workload, str) and workload.endswith(".json") \
            and "\n" not in workload:
        rec = cache.load_artifact(workload)
        hlo = rec.get("hlo", {})
        return KernelGraph.from_totals(
            flops=hlo.get("flops_per_device", 0.0),
            bytes_accessed=hlo.get("bytes_per_device", 0.0),
            collective_wire=hlo.get("collective_wire_bytes", 0.0),
            flash_block_bytes=hlo.get("flash_block_bytes", 0.0),
            key=f"{rec.get('arch', '?')}/{rec.get('shape', '?')}")
    if isinstance(workload, str):
        return cache.parse_cached(workload, tpu_correct=tpu_correct)
    raise TypeError("cannot interpret workload of type "
                    f"{type(workload).__name__}; pass HLO text, a "
                    "KernelGraph, or a dry-run .json path")


def _reports_for(graph: KernelGraph, base: MachineModel, eng: CostEngine,
                 overlays: Iterable[Overlay], name: str) -> List[Report]:
    import dataclasses

    from repro.perf.engines import plan_for_graph
    out = []
    plan = None
    for ov in overlays:
        machine = base if ov.is_identity else base.with_overlay(ov)
        rep = eng.estimate(graph, machine)
        if rep.plan is None:
            # every engine reports the tiles the kernel layer would run
            # (overlay knobs scale timing, not the spec's tile geometry)
            if plan is None:
                plan = plan_for_graph(graph, base)
            rep = dataclasses.replace(rep, plan=plan)
        out.append(dataclasses.replace(rep, scenario=ov.describe(),
                                       workload=name))
    return out


def predict(workload, *, device: Union[str, MachineModel] = "mi300",
            engine: Union[str, CostEngine] = "mfma",
            overlays: Optional[Union[Overlay, Iterable[Overlay]]] = None,
            tpu_correct: bool = True,
            workload_name: str = "") -> Union[Report, List[Report]]:
    """Cost ``workload`` on ``device`` under ``engine``.

    ``overlays=None`` returns one baseline :class:`Report`; a single
    :class:`Overlay` returns its Report; a list returns one Report per
    scenario (the workload is parsed once for all of them).

    >>> predict(compiled.as_text(), device="mi300x", engine="roofline")
    >>> predict(txt, device="mi300", engine="mfma",
    ...         overlays=overlay_grid(mfma_scale=(0.5, 1, 2)))
    """
    graph = as_graph(workload, tpu_correct=tpu_correct)
    base = as_machine(device)
    eng = get_engine(engine)
    name = workload_name or graph.key
    if overlays is None:
        return _reports_for(graph, base, eng, [IDENTITY], name)[0]
    if isinstance(overlays, Overlay):
        return _reports_for(graph, base, eng, [overlays], name)[0]
    return _reports_for(graph, base, eng, list(overlays), name)


def sweep(workloads: Union[Mapping[str, object], Iterable[object]], *,
          devices: Iterable[Union[str, MachineModel]] = ("mi300",),
          engines: Iterable[Union[str, CostEngine]] = ("mfma",),
          overlays: Iterable[Overlay] = (IDENTITY,),
          tpu_correct: bool = True) -> List[Report]:
    """The fleet-wide cartesian sweep: workloads x devices x engines x
    overlays, parsing each workload exactly once.

    ``workloads`` may be a mapping (name -> HLO text / KernelGraph /
    artifact path) or a plain iterable (auto-named by content hash).
    Engine instances are shared across the whole sweep so per-engine
    memoisation (e.g. the scoreboard's measured tile loops) spans cells.
    """
    if isinstance(workloads, Mapping):
        named = list(workloads.items())
    else:
        named = [("", w) for w in workloads]
    graphs = []
    for name, w in named:
        g = as_graph(w, tpu_correct=tpu_correct)
        graphs.append((name or g.key, g))
    engs = [get_engine(e) for e in engines]
    ovs = list(overlays)
    out: List[Report] = []
    for dev in devices:
        base = as_machine(dev)
        for name, graph in graphs:
            for eng in engs:
                out.extend(_reports_for(graph, base, eng, ovs, name))
    return out
