"""Pluggable cost engines over one :class:`~repro.perf.hlo_ir.KernelGraph`.

Three built-in implementations of the :class:`CostEngine` protocol, all
emitting the shared :class:`~repro.perf.report.Report` schema:

* :class:`RooflineEngine` — peaks/bandwidths from the device spec
  (compute vs HBM vs interconnect bound, the launch-time roofline);
* :class:`MfmaAnalyticEngine` — the paper's closed-form MCE throughput
  model (each MCE retires one MFMA per ``mfma_cycles``; MXU systolic
  passes on TPUs), previously ``hlo_bridge.predict_dots``;
* :class:`ScoreboardEngine` — lowers representative GEMM tile loops to
  ``repro.core.program`` IR, runs the event-driven NRDY_MATRIX_CORE
  simulator, and extrapolates measured per-MFMA throughput to the module
  (validates the analytic issue-semantics assumption, including issue
  overhead the closed form ignores).

All engines compose with ``repro.arch`` overlay scenarios: pass a machine
built via ``get_machine(name, overlay=...)`` (or let
:func:`repro.perf.pipeline.predict` do it).  Adding an engine is
implementing ``name`` + ``estimate(graph, machine)`` and registering it —
see ROADMAP.md "Architecture" for the <30-line recipe.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

from repro.arch import select as arch_select
from repro.core import isa
from repro.core.machine import MachineModel, as_machine
# the representative-tile measurement path lives with the other
# microbenchmarks; re-exported here for legacy call sites
from repro.core.microbench import (gemm_stream, measure_plan_throughput,
                                   simulate_gemm_cu)
from repro.perf.hlo_ir import KernelGraph
from repro.perf.report import OpCost, Report

__all__ = [
    "CostEngine", "RooflineEngine", "MfmaAnalyticEngine", "ScoreboardEngine",
    "best_instr", "mfma_count", "cost_dot_pairs", "DotCosts",
    "bound_time", "roofline_times", "gemm_stream", "simulate_gemm_cu",
    "plan_for_dot", "plan_for_graph",
]


# ---------------------------------------------------------------------------
# Instruction selection + counting (moved from repro.core.hlo_bridge)
# ---------------------------------------------------------------------------

def best_instr(machine: MachineModel, hlo_dtype: str) -> Optional[str]:
    """Highest-throughput supported MFMA instruction for an operand dtype.

    Thin wrapper: instruction selection is a device property owned by
    :mod:`repro.arch.select`; the machine contributes its backing spec and
    the active ``mfma_scale``.
    """
    machine = as_machine(machine)
    spec = machine.spec
    if spec is None and machine.gpu_table is not None:
        from repro.arch.registry import get_device
        spec = get_device(machine.gpu_table)   # hand-built legacy model
    if spec is None or not spec.has_cycle_table:
        return None
    return arch_select.best_mfma_for_hlo(spec, hlo_dtype,
                                         mfma_scale=machine.mfma_scale)


def mfma_count(dot, instr_name: str) -> int:
    """MFMA instructions to cover a dot with ``instr_name`` tiles."""
    i = isa.lookup(instr_name)
    tiles = (dot.batch * math.ceil(dot.m / i.m) * math.ceil(dot.n / i.n)
             * math.ceil(dot.k / i.k))
    return math.ceil(tiles / i.blocks)


@dataclasses.dataclass
class DotCosts:
    """Aggregate of the analytic matrix-unit model over a dot list."""

    total_cycles: float = 0.0
    time_s: float = 0.0
    total_mfma: float = 0.0
    matrix_flops: float = 0.0
    instr_mix: Dict[str, int] = dataclasses.field(default_factory=dict)
    per_op: List[OpCost] = dataclasses.field(default_factory=list)


def cost_dot_pairs(machine: MachineModel, pairs: Sequence[Tuple],
                   fallback_dtype: str = "bf16") -> DotCosts:
    """The closed-form MCE/MXU throughput model over (dot, count) pairs.

    This is the ONE home of the paper's analytic issue semantics (each MCE
    retires one MFMA per ``mfma_cycles``, no intra-WF pipelining, full
    cross-WF/SIMD parallelism; 128x128 systolic passes on MXUs) —
    ``hlo_bridge.predict_dots`` and :class:`MfmaAnalyticEngine` both call
    in, so they agree exactly by construction.
    """
    machine = as_machine(machine)
    instr_mix: Dict[str, int] = defaultdict(int)
    out = DotCosts()
    clock_hz = machine.clock_mhz * 1e6

    for d, cnt in pairs:
        if machine.mxu_count:  # TPU analytic path: 128x128 systolic passes
            passes = (d.batch * math.ceil(d.m / machine.mxu_dim)
                      * math.ceil(d.n / machine.mxu_dim)
                      * math.ceil(d.k / machine.mxu_dim))
            # one pass streams mxu_dim rows through the array
            cycles = passes * machine.mxu_dim / machine.mxu_count
            cycles *= machine.mfma_scale  # what-if applies to MXU too
            op_cycles = cnt * cycles
            instr = f"mxu_{machine.mxu_dim}x{machine.mxu_dim}"
            instr_mix[instr] += int(cnt * passes)
            out.total_mfma += cnt * passes
            n_units = int(cnt * passes)
        else:
            instr = best_instr(machine, d.in_dtype) or best_instr(machine, {
                "bf16": "bf16", "f16": "f16"}.get(fallback_dtype, "f32"))
            if instr is None:
                continue
            n = mfma_count(d, instr)
            lat = machine.mfma_cycles(instr)
            # throughput bound: chip retires mce_per_cu*cu_count MFMAs / lat
            op_cycles = cnt * n * lat / (machine.mce_per_cu * machine.cu_count)
            instr_mix[instr] += int(cnt * n)
            out.total_mfma += cnt * n
            n_units = int(cnt * n)
        out.total_cycles += op_cycles
        out.matrix_flops += cnt * d.flops
        out.per_op.append(OpCost(
            label=f"dot[{d.batch}x{d.m}x{d.n}x{d.k}]{d.in_dtype}",
            kind="dot", time_s=op_cycles / clock_hz, count=float(cnt),
            flops=float(cnt * d.flops),
            detail=f"{instr} x{n_units}"))

    out.time_s = out.total_cycles / clock_hz
    out.instr_mix = dict(instr_mix)
    return out


# ---------------------------------------------------------------------------
# Roofline terms (moved from launch.roofline's inline math)
# ---------------------------------------------------------------------------

def bound_time(amount: float, rate: float) -> float:
    """Time to move/compute ``amount`` at ``rate``.

    A spec that omits a bandwidth can't bound traffic it carries: zero
    work is free, nonzero work on a zero-rate resource is infinite.
    """
    if rate <= 0:
        return 0.0 if amount <= 0 else float("inf")
    return amount / rate


def roofline_times(flops: float, nbytes: float, wire_bytes: float,
                   machine: MachineModel) -> Dict[str, float]:
    """The three roofline terms for one module on one machine.

    Peaks and bandwidths come from the machine's backing
    :class:`~repro.arch.DeviceSpec` (overlay scenarios already applied);
    an engine-level ``mfma_scale`` divides the advertised peak, matching
    ``Overlay.apply``'s ``peak_flops`` semantics.
    """
    machine = as_machine(machine)
    spec = machine.spec
    if spec is None:
        raise ValueError(
            f"{machine.name} has no backing DeviceSpec; the roofline needs "
            "bandwidths from the repro.arch registry")
    peak = spec.peak_flops_effective
    if machine.mfma_scale != 1.0:
        peak /= machine.mfma_scale
    links, link_bw = spec.interconnect.links, spec.interconnect.link_bw
    return {
        "compute": bound_time(flops, peak),
        "memory": bound_time(nbytes, spec.memory.hbm_bw),
        "collective": bound_time(wire_bytes, links * link_bw),
        "peak_flops": peak,
    }


# ---------------------------------------------------------------------------
# Tile planning for arbitrary HLO dots (the execution layer's planner)
# ---------------------------------------------------------------------------

def plan_for_dot(machine, d, fallback_dtype: str = "bf16"):
    """The :class:`~repro.kernels.plan.TilePlan` the ``mfma_gemm`` kernel
    would execute for one HLO dot on ``machine`` — dims padded to the
    alignment quantum, exactly modelling padded execution.  This is the
    SAME planner the ops layer runs, so predicted and executed tiles can
    be cross-checked (``Report.plan``).  A dot dtype the planner cannot
    size falls back to ``fallback_dtype``, mirroring ``best_instr``;
    genuine planning failures (e.g. a what-if device whose fast memory
    cannot hold one aligned tile set) propagate as ``ValueError``."""
    from repro.kernels.plan import UnknownDtypeError, plan_for
    machine = as_machine(machine)
    shapes = {"M": d.m, "N": d.n, "K": d.k}
    try:
        return plan_for("mfma_gemm", shapes, dtype=d.in_dtype,
                        device=machine, pad=True)
    except UnknownDtypeError:
        return plan_for("mfma_gemm", shapes, dtype=fallback_dtype,
                        device=machine, pad=True)


def plan_for_graph(graph: KernelGraph, machine) -> Optional[Dict]:
    """Plan dict for the module's dominant (most-FLOPs) dot, or None for
    a dot-free / totals-only graph."""
    pairs = graph.dot_pairs()
    if not pairs:
        return None
    d, _ = max(pairs, key=lambda p: p[0].flops * p[1])
    try:
        return plan_for_dot(machine, d).as_dict()
    except ValueError:
        return None


# ---------------------------------------------------------------------------
# The engine protocol + implementations
# ---------------------------------------------------------------------------

class CostEngine(Protocol):
    """What the pipeline needs from a cost model: a name and an estimate."""

    name: str

    def estimate(self, graph: KernelGraph, machine) -> Report:
        """Cost ``graph`` on ``machine`` (MachineModel/DeviceSpec/name)."""
        ...


class RooflineEngine:
    """Bandwidth/peak bound analysis from the device spec."""

    name = "roofline"

    def __init__(self, *, kernel_adjusted: bool = True):
        # kernel-adjusted: flash-attention block intermediates are
        # VMEM-resident in the shipped Pallas kernel; the XLA reference
        # materialises them
        self.kernel_adjusted = kernel_adjusted

    def estimate(self, graph: KernelGraph, machine) -> Report:
        machine = as_machine(machine)
        nbytes = graph.bytes_accessed
        if self.kernel_adjusted:
            nbytes -= graph.flash_block_bytes
        t = roofline_times(graph.flops, nbytes, graph.collective_wire,
                           machine)
        total = max(t["compute"], t["memory"], t["collective"])
        bound = max(("compute", t["compute"]), ("memory", t["memory"]),
                    ("collective", t["collective"]), key=lambda kv: kv[1])[0]
        peak, hbm = t["peak_flops"], (machine.spec.memory.hbm_bw
                                      if machine.spec else 0.0)
        links = machine.spec.interconnect if machine.spec else None
        link_rate = links.links * links.link_bw if links else 0.0
        per_op = []
        for op in graph.ops:
            if op.kind == "dot":
                ot = bound_time(op.count * op.flops, peak)
            elif op.kind == "collective":
                ot = bound_time(op.count * op.wire_bytes, link_rate)
            else:
                ot = bound_time(op.count * op.bytes, hbm)
            per_op.append(OpCost(label=op.label, kind=op.kind, time_s=ot,
                                 count=op.count,
                                 flops=float(op.count * op.flops),
                                 bytes=op.count * op.bytes))
        util = 0.0
        if total and not math.isinf(total):
            util = bound_time(graph.flops, peak) / total
        return Report(
            engine=self.name, device=machine.name,
            total_time_s=total,
            compute_time_s=t["compute"], memory_time_s=t["memory"],
            collective_time_s=t["collective"], bound=bound,
            utilization=util, per_op=per_op,
            metrics={"peak_flops": peak, "hbm_bw": hbm,
                     "link_rate": link_rate,
                     "bytes_accessed": nbytes,
                     "collective_wire_bytes": graph.collective_wire})


class MfmaAnalyticEngine:
    """The paper's closed-form MCE/MXU throughput model."""

    name = "mfma"

    def __init__(self, fallback_dtype: str = "bf16"):
        self.fallback_dtype = fallback_dtype

    def estimate(self, graph: KernelGraph, machine) -> Report:
        machine = as_machine(machine)
        costs = cost_dot_pairs(machine, graph.dot_pairs(),
                               fallback_dtype=self.fallback_dtype)
        peak = machine.matrix_flops_per_cycle * machine.clock_mhz * 1e6
        if machine.mxu_count and machine.mfma_scale != 1.0:
            # the MXU cost path scales pass time by mfma_scale but the
            # mxu_count*mxu_dim^2 peak formula can't see it — fold it in
            # here or utilization exceeds 1 under faster-MCE scenarios
            peak /= machine.mfma_scale
        util = 0.0
        if costs.time_s > 0 and peak > 0:
            util = costs.matrix_flops / costs.time_s / peak
        return Report(
            engine=self.name, device=machine.name,
            total_time_s=costs.time_s,
            compute_time_s=costs.time_s, bound="matrix",
            utilization=util, per_op=costs.per_op,
            metrics={"total_mfma": int(costs.total_mfma),
                     "mce_cycles": costs.total_cycles,
                     "matrix_flops": costs.matrix_flops,
                     "mfma_scale": machine.mfma_scale,
                     "instr_mix": costs.instr_mix})


class ScoreboardEngine:
    """Event-driven validation: representative tile loops through the
    NRDY_MATRIX_CORE simulator, extrapolated to the module.

    Per dot, the engine derives the SAME :class:`TilePlan` the
    ``mfma_gemm`` Pallas kernel would execute (``plan_for_dot``: dims
    padded to the device's alignment quantum, blocks VMEM-budgeted) and
    simulates a full-occupancy slice of that tile — one WF per MCE, each
    WF's stream its share of the plan tile's MFMA micro-ops (capped at
    ``max_tiles_per_wf``; cycles/MFMA converges well before the cap).
    The measured cycles/MFMA — which include issue overhead the analytic
    model ignores — replace the tabled latency in the throughput
    extrapolation.  MXU (table-less) devices have no instruction stream
    to simulate and fall back to the analytic pass model, flagged in
    ``metrics["simulated"]``.  ``Report.plan`` records the dominant
    dot's plan for cross-checking against the executed tiles.
    """

    name = "scoreboard"

    def __init__(self, *, max_tiles_per_wf: int = 16,
                 fallback_dtype: str = "bf16"):
        self.max_tiles_per_wf = max_tiles_per_wf
        self.fallback_dtype = fallback_dtype
        self._measured: Dict[Tuple, Dict[str, float]] = {}

    def _measure(self, machine: MachineModel, instr: str,
                 plan) -> Dict[str, float]:
        """Measured per-CU throughput for one (instruction, plan tile)
        (memoised on the timing-relevant machine state, so overlay sweeps
        re-simulate only when a knob actually changes the timing).
        ``plan=None`` (unplannable dot) measures a fixed-length stream."""
        blocks = tuple(sorted(plan.blocks.items())) if plan is not None \
            else None
        key = (instr, blocks, machine.mfma_cycles(instr), machine.t_inst,
               machine.simd_per_cu, machine.mce_per_cu)
        hit = self._measured.get(key)
        if hit is not None:
            return hit
        if plan is None:
            out = simulate_gemm_cu(machine, instr,
                                   tiles_per_wf=self.max_tiles_per_wf,
                                   n_wf=machine.mce_per_cu)
            out["tiles_per_wf"] = self.max_tiles_per_wf
            out["cycles_per_mfma_cu"] = out["makespan"] / out["total_mfma"]
        else:
            out = measure_plan_throughput(
                machine, instr, plan,
                max_tiles_per_wf=self.max_tiles_per_wf)
        self._measured[key] = out
        return out

    def estimate(self, graph: KernelGraph, machine) -> Report:
        machine = as_machine(machine)
        if machine.mxu_count or not machine.has_mfma_table:
            # No MFMA instruction stream on MXU devices: analytic pass model.
            rep = MfmaAnalyticEngine(self.fallback_dtype).estimate(
                graph, machine)
            metrics = dict(rep.metrics)
            metrics["simulated"] = 0.0
            return dataclasses.replace(rep, engine=self.name,
                                       metrics=metrics,
                                       plan=plan_for_graph(graph, machine))

        clock_hz = machine.clock_mhz * 1e6
        total_cycles = total_mfma = matrix_flops = 0.0
        util_acc = util_w = 0.0
        best_plan = None
        best_flops = -1.0
        per_op: List[OpCost] = []
        for d, cnt in graph.dot_pairs():
            instr = best_instr(machine, d.in_dtype) or best_instr(machine, {
                "bf16": "bf16", "f16": "f16"}.get(self.fallback_dtype, "f32"))
            if instr is None:
                continue
            try:
                plan = plan_for_dot(machine, d)
            except ValueError:
                plan = None     # unplannable (e.g. tiny what-if VMEM):
                                # degrade to the fixed stream, plan column
                                # stays empty like the other engines
            if plan is not None and cnt * d.flops > best_flops:
                best_flops, best_plan = cnt * d.flops, plan
            n = mfma_count(d, instr)
            meas = self._measure(machine, instr, plan)
            # chip-level: every CU runs the measured stream concurrently
            op_cycles = cnt * n * meas["cycles_per_mfma_cu"] / machine.cu_count
            total_cycles += op_cycles
            total_mfma += cnt * n
            matrix_flops += cnt * d.flops
            util_acc += meas["mce_utilization"] * cnt * n
            util_w += cnt * n
            per_op.append(OpCost(
                label=f"dot[{d.batch}x{d.m}x{d.n}x{d.k}]{d.in_dtype}",
                kind="dot", time_s=op_cycles / clock_hz, count=float(cnt),
                flops=float(cnt * d.flops),
                detail=f"{instr} {meas['cycles_per_mfma_cu']:.1f}cy/mfma"
                       + (f" tile {plan.blocks['block_m']}x"
                          f"{plan.blocks['block_n']}x"
                          f"{plan.blocks['block_k']}" if plan else "")))
        time_s = total_cycles / clock_hz
        return Report(
            engine=self.name, device=machine.name,
            total_time_s=time_s, compute_time_s=time_s, bound="matrix",
            utilization=util_acc / util_w if util_w else 0.0,
            per_op=per_op,
            plan=best_plan.as_dict() if best_plan is not None else None,
            metrics={"total_mfma": int(total_mfma),
                     "mce_cycles": total_cycles,
                     "matrix_flops": matrix_flops,
                     "mfma_scale": machine.mfma_scale,
                     "simulated": 1.0})
