"""One HLO -> :class:`KernelGraph` parser for the whole performance stack.

Before this module existed, four independent estimators each re-parsed
``compiled.as_text()`` with their own copied regexes and byte tables:
``hlo_bridge.parse_dots``/``parse_collectives``, ``hlo_analysis.analyze``,
``launch.dryrun._cpu_upcast_bytes`` and the roofline's record plumbing.
Everything textual now lives here, once:

* the per-element byte table (:data:`BYTES_PER_ELEM`),
* the shape / dot-dims / replica-group / StableHLO regexes,
* the ``while`` trip-count walk (``known_trip_count`` backend config with a
  ``compare(..., constant(N), direction=LT)`` condition fallback, nested
  loops multiply, unknown loops fall back to 1),
* the XLA:CPU bf16->f32 dot-legalisation ``convert`` accounting
  (both the TPU byte correction and the dry-run upcast-buffer estimate).

:func:`parse_module` returns a :class:`KernelGraph` of typed
:class:`KernelOp` entries — dots with B/M/N/K + dtype, collectives with
ring-model wire bytes, memory-bound ops with kernel-boundary bytes — plus
module-level aggregates.  Cost engines (:mod:`repro.perf.engines`) consume
the graph; they never see HLO text.  ``repro.core.hlo_bridge`` and
``repro.core.hlo_analysis`` are thin compatibility shims over this module.
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = [
    "BYTES_PER_ELEM", "DotOp", "KernelOp", "KernelGraph",
    "parse_module", "parse_static_dots", "parse_collectives_static",
    "collective_wire_bytes", "cpu_upcast_bytes", "graph_key",
]

# ---------------------------------------------------------------------------
# The ONE byte table (was hlo_bridge._BYTES, re-imported by hlo_analysis)
# ---------------------------------------------------------------------------

BYTES_PER_ELEM = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s64": 8, "u64": 8, "pred": 1, "s4": 1, "u4": 1,
}

# ---------------------------------------------------------------------------
# The ONE regex home
# ---------------------------------------------------------------------------

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
DEF_RE = re.compile(r"(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
DOT_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^\s]*\s+dot\(([^)]*)\)\s*,\s*(.*)")
DIMS_RE = {
    "lhs_b": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
    "rhs_b": re.compile(r"rhs_batch_dims=\{([\d,]*)\}"),
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "rhs_c": re.compile(r"rhs_contracting_dims=\{([\d,]*)\}"),
}
COLL_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# StableHLO (lowered, pre-partitioning) forms:
SH_DOT_RE = re.compile(
    r"stablehlo\.dot_general\s+[^:]*?"
    r"(?:batching_dims\s*=\s*\[([\d, ]*)\]\s*x\s*\[[\d, ]*\]\s*,\s*)?"
    r"contracting_dims\s*=\s*\[([\d, ]*)\]\s*x\s*\[([\d, ]*)\][^:]*:\s*"
    r"\(tensor<([^>]+)>,\s*tensor<([^>]+)>\)")
SH_CONV_RE = re.compile(r"stablehlo\.convolution")
# computation-structure parsing (was hlo_analysis):
# note: parameter lists may contain nested parens (tuple params), so match
# loosely: name, open-paren, anything, '->', anything, trailing '{'
COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")
OP_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
RESULT_SHAPES_RE = re.compile(r"(\w+)\[([\d,]*)\]")
TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
WHILE_ATTR_RE = re.compile(r"condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
CONST_RE = re.compile(r"(%[\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
# XLA:CPU upcast-convert accounting (was launch.dryrun._CONVERT_RE/_HDR_RE):
CONVERT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*f32\[([\d,]+)\][^\s]*\s+convert\(")
HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")

# ops that don't touch memory / are name-plumbing only
FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "add-dependency", "partition-id", "replica-id",
            "iota"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


# ---------------------------------------------------------------------------
# Typed ops + graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DotOp:
    """One matmul site (legacy shape, kept for the hlo_bridge API)."""

    in_dtype: str          # HLO dtype of operands ("bf16", "f32", ...)
    batch: int
    m: int
    n: int
    k: int

    @property
    def macs(self) -> int:
        return self.batch * self.m * self.n * self.k

    @property
    def flops(self) -> int:
        return 2 * self.macs


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One typed node of a :class:`KernelGraph`.

    ``kind``: ``"dot"`` (B/M/N/K + dtype), ``"collective"`` (result +
    ring-model wire bytes, group size) or ``"memory"`` (kernel-boundary
    bytes, aggregated per opcode).  ``count`` is the *executed* multiplier
    — the product of enclosing ``while`` trip counts; per-execution
    quantities (``flops``, ``bytes``, ``wire_bytes``) must be multiplied
    by it for module totals.  Exception: ``"memory"`` ops are per-opcode
    aggregates over computations with differing multipliers, so they
    carry ``count=1.0`` and already-loop-summed ``bytes`` (consistently,
    ``count * bytes`` is the module total for every kind).
    """

    kind: str
    opcode: str
    count: float = 1.0
    dtype: str = ""
    batch: int = 0
    m: int = 0
    n: int = 0
    k: int = 0
    bytes: float = 0.0        # kernel-boundary bytes per execution
    wire_bytes: float = 0.0   # collective wire bytes per execution
    group: int = 1            # collective replica-group size

    @property
    def in_dtype(self) -> str:
        """Alias so cost engines can treat dot KernelOps like DotOps."""
        return self.dtype

    @property
    def macs(self) -> int:
        return self.batch * self.m * self.n * self.k

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def as_dot(self) -> DotOp:
        return DotOp(in_dtype=self.dtype, batch=self.batch, m=self.m,
                     n=self.n, k=self.k)

    @property
    def label(self) -> str:
        if self.kind == "dot":
            return (f"dot[{self.batch}x{self.m}x{self.n}x{self.k}]"
                    f"{self.dtype}")
        if self.kind == "collective":
            return f"{self.opcode}(g={self.group})"
        return self.opcode


@dataclasses.dataclass
class KernelGraph:
    """The parsed per-device module: typed ops + loop-aware aggregates."""

    ops: List[KernelOp] = dataclasses.field(default_factory=list)
    flops: float = 0.0                   # loop-aware total (per device)
    bytes_accessed: float = 0.0          # loop-aware kernel-boundary bytes
    collective_wire: float = 0.0         # loop-aware per-device wire bytes
    flash_block_bytes: float = 0.0       # flash-attn block intermediates
    bytes_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)
    key: str = ""                        # content hash of the source text
    source: str = "hlo"                  # "hlo" | "stablehlo" | "totals"

    def dot_pairs(self) -> List[Tuple[KernelOp, float]]:
        """(dot, executed-count) pairs — the analytic engines' input."""
        return [(op, op.count) for op in self.ops if op.kind == "dot"]

    @property
    def dots(self) -> List[KernelOp]:
        return [op for op in self.ops if op.kind == "dot"]

    @property
    def collectives(self) -> Dict[str, Dict[str, float]]:
        """Legacy per-kind stats dict: {kind: count/result_bytes/wire_bytes}."""
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
        for op in self.ops:
            if op.kind != "collective":
                continue
            st = out[op.opcode]
            st["count"] += op.count
            st["result_bytes"] += op.count * op.bytes
            st["wire_bytes"] += op.count * op.wire_bytes
        return dict(out)

    @classmethod
    def from_totals(cls, *, flops: float = 0.0, bytes_accessed: float = 0.0,
                    collective_wire: float = 0.0,
                    flash_block_bytes: float = 0.0,
                    key: str = "") -> "KernelGraph":
        """A degenerate graph from recorded aggregates (e.g. a dry-run JSON
        artifact that stored totals but not the HLO text) — enough for the
        roofline engine, which only consumes module sums."""
        return cls(ops=[], flops=flops, bytes_accessed=bytes_accessed,
                   collective_wire=collective_wire,
                   flash_block_bytes=flash_block_bytes, key=key,
                   source="totals")


def graph_key(text: str) -> str:
    """Content hash identifying a parsed module (cache key)."""
    return hashlib.sha256(text.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Low-level helpers
# ---------------------------------------------------------------------------

def _parse_int_list(s: str) -> List[int]:
    s = s.strip()
    return [int(x) for x in s.split(",")] if s else []


def _tensor_sig(sig: str) -> Tuple[str, List[int]]:
    """'256x1024xbf16' -> ('bf16', [256, 1024]); '8xf32' -> ('f32', [8])."""
    parts = sig.split("x")
    dims, dtype = [], parts[-1]
    for p in parts[:-1]:
        dims.append(int(p))
    return dtype, dims


def _mnk(ldims, rdims, lhs_b, lhs_c, rhs_b, rhs_c) -> Tuple[int, int, int, int]:
    batch = 1
    for d in lhs_b:
        batch *= ldims[d]
    k_total = 1
    for d in lhs_c:
        k_total *= ldims[d]
    m_total = 1
    for i, d in enumerate(ldims):
        if i not in lhs_b and i not in lhs_c:
            m_total *= d
    n_total = 1
    for i, d in enumerate(rdims):
        if i not in rhs_b and i not in rhs_c:
            n_total *= d
    return batch, m_total, n_total, k_total


def _shape_bytes(dtype: str, dims: List[int]) -> float:
    if dtype not in BYTES_PER_ELEM:
        return 0.0
    size = 1
    for d in dims:
        size *= d
    return float(size * BYTES_PER_ELEM[dtype])


def _wire_bytes(kind: str, nbytes: float, g: int) -> float:
    """Ring-algorithm accounting: bytes one device moves over links.

      all-gather:         result * (g-1)/g      (receives all other shards)
      reduce-scatter:     result * (g-1)        (operand = result*g)
      all-reduce:         2 * result * (g-1)/g  (RS + AG phases)
      all-to-all:         result * (g-1)/g
      collective-permute: result                (one hop)
    """
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind in ("all-to-all", "ragged-all-to-all"):
        return nbytes * (g - 1) / g
    return nbytes  # collective-permute: one hop


def _group_size(line: str) -> int:
    m = GROUPS_RE.search(line)           # replica_groups=[G,S]<=[N]
    if m:
        return int(m.group(2))
    m = GROUPS_LIST_RE.search(line)      # replica_groups={{0,1,2,3},...}
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_alias = None
    for line in text.splitlines():
        m = COMP_HDR_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry_alias = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry_alias is not None:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _symbol_table(text: str) -> Dict[str, Tuple[str, List[int]]]:
    sym: Dict[str, Tuple[str, List[int]]] = {}
    for line in text.splitlines():
        m = OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        sm = RESULT_SHAPES_RE.search(rhs)
        if sm:
            sym[name] = (sm.group(1), _parse_int_list(sm.group(2)))
    return sym


def _opcode_of(rhs: str) -> Optional[str]:
    """Opcode from an op right-hand side like 'f32[8]{0} fusion(...)'."""
    m = re.match(r"^(?:\([^=]*?\)|[\w\[\]{},:#\*]+)\s+([\w\-]+)", rhs)
    return m.group(1) if m else None


def _operand_names(rhs: str) -> List[str]:
    lp = rhs.find("(")
    if lp < 0:
        return []
    depth, end = 0, -1
    for i in range(lp, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end < 0:
        return []
    inner = rhs[lp + 1:end]
    return re.findall(r"%[\w.\-]+", inner)


def _trip_count(line: str, cond_name: str,
                comps: Dict[str, List[str]]) -> float:
    """Trip count of a ``while`` op: the ``known_trip_count`` backend
    config when present, else the condition's
    ``compare(induction, constant(N), direction=LT)`` pattern, else 1
    (unknown-trip-count fallback: charge the body once)."""
    m = TRIP_RE.search(line)
    if m:
        return float(m.group(1))
    consts = {}
    for cl in comps.get(cond_name, []):
        cm = CONST_RE.search(cl)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))
    for cl in comps.get(cond_name, []):
        if "compare(" in cl and "direction=LT" in cl:
            for name in _operand_names(cl.split("=", 1)[1]):
                if name in consts:
                    return float(consts[name])
    return 1.0


def _convert_sources(text: str,
                     sym: Dict[str, Tuple[str, List[int]]]) -> Dict[str, str]:
    """name -> source dtype for every ``convert`` op (used to charge
    XLA:CPU's bf16->f32 dot-legalisation converts at bf16 width: those
    converts don't exist on TPU, whose MXU consumes bf16 natively)."""
    out = {}
    for line in text.splitlines():
        m = OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        if not re.match(r"^\S+\s+convert\(", rhs):
            continue
        ops = re.findall(r"%[\w.\-]+", rhs[rhs.find("("):])
        if ops and ops[0] in sym:
            out[name] = sym[ops[0]][0]
    return out


# ---------------------------------------------------------------------------
# Static dot parsing (each site counted once; StableHLO or post-SPMD HLO)
# ---------------------------------------------------------------------------

def _parse_stablehlo_dots(text: str) -> List[KernelOp]:
    out: List[KernelOp] = []
    for m in SH_DOT_RE.finditer(text):
        bdims_s, lc_s, rc_s, lsig, rsig = m.groups()
        ldt, ldims = _tensor_sig(lsig)
        rdt, rdims = _tensor_sig(rsig)
        lhs_b = _parse_int_list((bdims_s or "").replace(" ", ""))
        # batching dims are leading & symmetric in stablehlo's pretty form
        rhs_b = list(lhs_b)
        lhs_c = _parse_int_list(lc_s.replace(" ", ""))
        rhs_c = _parse_int_list(rc_s.replace(" ", ""))
        b, mm, nn, kk = _mnk(ldims, rdims, lhs_b, lhs_c, rhs_b, rhs_c)
        out.append(KernelOp(kind="dot", opcode="dot", dtype=ldt,
                            batch=b, m=mm, n=nn, k=kk))
    return out


def _parse_hlo_dots(text: str) -> List[KernelOp]:
    # symbol table: %name -> (dtype, dims) for operand resolution
    sym: Dict[str, Tuple[str, List[int]]] = {}
    for m in DEF_RE.finditer(text):
        sym[m.group(1)] = (m.group(2), _parse_int_list(m.group(3)))
    out: List[KernelOp] = []
    for line in text.splitlines():
        if " dot(" not in line:
            continue
        m = DOT_RE.search(line)
        if not m:
            continue
        odt, odims_s, operands, attrs = m.groups()
        dims = {k: _parse_int_list(rx.search(attrs).group(1))
                if rx.search(attrs) else [] for k, rx in DIMS_RE.items()}
        # operands: either inline-shaped or bare %names
        inline = SHAPE_RE.findall(operands)
        names = [t.strip().split(" ")[-1] for t in operands.split(",")]
        if len(inline) >= 2:
            (ldt, ls), (rdt, rs) = inline[0], inline[1]
            ldims, rdims = _parse_int_list(ls), _parse_int_list(rs)
        elif len(names) >= 2 and names[0] in sym and names[1] in sym:
            (ldt, ldims), (rdt, rdims) = sym[names[0]], sym[names[1]]
        else:
            # fall back: derive M,N from output; K unknown -> skip
            continue
        b, mm, nn, kk = _mnk(ldims, rdims, dims["lhs_b"], dims["lhs_c"],
                             dims["rhs_b"], dims["rhs_c"])
        out.append(KernelOp(kind="dot", opcode="dot", dtype=ldt,
                            batch=b, m=mm, n=nn, k=kk))
    return out


def parse_static_dots(text: str) -> List[KernelOp]:
    """Extract every dot op (each counted once, even inside while bodies).

    Accepts StableHLO (``lowered.as_text()`` — preserves bf16 operand types,
    global shapes) or post-SPMD HLO (``compiled.as_text()`` — per-device
    shapes; XLA:CPU upcasts bf16 dots to f32, a backend artifact).
    """
    if "stablehlo.dot_general" in text:
        return _parse_stablehlo_dots(text)
    return _parse_hlo_dots(text)


def parse_collectives_static(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind stats from post-SPMD HLO text, each op counted
    once (no loop awareness — see :func:`parse_module` for that).

    Returns {kind: {count, result_bytes, wire_bytes}}.
    """
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = COLL_RE.search(line)
        if not m:
            continue
        kind, start = m.group(1), m.group(2)
        if f"{kind}-done" in line:
            continue  # async completion: payload counted at -start
        head = line.split(f" {kind}", 1)[0]
        shapes = SHAPE_RE.findall(head)
        if not shapes:
            continue
        # async -start results are tuples (operand, result, ...): take last
        dt, dims_s = shapes[-1]
        if dt not in BYTES_PER_ELEM:
            continue
        size = 1
        for d in _parse_int_list(dims_s):
            size *= d
        nbytes = float(size * BYTES_PER_ELEM[dt])
        g = max(1, _group_size(line))
        st = stats[kind]
        st["count"] += 1
        st["result_bytes"] += nbytes
        st["wire_bytes"] += _wire_bytes(kind, nbytes, g)
    return dict(stats)


def collective_wire_bytes(hlo_text: str) -> float:
    """Total per-device wire bytes across all collectives (static count)."""
    return sum(v["wire_bytes"]
               for v in parse_collectives_static(hlo_text).values())


# ---------------------------------------------------------------------------
# XLA:CPU upcast-buffer estimate (was launch.dryrun._cpu_upcast_bytes)
# ---------------------------------------------------------------------------

def cpu_upcast_bytes(hlo_text: str) -> int:
    """XLA:CPU legalises bf16 dots by hoisting whole-buffer f32 converts
    (often outside loops).  These buffers don't exist on TPU (native bf16
    MXU operands) — estimate their total so the roofline can report a
    TPU-corrected temp size alongside the raw CPU number."""
    total = 0
    in_fused = False
    for line in hlo_text.splitlines():
        h = HDR_RE.match(line)
        if h:
            in_fused = "fused" in h.group(1) or "region" in h.group(1)
            continue
        if in_fused:
            continue
        m = CONVERT_RE.match(line)
        if not m:
            continue
        dims = m.group(1)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 < 64 * 2**20:
            continue
        if f"bf16[{dims}]" in hlo_text:   # converts a bf16 buffer of same shape
            total += n * 4
    return total


# ---------------------------------------------------------------------------
# The loop-aware module parser (was hlo_analysis.analyze)
# ---------------------------------------------------------------------------

def parse_module(text: str, *, tpu_correct: bool = True) -> KernelGraph:
    """Parse a post-SPMD module into a loop-aware :class:`KernelGraph`.

    Computations reachable from ENTRY via ``while(body=..., condition=...)``
    accumulate ``multiplier = parent_multiplier * trip_count``; per executed
    computation we account dot FLOPs (operand shapes resolved through a
    module-wide symbol table), kernel-boundary bytes for every
    materialising op, and per-kind collective wire bytes.  With
    ``tpu_correct`` (default) XLA:CPU's bf16->f32 dot-legalisation converts
    are charged at bf16 width (they don't exist on TPU).
    """
    comps = _split_computations(text)
    sym = _symbol_table(text)
    cvt_src = _convert_sources(text, sym) if tpu_correct else {}

    def shape_bytes_of(name: str) -> float:
        if name not in sym:
            return 0.0
        dt, dims = sym[name]
        if tpu_correct and dt == "f32" and cvt_src.get(name) == "bf16":
            dt = "bf16"           # TPU keeps the native bf16 operand
        return _shape_bytes(dt, dims)

    # 1. multipliers: walk from entry through while ops
    mult: Dict[str, float] = defaultdict(float)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    entry_lines = comps["__entry__"]
    # identify the actual entry computation name to avoid double count
    entry_names = [n for n, ls in comps.items() if ls is entry_lines]
    real_entry = [n for n in entry_names if n != "__entry__"][0]
    mult[real_entry] = 1.0
    frontier = [real_entry]
    while frontier:
        cname = frontier.pop()
        cmult = mult[cname]
        for line in comps.get(cname, []):
            if " while(" not in line:
                continue
            wm = WHILE_ATTR_RE.search(line)
            if not wm:
                continue
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(line, cond, comps)
            for sub, m_extra in ((body, trips), (cond, trips + 1)):
                if sub in comps:
                    mult[sub] += cmult * m_extra
                    frontier.append(sub)

    # 2. executed computations = those with a multiplier (fusion-called
    #    computations are charged at their call site, not walked).
    flops = 0.0
    nbytes = 0.0
    flash_bytes = 0.0
    by_opcode: Dict[str, float] = defaultdict(float)
    dot_ops: List[KernelOp] = []
    coll_ops: List[KernelOp] = []

    for cname, cmult in list(mult.items()):
        if cmult <= 0:
            continue
        for line in comps.get(cname, []):
            m = OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.groups()
            opcode = _opcode_of(rhs)
            if opcode is None or opcode in FREE_OPS:
                continue
            if tpu_correct and opcode == "convert" \
                    and cvt_src.get(name) == "bf16":
                continue  # CPU dot-legalisation artifact: free on TPU
            # --- bytes: result + operands (kernel-boundary traffic) ---
            line_bytes = shape_bytes_of(name)
            for opn in _operand_names(rhs):
                line_bytes += shape_bytes_of(opn)
            nbytes += cmult * line_bytes
            by_opcode[opcode] += cmult * line_bytes
            if opcode in ("fusion", "dot"):
                rdt, rdims = sym.get(name, ("", []))
                if len(rdims) >= 3 and rdims[-1] == 512 and rdims[-2] >= 128:
                    flash_bytes += cmult * line_bytes

            # --- dot flops ---
            if opcode == "dot":
                attrs = rhs.split(")", 1)[1] if ")" in rhs else ""
                dims = {k: _parse_int_list(rx.search(attrs).group(1))
                        if rx.search(attrs) else []
                        for k, rx in DIMS_RE.items()}
                opnames = _operand_names(rhs)
                if len(opnames) >= 2 and opnames[0] in sym and opnames[1] in sym:
                    (ldt, ldims), (_, rdims2) = sym[opnames[0]], sym[opnames[1]]
                    b, mm, nn, kk = _mnk(ldims, rdims2, dims["lhs_b"],
                                         dims["lhs_c"], dims["rhs_b"],
                                         dims["rhs_c"])
                    op = KernelOp(kind="dot", opcode="dot", dtype=ldt,
                                  batch=b, m=mm, n=nn, k=kk, count=cmult,
                                  bytes=line_bytes)
                    dot_ops.append(op)
                    flops += cmult * op.flops

            # --- collectives ---
            for kind in COLLECTIVES:
                if opcode == kind or opcode == kind + "-start":
                    g = 1
                    gm = GROUPS_RE.search(line)
                    if gm:
                        g = int(gm.group(2))
                    else:
                        gl = GROUPS_LIST_RE.search(line)
                        if gl:
                            g = len([x for x in gl.group(1).split(",")
                                     if x.strip()])
                    # result shape: last tensor in the (possibly tuple) result
                    shapes = RESULT_SHAPES_RE.findall(rhs.split(opcode)[0])
                    if shapes:
                        cdt, cdims = shapes[-1]
                        cb = _shape_bytes(cdt, _parse_int_list(cdims))
                        ops_n = _operand_names(rhs)
                        if tpu_correct and cdt == "f32" and ops_n and \
                                cvt_src.get(ops_n[0]) == "bf16":
                            cb /= 2  # TPU moves the bf16 tensor, not f32
                        g = max(1, g)
                        coll_ops.append(KernelOp(
                            kind="collective", opcode=kind, count=cmult,
                            dtype=cdt, bytes=cb,
                            wire_bytes=_wire_bytes(kind, cb, g), group=g))
                    break

    # 3. memory-bound traffic, one aggregated op per opcode (dot and
    #    collective traffic already carried on their typed ops).
    coll_opcodes = {op.opcode for op in coll_ops} \
        | {op.opcode + "-start" for op in coll_ops}
    mem_ops = [KernelOp(kind="memory", opcode=opc, bytes=total)
               for opc, total in sorted(by_opcode.items(),
                                        key=lambda kv: -kv[1])
               if opc != "dot" and opc not in coll_opcodes]

    return KernelGraph(
        ops=dot_ops + coll_ops + mem_ops,
        flops=flops,
        bytes_accessed=nbytes,
        collective_wire=sum(op.count * op.wire_bytes for op in coll_ops),
        flash_block_bytes=flash_bytes,
        bytes_by_opcode=dict(by_opcode),
        key=graph_key(text),
        source="hlo",
    )
