"""repro.perf — the unified performance-model pipeline.

One HLO -> :class:`KernelGraph` IR behind pluggable cost engines, cached
artifacts, and fleet-wide scenario sweeps:

  hlo_ir    — the single parser (dots, collectives, memory ops, while
              trip counts, CPU-upcast accounting) everything consumes
  engines   — CostEngine protocol: RooflineEngine / MfmaAnalyticEngine /
              ScoreboardEngine, one shared Report schema
  report    — Report/OpCost result types + sweep tables
  cache     — content-hashed memoization of parsed graphs + artifacts
  pipeline  — predict(workload, device=, engine=, overlays=) and the
              cartesian sweep() that parses each module exactly once

``repro.core.hlo_bridge`` and ``repro.core.hlo_analysis`` remain as thin
compatibility shims; new code should target this package.  To add a cost
engine, see ROADMAP.md "Architecture" (a <30-line change).
"""

from repro.perf.hlo_ir import (BYTES_PER_ELEM, DotOp, KernelGraph,  # noqa: F401
                               KernelOp, parse_module, parse_static_dots)
from repro.perf.report import OpCost, Report, format_reports  # noqa: F401
from repro.perf.engines import (CostEngine, MfmaAnalyticEngine,  # noqa: F401
                                RooflineEngine, ScoreboardEngine)
from repro.perf.cache import cache_stats, clear_cache, parse_cached  # noqa: F401
from repro.perf.pipeline import (as_graph, get_engine, list_engines,  # noqa: F401
                                 predict, register_engine, sweep)

__all__ = [
    "BYTES_PER_ELEM", "DotOp", "KernelOp", "KernelGraph",
    "parse_module", "parse_static_dots",
    "OpCost", "Report", "format_reports",
    "CostEngine", "RooflineEngine", "MfmaAnalyticEngine", "ScoreboardEngine",
    "parse_cached", "cache_stats", "clear_cache",
    "predict", "sweep", "as_graph",
    "register_engine", "get_engine", "list_engines",
]
