"""The one result schema every cost engine emits.

A :class:`Report` answers the same questions regardless of which engine
produced it — "how long, bound by what, doing what per op" — so sweeps can
mix engines, devices and overlay scenarios in one table, and a new engine
plugs into every consumer (roofline CLI, what-if grids, benchmarks) by
returning this schema.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["OpCost", "Report", "format_reports"]

#: The bottleneck vocabulary shared by all engines.
BOUNDS = ("compute", "memory", "collective", "matrix")


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Cost of one kernel-graph op under the engine's model."""

    label: str                 # e.g. "dot[1x256x256x512]bf16", "all-reduce"
    kind: str                  # "dot" | "collective" | "memory"
    time_s: float              # op time at its own bound, executed count incl.
    count: float = 1.0
    flops: float = 0.0
    bytes: float = 0.0
    detail: str = ""           # engine-specific (instr name, group size, ...)


@dataclasses.dataclass(frozen=True)
class Report:
    """Per-(workload x device x scenario) cost estimate, any engine."""

    engine: str                # "roofline" | "mfma" | "scoreboard" | custom
    device: str
    scenario: str = "baseline"           # Overlay.describe() label
    workload: str = ""                   # caller-supplied name (sweeps)
    total_time_s: float = 0.0            # end-to-end bound-implied time
    compute_time_s: float = 0.0
    memory_time_s: float = 0.0
    collective_time_s: float = 0.0
    bound: str = "compute"               # dominant term, from BOUNDS
    utilization: float = 0.0             # achieved/peak at the bottleneck
    per_op: Sequence[OpCost] = ()
    #: The dominant dot's TilePlan (``TilePlan.as_dict()``): the tiles the
    #: mfma_gemm kernel would execute for this workload on this device —
    #: lets predicted and executed tilings be cross-checked.
    plan: Optional[Dict[str, Any]] = None
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def top_ops(self, n: int = 5) -> List[OpCost]:
        return sorted(self.per_op, key=lambda o: -o.time_s)[:n]

    def plan_summary(self) -> str:
        """Compact "bm x bn x bk"-style rendering of the plan column."""
        if not self.plan:
            return "-"
        blocks = [str(v) for k, v in self.plan.items()
                  if k.startswith("block_") or k == "chunk"]
        return "x".join(blocks) if blocks else "-"

    def as_dict(self) -> Dict[str, Any]:
        """JSON-able record (benchmark artifacts, CI trajectories)."""
        d = dataclasses.asdict(self)
        d["per_op"] = [dataclasses.asdict(o) for o in self.top_ops(10)]
        d["metrics"] = {k: v for k, v in self.metrics.items()
                        if isinstance(v, (int, float, str))}
        return d

    def breakdown(self) -> str:
        """Human-readable per-op latency breakdown."""
        hdr = (f"{self.engine} on {self.device} [{self.scenario}]: "
               f"{_us(self.total_time_s)} ({self.bound}-bound, "
               f"util={self.utilization:.2f})")
        lines = [hdr]
        for o in self.top_ops(8):
            lines.append(f"  {o.label:42s} {_us(o.time_s):>12s}  {o.detail}")
        return "\n".join(lines)


def _us(t: float) -> str:
    if math.isinf(t):
        return "inf"
    return f"{t * 1e6:.1f}us"


def format_reports(reports: Sequence[Report]) -> str:
    """One row per report: the sweep-comparison table."""
    hdr = (f"| {'workload':20s} | {'device':10s} | {'engine':10s} "
           f"| {'scenario':24s} | {'total':>10s} | {'bound':10s} | util "
           f"| {'plan':14s} |")
    sep = "|" + "-" * 22 + "|" + "-" * 12 + "|" + "-" * 12 + "|" + "-" * 26 \
        + "|" + "-" * 12 + "|" + "-" * 12 + "|------|" + "-" * 16 + "|"
    out = [hdr, sep]
    for r in reports:
        out.append(
            f"| {r.workload[:20]:20s} | {r.device[:10]:10s} "
            f"| {r.engine[:10]:10s} | {r.scenario[:24]:24s} "
            f"| {_us(r.total_time_s):>10s} | {r.bound:10s} "
            f"| {r.utilization:4.2f} | {r.plan_summary()[:14]:14s} |")
    return "\n".join(out)
