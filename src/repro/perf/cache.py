"""Content-hashed memoization of parsed kernel graphs + dry-run artifacts.

A full (arch x shape x device x overlay x engine) sweep used to re-parse
each HLO module once per estimator; with this cache it parses exactly once
per distinct module text (asserted by ``tests/test_perf_cache.py``).
Keys are content hashes, so identical text from different callers shares
one entry and a recompiled (changed) module can never serve stale costs.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import OrderedDict
from pathlib import Path
from typing import Any, Dict

from repro.perf.hlo_ir import KernelGraph, graph_key, parse_module

__all__ = ["parse_cached", "load_artifact", "cache_stats", "clear_cache",
           "CacheStats"]

_MAX_GRAPHS = 64          # parsed modules are a few MB each at most
_MAX_ARTIFACTS = 256


@dataclasses.dataclass
class CacheStats:
    parses: int = 0        # cache misses: full text parses performed
    hits: int = 0
    artifact_loads: int = 0
    artifact_hits: int = 0


_stats = CacheStats()
_graphs: "OrderedDict[str, KernelGraph]" = OrderedDict()
_artifacts: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()


def parse_cached(text: str, *, tpu_correct: bool = True) -> KernelGraph:
    """:func:`repro.perf.hlo_ir.parse_module`, memoised on content hash."""
    key = f"{graph_key(text)}:{int(tpu_correct)}"
    hit = _graphs.get(key)
    if hit is not None:
        _graphs.move_to_end(key)
        _stats.hits += 1
        return hit
    _stats.parses += 1
    graph = parse_module(text, tpu_correct=tpu_correct)
    _graphs[key] = graph
    while len(_graphs) > _MAX_GRAPHS:
        _graphs.popitem(last=False)
    return graph


def load_artifact(path) -> Dict[str, Any]:
    """A dry-run JSON record, memoised on file content hash.

    Sweeps over the same artifact directory (roofline + what-if + bench)
    read each record once per content version; editing or regenerating a
    record invalidates its entry automatically.
    """
    raw = Path(path).read_bytes()
    key = hashlib.sha256(raw).hexdigest()[:16]
    hit = _artifacts.get(key)
    if hit is not None:
        _artifacts.move_to_end(key)
        _stats.artifact_hits += 1
        return hit
    _stats.artifact_loads += 1
    rec = json.loads(raw)
    _artifacts[key] = rec
    while len(_artifacts) > _MAX_ARTIFACTS:
        _artifacts.popitem(last=False)
    return rec


def cache_stats() -> CacheStats:
    return dataclasses.replace(_stats)


def clear_cache() -> None:
    _graphs.clear()
    _artifacts.clear()
    _stats.parses = _stats.hits = 0
    _stats.artifact_loads = _stats.artifact_hits = 0
