"""Jamba-v0.1-52B: hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; one attention
layer per 8 (offset 4), MoE (16 experts, top-2, d_ff=14336) every other
layer; Mamba mixers d_state=16, conv=4, expand=2.  No explicit positional
embedding (the SSM provides position).  Sub-quadratic overall -> runs
long_500k (the 4 attention layers use the blockwise kernel; mamba is O(S)).
"""

from repro.models.config import ModelConfig, MoESpec, SSMSpec

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128, pos_embed="none",
    attn_period=8, attn_offset=4,
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=14336, period=2),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    sub_quadratic=True,
    microbatches=8,
)
