"""Whisper-base: encoder-decoder audio model [arXiv:2212.04356].

6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048 vocab=51865; LayerNorm,
GELU MLP, learned positions, tied embeddings.  The conv audio frontend is
a STUB: the encoder consumes precomputed (batch, 1500, 512) frame
embeddings.  The 32k decoder shapes exceed whisper's trained 448 positions
but lower/compile mechanically (DESIGN.md).
"""

from repro.models.config import EncoderSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, norm_type="layer", mlp_type="gelu",
    pos_embed="learned", tie_embeddings=True,
    encoder=EncoderSpec(n_layers=6, n_frames=1500),
)
