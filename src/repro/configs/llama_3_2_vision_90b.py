"""Llama-3.2-Vision-90B backbone [hf:meta-llama/Llama-3.2-11B-Vision,
scaled per assignment].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
is a gated cross-attention layer over 4100 precomputed patch embeddings
(vision tower is a STUB per the assignment: ``input_specs`` supplies
(batch, 4100, d_model) media embeddings).
"""

from repro.models.config import CrossAttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, head_dim=128, rope_theta=500_000.0,
    cross_attn=CrossAttnSpec(period=5, n_media_tokens=4100),
    microbatches=16,
    grad_accum_dtype="bfloat16",
)
