"""Architecture registry + input-shape cells.

``get_config("yi-34b")`` returns the exact published config; each arch file
exports ``CONFIG``.  ``SHAPES`` defines the 4 assigned input shapes; the
(arch x shape) applicability matrix (with skip reasons) lives here so the
dry-run, roofline, and DESIGN.md all agree.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "applicable", "ShapeSpec",
           "all_cells"]

ARCHS = [
    "yi-34b", "mistral-nemo-12b", "internlm2-20b", "qwen2-7b",
    "llama-3.2-vision-90b", "mamba2-370m", "whisper-base",
    "qwen3-moe-235b-a22b", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module("repro.configs." + arch.replace("-", "_")
                                  .replace(".", "_"))
    return mod.CONFIG


def applicable(arch: str, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention: 524288-token decode is "
                "intentionally skipped (DESIGN.md §Arch-applicability); "
                "run for SSM/hybrid archs only")
    return None


def all_cells():
    """Every runnable (arch, shape) pair plus the documented skips."""
    run, skip = [], []
    for a in ARCHS:
        for s in SHAPES:
            reason = applicable(a, s)
            (skip if reason else run).append((a, s, reason))
    return run, skip
