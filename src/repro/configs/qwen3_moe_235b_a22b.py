"""Qwen3-235B-A22B: MoE decoder [hf:Qwen/Qwen3-30B-A3B family, per
assignment].

94L d_model=4096 64H (GQA kv=4, head_dim=128) vocab=151936;
MoE: 128 experts, top-8, d_ff=1536 per expert, no shared experts,
renormalised top-k gates.
"""

from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=0,
    vocab_size=151936, head_dim=128, rope_theta=1_000_000.0,
    moe=MoESpec(n_experts=128, top_k=8, d_ff_expert=1536),
    microbatches=8,
    grad_accum_dtype="bfloat16",
)
