"""Yi-34B: llama-arch dense GQA decoder [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=20480 vocab=64000.
56 heads do not divide the 16-way model axis -> attention runs
sequence-TP (see attention.py); FFN/vocab shard cleanly.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128, rope_theta=5_000_000.0,
    microbatches=2,
)
