"""Mistral-Nemo-12B (Base-2407): dense GQA, 128k context
[hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim=128
(explicit in the HF config: 32*128 = 4096 != d_model).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128, rope_theta=1_000_000.0,
    microbatches=2,
)
