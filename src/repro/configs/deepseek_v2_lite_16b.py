"""DeepSeek-V2-Lite-16B: MLA + MoE [arXiv:2405.04434].

27L d_model=2048 16H MLA (kv_lora=512, qk_nope=128, qk_rope=64, v=128)
vocab=102400; layer 0 uses a dense 10944-wide FFN, layers 1-26 are MoE
with 64 routed experts (top-6) + 2 shared experts of d_ff=1408.

Fidelity note (also in DESIGN.md): the assignment line says "MoE 64e
top-6" and "2 shared+160 routed"; 160 routed is full DeepSeek-V2 — the
Lite model is 64 routed + 2 shared, which matches the 64e spec we build.
"""

from repro.models.config import MLASpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab_size=102400, rope_theta=10_000.0,
    mla=MLASpec(kv_lora_rank=512, q_lora_rank=0, qk_nope_dim=128,
                qk_rope_dim=64, v_head_dim=128),
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                d_ff_shared=2816),
    first_k_dense=1,
)
