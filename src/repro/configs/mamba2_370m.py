"""Mamba2-370M: attention-free SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 (attn-free, no FFN: d_ff=0) vocab=50280, ssm_state=128.
d_inner = 2*1024 = 2048, head_dim 64 -> 32 SSD heads, 1 B/C group.
O(S) scan => runs the long_500k shape.
"""

from repro.models.config import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32, d_ff=0,
    vocab_size=50280, pos_embed="none", tie_embeddings=True,
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    sub_quadratic=True,
)
