import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The dry-run lowers/compiles only (never executes), so keep faithful bf16
# dots in the HLO instead of the CPU-execution f32 upcast (see layers.mm).
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. builds abstract, sharding-annotated inputs (launch/specs.py),
  3. ``jax.jit(fn).lower(...).compile()`` — sharding mismatches, OOM at
     compile, or unsupported collectives fail HERE, which is the point,
  4. records memory_analysis / cost_analysis / loop-aware HLO stats
     (FLOPs, bytes, per-kind collective wire bytes) to JSON for the
     roofline (§Roofline) and the MFMA what-if bridge.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, all_cells, applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_fn_and_specs
from repro.parallel.api import set_mesh
# the upcast-convert estimator and the loop-aware module parser both live
# in the unified performance pipeline now (one regex home)
from repro.perf.cache import parse_cached
from repro.perf.hlo_ir import cpu_upcast_bytes as _cpu_upcast_bytes

__all__ = ["run_cell", "main"]


def _mem_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
        try:
            upcast = _cpu_upcast_bytes(compiled.as_text())
            out["cpu_upcast_convert_bytes"] = upcast
            out["tpu_estimate_bytes_per_device"] = (
                out["total_bytes_per_device"] - upcast)
        except Exception:
            pass
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    """Lower+compile one cell; returns the stats record."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.size, "kind": shape.kind}
    # donate the state buffers (params/opt for train, KV cache for decode):
    # the updated state aliases the input allocation, as in production
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    t0 = time.time()
    with set_mesh(mesh):
        fn, specs = cell_fn_and_specs(arch, shape, mesh, cfg=cfg)
        lowered = jax.jit(fn, donate_argnums=donate).lower(*specs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    rec["n_params"] = int(sum(
        x.size for x in jax.tree.leaves(specs[0])))

    mem = _mem_stats(compiled)
    rec["memory"] = mem                         # proves it fits
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals", "utilization")}
    except Exception:
        rec["cost_analysis"] = {}

    # loop-aware stats from the compiled (post-SPMD, per-device) module;
    # parse_cached means the what-if / roofline consumers of this same
    # text reuse the KernelGraph instead of re-parsing
    try:
        graph = parse_cached(compiled.as_text())
        top_ops = dict(sorted(graph.bytes_by_opcode.items(),
                              key=lambda kv: -kv[1])[:10])
        rec["hlo"] = {
            "flops_per_device": graph.flops,
            "bytes_per_device": graph.bytes_accessed,
            "collectives": graph.collectives,
            "collective_wire_bytes": graph.collective_wire,
            "bytes_by_opcode": top_ops,
            "flash_block_bytes": graph.flash_block_bytes,
        }
    except Exception as e:  # keep the cell green; roofline can re-derive
        rec["hlo"] = {"error": f"{type(e).__name__}: {e}"}

    if verbose:
        mb = mem.get("total_bytes_per_device", 0) / 2**30
        tb = mem.get("tpu_estimate_bytes_per_device", 0) / 2**30
        fl = rec.get("hlo", {}).get("flops_per_device", 0)
        print(f"[dryrun] {arch:24s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile={rec['compile_s']:7.1f}s mem/dev={mb:6.2f}GiB "
              f"(tpu-est {tb:6.2f}) flops/dev={fl:.3e}", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every runnable (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        cells, skips = all_cells()
        todo = [(a, s) for a, s, _ in cells]
        for a, s, reason in skips:
            print(f"[dryrun] SKIP {a} {s}: {reason}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        reason = applicable(args.arch, args.shape)
        if reason:
            print(f"[dryrun] SKIP {args.arch} {args.shape}: {reason}")
            return 0
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            path = out_dir / f"{tag}.json"
            try:
                rec = run_cell(arch, shape, mp)
                path.write_text(json.dumps(rec, indent=1))
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for t, e in failures:
            print("  ", t, e)
        return 1
    print(f"[dryrun] all {len(todo) * len(meshes)} cells compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
