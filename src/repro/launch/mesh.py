"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=256 chips/pod ("data","model"); multi-pod adds a leading
    2-way "pod" axis (the slower DCN/ICI-optical dimension) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh for single-device tests (exercises the sharding
    code paths without requiring fake devices)."""
    return jax.make_mesh(shape, axes)
