"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Everything here is abstract: ``jax.eval_shape`` over the init functions,
with NamedShardings attached — weak-type-correct, shardable, zero device
allocation.  The same specs drive the dry-run, the roofline, and the perf
hillclimb, so the three always measure the same program.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs import ShapeSpec, get_config
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.models.layers import DTYPES
from repro.parallel.api import logical_to_spec
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

__all__ = ["sharded_abstract", "batch_specs", "cell_fn_and_specs",
           "abstract_params", "abstract_opt_state", "abstract_cache"]


def sharded_abstract(tree, rule: Callable, mesh: Optional[Mesh]):
    """Attach NamedShardings (via a (path, leaf)->logical-axes rule) to an
    abstract pytree."""
    def f(path, leaf):
        if mesh is None:
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
        spec = logical_to_spec(leaf.shape, rule(path, leaf), mesh)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree_util.tree_map_with_path(f, tree)


def abstract_params(cfg: ModelConfig, mesh: Optional[Mesh]):
    shapes = jax.eval_shape(
        functools.partial(model_lib.init_params, cfg), jax.random.PRNGKey(0))
    return sharded_abstract(shapes, model_lib.param_axes_rule, mesh)


def abstract_opt_state(cfg: ModelConfig, params_abstract, mesh: Optional[Mesh]):
    shapes = jax.eval_shape(init_opt_state, params_abstract)
    return sharded_abstract(shapes, model_lib.param_axes_rule, mesh)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   mesh: Optional[Mesh]):
    shapes = jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, batch, max_len))
    return sharded_abstract(shapes, model_lib.cache_axes_rule, mesh)


def _batch_rule(path, leaf):
    nd = len(leaf.shape)
    return ("batch",) + (None,) * (nd - 1)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Optional[Mesh],
                *, with_labels: bool) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    dt = DTYPES[cfg.dtype]
    b = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if with_labels:
        b["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.cross_attn:
        b["media"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_attn.n_media_tokens, cfg.d_model), dt)
    if cfg.encoder:
        b["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), dt)
    return sharded_abstract(b, _batch_rule, mesh)


def cell_fn_and_specs(arch: str, shape: ShapeSpec, mesh: Optional[Mesh],
                      cfg: Optional[ModelConfig] = None,
                      opt_cfg: Optional[OptConfig] = None
                      ) -> Tuple[Callable, Tuple]:
    """The function this cell lowers + its abstract, sharded arguments.

    train  -> train_step(params, opt_state, batch)
    prefill-> prefill(params, batch)           (last-token logits + cache)
    decode -> decode_step(params, cache, tokens, pos)
    """
    cfg = cfg or get_config(arch)
    params = abstract_params(cfg, mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, opt_cfg)
        opt = abstract_opt_state(cfg, params, mesh)
        batch = batch_specs(cfg, shape, mesh, with_labels=True)
        return step, (params, opt, batch)

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, mesh, with_labels=False)
        fn = functools.partial(model_lib.prefill, cfg, max_len=shape.seq_len)
        return (lambda p, b: fn(p, b)), (params, batch)

    # decode: one new token against a seq_len KV cache
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, mesh)
    tokens = sharded_abstract(
        {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)},
        _batch_rule, mesh)["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    if mesh is not None:
        pos = jax.ShapeDtypeStruct(
            (), jnp.int32,
            sharding=NamedSharding(mesh, logical_to_spec((), (), mesh)))
    fn = functools.partial(model_lib.decode_step, cfg)
    return fn, (params, cache, tokens, pos)
