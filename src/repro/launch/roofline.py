"""Roofline analysis over the dry-run artifacts (assignment §Roofline).

Per (arch x shape) single-pod cell, from the compiled per-device module:

  compute_t    = HLO_FLOPs_dev / peak_FLOPs          (spec.peak_flops)
  memory_t     = HLO_bytes_dev / HBM_bw              (spec.memory.hbm_bw)
  collective_t = wire_bytes_dev / (links x link_bw)  (spec.interconnect;
                 ``links`` counts concurrently-driven ring links —
                 a 2D-torus all-reduce can stripe further)

plus the dominant term, MODEL_FLOPS (6·N·D train / 2·N·D prefill+decode,
N_active for MoE), and the useful-compute ratio MODEL/HLO.

Peaks and bandwidths come from the ``repro.arch`` device registry (default
``tpu_v5e``: 197 bf16 TF/s, 819 GB/s HBM, 2 x 50 GB/s ICI) — any
registered device rooflines via ``--device``.  The bound math itself is
the unified pipeline's :class:`repro.perf.engines.RooflineEngine`; this
module is the dry-run-artifact CLI over it.

    python -m repro.launch.roofline --dryrun-dir experiments/dryrun \
        [--device tpu_v5p]
"""

from __future__ import annotations

import argparse
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.arch import DeviceSpec, get_device
from repro.configs import SHAPES, get_config
from repro.perf.cache import load_artifact
from repro.perf.engines import RooflineEngine
from repro.perf.hlo_ir import KernelGraph

_DEFAULT_DEVICE = "tpu_v5e"

__all__ = ["roofline_row", "active_fraction", "main", "load_cells"]


def active_fraction(arch: str) -> float:
    """Active-parameter fraction for MoE archs (routed experts scaled by
    top_k / n_experts; shared experts and the rest count fully)."""
    cfg = get_config(arch)
    if cfg.moe is None:
        return 1.0
    from repro.launch.specs import abstract_params
    params = abstract_params(cfg, None)
    total = routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(p.key) for p in path if hasattr(p, "key")]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if any(nm in ("we_g", "we_i", "we_o") for nm in names):
            routed += n
    frac = (total - routed + routed * cfg.moe.top_k / cfg.moe.n_experts) \
        / total
    return frac


def model_flops(arch: str, shape_name: str, n_params: int) -> float:
    """6·N·D for training, 2·N·D for single-pass inference (per step)."""
    shape = SHAPES[shape_name]
    act = active_fraction(arch)
    n_active = n_params * act
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


def roofline_row(rec: Dict, spec: Optional[DeviceSpec] = None
                 ) -> Optional[Dict]:
    spec = spec or get_device(_DEFAULT_DEVICE)
    hlo = rec.get("hlo", {})
    if "flops_per_device" not in hlo:
        return None
    n_dev = rec["n_devices"]
    f = hlo["flops_per_device"]
    b = hlo["bytes_per_device"]
    graph = KernelGraph.from_totals(
        flops=f, bytes_accessed=b,
        collective_wire=hlo["collective_wire_bytes"],
        # kernel-adjusted: flash-attention block intermediates are
        # VMEM-resident in the shipped Pallas kernel; the XLA reference
        # materialises them
        flash_block_bytes=hlo.get("flash_block_bytes", 0.0),
        key=f"{rec['arch']}/{rec['shape']}")
    report = RooflineEngine().estimate(graph, spec)
    report_xla = RooflineEngine(kernel_adjusted=False).estimate(graph, spec)
    compute_t, memory_t = report.compute_time_s, report.memory_time_s
    coll_t = report.collective_time_s
    mf = model_flops(rec["arch"], rec["shape"], rec["n_params"]) / n_dev
    step_t = report.total_time_s
    peak_flops = report.metrics["peak_flops"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_t": compute_t, "memory_t": memory_t,
        "memory_t_xla": report_xla.memory_time_s,
        "collective_t": coll_t, "dominant": report.bound,
        "model_flops_dev": mf, "hlo_flops_dev": f,
        "useful_ratio": mf / f if f else 0.0,
        # roofline fraction: useful model FLOPs per second at the
        # bottleneck-implied step time, vs peak
        "roofline_frac": (mf / step_t) / peak_flops if step_t else 0.0,
        "collectives": hlo.get("collectives", {}),
        "mem_gib": rec.get("memory", {}).get("total_bytes_per_device", 0)
        / 2**30,
        "mem_tpu_est_gib": rec.get("memory", {}).get(
            "tpu_estimate_bytes_per_device", 0) / 2**30,
    }


def load_cells(dryrun_dir: str, mesh: str = "single",
               device: str = _DEFAULT_DEVICE):
    spec = get_device(device)
    rows = []
    for f in sorted(Path(dryrun_dir).glob(f"*_{mesh}.json")):
        rec = load_artifact(f)
        row = roofline_row(rec, spec)
        if row:
            rows.append(row)
    return rows


def _fmt(rows):
    hdr = (f"| {'arch':24s} | {'shape':11s} | compute_ms | memory_ms | "
           "collective_ms | dominant | MODEL/HLO | roofline |")
    sep = "|" + "-" * 26 + "|" + "-" * 13 + "|" + "-" * 12 + "|" + "-" * 11 \
        + "|" + "-" * 15 + "|" + "-" * 10 + "|" + "-" * 11 + "|" + "-" * 10 + "|"
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']:24s} | {r['shape']:11s} "
            f"| {r['compute_t'] * 1e3:10.2f} | {r['memory_t'] * 1e3:9.2f} "
            f"| {r['collective_t'] * 1e3:13.2f} | {r['dominant']:8s} "
            f"| {r['useful_ratio']:9.3f} | {r['roofline_frac']:8.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--device", default=_DEFAULT_DEVICE,
                    help="device registry name whose peaks/bandwidths "
                         "anchor the roofline (e.g. tpu_v5e, tpu_v5p)")
    args = ap.parse_args()
    rows = load_cells(args.dryrun_dir, device=args.device)
    table = _fmt(rows)
    print(table)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(table + "\n")
    # quick bottleneck census
    from collections import Counter
    census = Counter(r["dominant"] for r in rows)
    print("\nbottleneck census:", dict(census))


if __name__ == "__main__":
    main()
