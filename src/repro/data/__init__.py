"""Data pipeline: synthetic sharded LM token stream with host prefetch."""

from repro.data.pipeline import SyntheticLM, prefetch_to_device  # noqa: F401
