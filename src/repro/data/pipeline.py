"""Synthetic sharded LM data pipeline.

``SyntheticLM`` generates a deterministic Zipf-distributed token stream
with local n-gram correlations (so the ~100M-param example actually has
signal to learn: token t+1 depends on token t through a fixed permutation
mixed with noise).  Batches are addressable by step — ``batch(step)`` is a
pure function of (seed, step) — which makes the fault-tolerant controller's
restart/replay exact and multi-host loading embarrassingly parallel (each
host materialises only its batch rows).

``prefetch_to_device`` overlaps host generation with device compute via a
background thread + bounded queue, placing each batch with the target
NamedSharding.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import jax
import numpy as np

__all__ = ["SyntheticLM", "prefetch_to_device"]


class SyntheticLM:
    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0, correlation: float = 0.8):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.correlation = correlation
        rng = np.random.RandomState(seed)
        self._perm = rng.permutation(vocab_size)
        # Zipf-ish unigram distribution
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=B, p=self._p)
        follow = rng.random((B, S)) < self.correlation
        fresh = rng.choice(self.vocab_size, size=(B, S), p=self._p)
        for t in range(S):
            nxt = self._perm[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch_np(step)

    def iterate(self, start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start
        while True:
            yield self.batch_np(step)
            step += 1


def prefetch_to_device(it: Iterator, *, size: int = 2,
                       sharding=None) -> Iterator:
    """Background-thread prefetch; places batches with ``sharding``."""
    q: "queue.Queue" = queue.Queue(maxsize=size)
    _END = object()

    def put(batch):
        if sharding is not None:
            batch = jax.tree.map(lambda x: jax.device_put(x, sharding), batch)
        else:
            batch = jax.tree.map(jax.device_put, batch)
        q.put(batch)

    def worker():
        try:
            for b in it:
                put(b)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        b = q.get()
        if b is _END:
            return
        yield b
