"""Mesh/axis plumbing shared by the model zoo and the launchers.

Design (mirrors MaxText-style logical axis rules, compacted):

* Physical mesh axes: ``pod`` (slow DCN axis, multi-pod only), ``data``
  (fast ICI, batch + FSDP), ``model`` (fast ICI, TP + EP).
* Model code never names physical axes.  It annotates arrays with *logical*
  axes (``"batch"``, ``"embed"``, ``"heads"``, ``"expert"``, ...) through
  :func:`shard`; :class:`AxisSpec` maps logical -> physical with a
  divisibility guard, so e.g. a 51865-row vocab silently drops the 16-way
  ``model`` axis instead of failing to partition.
* The active mesh + rules are installed by ``set_mesh`` (a context manager)
  and queried via ``current_mesh``/``current_axes``.  Outside a mesh, every
  annotation is a no-op, so the same model code runs in single-device smoke
  tests and in the 512-device dry-run unchanged.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisSpec", "DEFAULT_RULES", "set_mesh", "current_mesh",
           "current_axes", "shard", "logical_to_spec", "named_sharding"]


Physical = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """Logical-axis -> physical-mesh-axes mapping ("the rules")."""

    rules: Tuple[Tuple[str, Physical], ...]

    def physical(self, logical: Optional[str]) -> Physical:
        if logical is None:
            return ()
        for name, phys in self.rules:
            if name == logical:
                return phys
        return ()

    def replace(self, **updates: Physical) -> "AxisSpec":
        d = dict(self.rules)
        d.update(updates)
        return AxisSpec(tuple(d.items()))


#: Baseline rules.  ``batch`` spans the pure-DP pod axis plus the data axis;
#: ``fsdp`` (weight sharding) stays on the fast intra-pod ``data`` axis;
#: tensor/expert parallelism on ``model``; context parallelism reuses
#: ``data`` (long_500k runs with per-pod batch 1, so the axis is free).
DEFAULT_RULES = AxisSpec((
    ("batch", ("pod", "data")),
    # weight/optimizer sharding: fast ICI axis first, then the pod axis
    # (ZeRO-3 across pods — 235B-class states don't fit one pod's HBM;
    # cross-pod weight gathers ride DCN, where grad compression applies)
    ("fsdp", ("data", "pod")),
    ("tp", ("model",)),
    ("expert", ("model",)),
    ("context", ("data",)),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    # sequence parallelism for the residual stream (Megatron-SP style):
    # activations between blocks shard S over the model axis; GSPMD
    # inserts the all-gather/reduce-scatter pairs around TP matmuls
    ("seq", ("model",)),
    # query-sequence TP, used when head counts don't divide the model axis
    ("seq_tp", ("model",)),
    # decode KV-cache sequence axis: model first, then data when free
    # (long_500k batch=1 -> 256-way sequence sharding)
    ("kv_seq", ("model", "data")),
))


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.axes: AxisSpec = DEFAULT_RULES


_STATE = _State()


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh], axes: AxisSpec = DEFAULT_RULES):
    prev = (_STATE.mesh, _STATE.axes)
    _STATE.mesh, _STATE.axes = mesh, axes
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.mesh, _STATE.axes = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def current_axes() -> AxisSpec:
    return _STATE.axes


def _filter_axes(mesh: Mesh, dim: int, phys: Physical) -> Physical:
    """Keep only mesh axes that exist and evenly divide ``dim``."""
    out = []
    size = 1
    for ax in phys:
        if ax not in mesh.shape:
            continue
        nsz = size * mesh.shape[ax]
        if dim % nsz != 0:
            continue
        size = nsz
        out.append(ax)
    return tuple(out)


def logical_to_spec(shape: Sequence[int],
                    logical: Sequence[Optional[str]],
                    mesh: Optional[Mesh] = None,
                    axes: Optional[AxisSpec] = None) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Divisibility-guarded: axes that do not divide the dim (or are absent
    from the mesh) are dropped — and an axis may be used by only one dim
    (first wins), matching GSPMD validity rules.
    """
    mesh = mesh or current_mesh()
    axes = axes or current_axes()
    if mesh is None:
        return P(*([None] * len(shape)))
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        phys = [a for a in axes.physical(name) if a not in used]
        phys = _filter_axes(mesh, dim, tuple(phys))
        used.update(phys)
        if not phys:
            parts.append(None)
        elif len(phys) == 1:
            parts.append(phys[0])
        else:
            parts.append(tuple(phys))
    return P(*parts)


def named_sharding(shape: Sequence[int], logical: Sequence[Optional[str]],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(shape, logical, mesh))


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; identity without an active mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(x.shape, logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
