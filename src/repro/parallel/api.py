"""Mesh/axis plumbing shared by the model zoo and the launchers.

Design (mirrors MaxText-style logical axis rules, compacted):

* Physical mesh axes: ``pod`` (slow DCN axis, multi-pod only), ``data``
  (fast ICI, batch + FSDP), ``model`` (fast ICI, TP + EP).
* Model code never names physical axes.  It annotates arrays with *logical*
  axes (``"batch"``, ``"embed"``, ``"heads"``, ``"expert"``, ...) through
  :func:`shard`; :class:`AxisSpec` maps logical -> physical with a
  divisibility guard, so e.g. a 51865-row vocab silently drops the 16-way
  ``model`` axis instead of failing to partition.
* The active mesh + rules are installed by ``set_mesh`` (a context manager)
  and queried via ``current_mesh``/``current_axes``.  Outside a mesh, every
  annotation is a no-op, so the same model code runs in single-device smoke
  tests and in the 512-device dry-run unchanged.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisSpec", "DEFAULT_RULES", "set_mesh", "current_mesh",
           "current_axes", "shard", "logical_to_spec", "named_sharding",
           "ShardAssignment", "shard_assignment", "local_shapes"]


Physical = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """Logical-axis -> physical-mesh-axes mapping ("the rules")."""

    rules: Tuple[Tuple[str, Physical], ...]

    def physical(self, logical: Optional[str]) -> Physical:
        if logical is None:
            return ()
        for name, phys in self.rules:
            if name == logical:
                return phys
        return ()

    def replace(self, **updates: Physical) -> "AxisSpec":
        d = dict(self.rules)
        d.update(updates)
        return AxisSpec(tuple(d.items()))


#: Baseline rules.  ``batch`` spans the pure-DP pod axis plus the data axis;
#: ``fsdp`` (weight sharding) stays on the fast intra-pod ``data`` axis;
#: tensor/expert parallelism on ``model``; context parallelism reuses
#: ``data`` (long_500k runs with per-pod batch 1, so the axis is free).
DEFAULT_RULES = AxisSpec((
    ("batch", ("pod", "data")),
    # weight/optimizer sharding: fast ICI axis first, then the pod axis
    # (ZeRO-3 across pods — 235B-class states don't fit one pod's HBM;
    # cross-pod weight gathers ride DCN, where grad compression applies)
    ("fsdp", ("data", "pod")),
    ("tp", ("model",)),
    ("expert", ("model",)),
    ("context", ("data",)),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    # sequence parallelism for the residual stream (Megatron-SP style):
    # activations between blocks shard S over the model axis; GSPMD
    # inserts the all-gather/reduce-scatter pairs around TP matmuls
    ("seq", ("model",)),
    # query-sequence TP, used when head counts don't divide the model axis
    ("seq_tp", ("model",)),
    # decode KV-cache sequence axis: model first, then data when free
    # (long_500k batch=1 -> 256-way sequence sharding)
    ("kv_seq", ("model", "data")),
))


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.axes: AxisSpec = DEFAULT_RULES


_STATE = _State()


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh], axes: AxisSpec = DEFAULT_RULES):
    prev = (_STATE.mesh, _STATE.axes)
    _STATE.mesh, _STATE.axes = mesh, axes
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.mesh, _STATE.axes = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def current_axes() -> AxisSpec:
    return _STATE.axes


def _filter_axes(mesh: Mesh, dim: int, phys: Physical) -> Physical:
    """Keep only mesh axes that exist and evenly divide ``dim``."""
    out = []
    size = 1
    for ax in phys:
        if ax not in mesh.shape:
            continue
        nsz = size * mesh.shape[ax]
        if dim % nsz != 0:
            continue
        size = nsz
        out.append(ax)
    return tuple(out)


def logical_to_spec(shape: Sequence[int],
                    logical: Sequence[Optional[str]],
                    mesh: Optional[Mesh] = None,
                    axes: Optional[AxisSpec] = None) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Divisibility-guarded: axes that do not divide the dim (or are absent
    from the mesh) are dropped — and an axis may be used by only one dim
    (first wins), matching GSPMD validity rules.
    """
    if len(shape) != len(logical):
        raise ValueError(
            "logical_to_spec: shape and logical axis names must have the "
            f"same rank; got shape={tuple(shape)} (rank {len(shape)}) vs "
            f"logical={tuple(logical)} (rank {len(logical)})")
    mesh = mesh or current_mesh()
    axes = axes or current_axes()
    if mesh is None:
        return P(*([None] * len(shape)))
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        phys = [a for a in axes.physical(name) if a not in used]
        phys = _filter_axes(mesh, dim, tuple(phys))
        used.update(phys)
        if not phys:
            parts.append(None)
        elif len(phys) == 1:
            parts.append(phys[0])
        else:
            parts.append(tuple(phys))
    return P(*parts)


def named_sharding(shape: Sequence[int], logical: Sequence[Optional[str]],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(shape, logical, mesh))


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; identity without an active mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(x.shape, logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Per-shard shape resolution (the shard_map side of the kernel dispatch).
#
# ``logical_to_spec`` answers "how does GSPMD lay out *one array*"; the
# helpers below answer the op-level question the kernel layer needs: given
# the named dims of a whole op (B, H, KV, ...) and which logical axis each
# dim belongs to, how many ways does each dim shard on the active mesh, and
# what does one shard's shape look like?  Dims that share a logical axis
# (e.g. Q heads and KV heads both on "heads") must shard *together* — a
# mesh axis is used only if every size>1 dim in the group divides by it, so
# the grouped ratios (H/KV for GQA, nh/G for SSD) survive partitioning.
# Size-1 dims in a group are broadcast: they never block the axis and stay
# size 1 per shard (MQA's single KV head, Mamba-2's single B/C group).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """How an op's named dims land on the mesh.

    ``counts`` maps every dim name to its shard count (1 = replicated);
    ``axes_of`` maps the sharded dims to the physical mesh axes they use.
    """

    counts: Mapping[str, int]
    axes_of: Mapping[str, Physical]

    def spec(self, *dims: Optional[str]) -> P:
        """PartitionSpec for one array whose axes are the named dims.

        ``None`` marks an array axis that is not an op dim (always
        replicated).  Kernel wrappers use this to derive shard_map
        in/out specs from the same assignment the planner used.
        """
        parts = []
        for d in dims:
            phys = self.axes_of.get(d, ()) if d is not None else ()
            if not phys:
                parts.append(None)
            elif len(phys) == 1:
                parts.append(phys[0])
            else:
                parts.append(tuple(phys))
        return P(*parts)

    def local(self, shapes: Mapping[str, int]) -> Dict[str, int]:
        """Per-shard sizes of ``shapes`` under this assignment."""
        return {d: n // self.counts.get(d, 1) for d, n in shapes.items()}


def shard_assignment(shapes: Mapping[str, int],
                     logical: Mapping[str, Optional[str]],
                     mesh: Optional[Mesh] = None,
                     axes: Optional[AxisSpec] = None) -> ShardAssignment:
    """Assign mesh axes to an op's named dims via logical-axis rules.

    ``shapes`` maps dim name -> global size; ``logical`` maps dim name ->
    logical axis (dims absent from ``logical`` stay replicated).  Walks
    logical axes in first-appearance order of ``shapes``; each mesh axis is
    consumed by at most one logical axis (first wins, mirroring
    ``logical_to_spec``).  Without an active mesh everything is replicated.
    """
    unknown = [d for d in logical if d not in shapes]
    if unknown:
        raise ValueError(
            f"shard_assignment: logical map names dims {unknown} that are "
            f"not in shapes {sorted(shapes)}")
    mesh = mesh or current_mesh()
    axes = axes or current_axes()
    counts: Dict[str, int] = {d: 1 for d in shapes}
    axes_of: Dict[str, Physical] = {}
    if mesh is None:
        return ShardAssignment(counts, axes_of)
    used: set = set()
    seen: set = set()
    for dim in shapes:
        name = logical.get(dim)
        if name is None or name in seen:
            continue
        seen.add(name)
        group = [d for d in shapes if logical.get(d) == name]
        big = [d for d in group if shapes[d] > 1]
        if not big:
            continue
        assigned = []
        factor = 1
        for ax in axes.physical(name):
            if ax in used or ax not in mesh.shape:
                continue
            nf = factor * mesh.shape[ax]
            if any(shapes[d] % nf != 0 for d in big):
                continue
            factor = nf
            assigned.append(ax)
        if factor == 1:
            continue
        used.update(assigned)
        for d in big:
            counts[d] = factor
            axes_of[d] = tuple(assigned)
    return ShardAssignment(counts, axes_of)


def local_shapes(shapes: Mapping[str, int],
                 logical: Mapping[str, Optional[str]],
                 mesh: Optional[Mesh] = None,
                 axes: Optional[AxisSpec] = None) -> Dict[str, int]:
    """Map an op's global dim sizes to one shard's sizes on the mesh.

    This is what ``kernels.dispatch`` plans tiles against when an op runs
    under ``shard_map``: the kernel only ever sees the local block.
    """
    return shard_assignment(shapes, logical, mesh, axes).local(shapes)
