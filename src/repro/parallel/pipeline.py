"""Scan-based pipeline parallelism (GPipe schedule) over a mesh axis.

Each rank of the ``pp`` axis owns one contiguous stage of layers
(``stage_params`` stacked on a leading n_stages dim, sharded over the
axis).  The schedule runs ``n_micro + n_stages - 1`` ticks; at each tick
every rank applies its stage and the activation ring advances one hop via
``collective_permute`` — compute and communication overlap across ranks,
bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1).

This is the opt-in alternative to pure FSDP for the multi-pod mesh: map
``pp`` onto the "pod" axis so only stage-boundary activations cross the
slow DCN link (vs. per-layer weight gathers under cross-pod ZeRO-3).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["gpipe_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_apply(stage_fn: Callable, stage_params, x: jax.Array, *,
                mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run ``x`` through the pipeline.

    stage_fn(params_slice, h) -> h          (one stage, shapes preserved)
    stage_params: pytree, leaves (n_stages, ...) — sharded over ``axis``
    x: (n_micro, mb, ...) microbatched input (replicated)
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def ranked(params_loc, x_all):
        params_loc = jax.tree.map(lambda a: a[0], params_loc)  # (1,...) -> (...)
        rank = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            buf = carry
            # rank 0 ingests microbatch t (zeros once the stream dries up)
            x_t = jax.lax.dynamic_index_in_dim(
                x_all, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
            buf = jnp.where(rank == 0,
                            jnp.where(t < n_micro, x_t, jnp.zeros(mb_shape,
                                                                  x_all.dtype)),
                            buf)
            y = stage_fn(params_loc, buf)
            # the last rank emits microbatch t - (n_stages - 1)
            emit = y * (rank == n_stages - 1).astype(y.dtype)
            # advance the ring
            buf_next = jax.lax.ppermute(y, axis, perm)
            return buf_next, emit

        buf0 = jnp.zeros(mb_shape, x_all.dtype)
        _, emits = jax.lax.scan(tick, buf0, jnp.arange(n_ticks))
        # emits[t] is valid for microbatch t-(n_stages-1); all-reduce picks
        # the last rank's values (all other ranks contributed zeros)
        out = jax.lax.psum(emits[n_stages - 1:], axis)
        return out

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map(ranked, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stage_params, x)
