"""int8 gradient compression with error feedback (pod-axis all-reduce).

Cross-pod gradient reduction rides the slow DCN axis; compressing the
payload bf16/f32 -> int8 cuts wire bytes 2-4x.  Scheme (per leaf):

  scale  = max|g| / 127          (one f32 per leaf per pod)
  q      = round(g / scale) : int8
  wire   = all_reduce(q)  — the int8 tensor is what crosses the DCN
  g_hat  = q * scale ; residual = g - dequant(q)  (error feedback, applied
           to the *next* step's gradient so quantisation error is not lost)

``compress_decompress`` is the jit-safe quantise+EF core (usable as a
``grad_transform`` in make_train_step); ``int8_psum`` is the shard_map
form that actually reduces int8 over a named axis — the unit tests verify
the two compose to a true compressed all-reduce.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "compress_decompress", "int8_psum",
           "init_residuals"]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_residuals(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads, residuals) -> Tuple[Any, Any]:
    """Quantise grads (+ carried residual), return (g_hat, new_residuals).

    Simulates the int8 wire format end-to-end; on hardware the psum runs
    between quantize and dequantize (see int8_psum)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize(gf)
        g_hat = dequantize(q, scale)
        return g_hat.astype(g.dtype), gf - g_hat
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = jax.tree_util.tree_unflatten(treedef, [a for a, _ in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [b for _, b in out])
    return g_hat, new_r


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean all-reduce with an int8 payload over ``axis_name`` (use inside
    shard_map over the pod axis).  All ranks agree on ONE scale (pmax of
    |x| — a scalar pre-reduce) so the int32 sum of int8 partials
    dequantises exactly; wire cost = int8 tensor + one f32 scalar."""
    n = jax.lax.psum(1, axis_name)
    xf = x.astype(jnp.float32)
    scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (qsum.astype(jnp.float32) * scale / n).astype(x.dtype)
