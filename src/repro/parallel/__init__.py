"""Distribution layer: mesh axes, sharding rules, compression, pipeline."""

from repro.parallel.api import (AxisSpec, current_axes, set_mesh, current_mesh,
                                shard, logical_to_spec)  # noqa: F401
