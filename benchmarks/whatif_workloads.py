"""Framework-scale what-if (the paper's Section V-B payoff): decompose the
compiled smoke-scale train steps of assigned architectures into MFMA
streams and predict matrix-unit-bound time on EVERY device in the
``repro.arch`` registry (MI200/MI300/MI300X, TPU v5e/v5p), under
``mfma_scale`` overlays in {1, 2} — one ``repro.perf.sweep`` call over the
unified pipeline, each module parsed exactly once.

This is the gem5-for-PyTorch story at static-analysis speed: the same HLO
the dry-run validates is re-costed against each device's capability spec.
"""

from __future__ import annotations

import os

# lower/compile only (never executes): analyse the faithful bf16 program,
# not the CPU-execution f32 upcast (see repro.models.layers.mm)
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

import sys
import time

import jax
import jax.numpy as jnp

from repro.arch import Overlay, list_devices
from repro.configs import get_config
from repro.models import init_params
from repro.models.model import loss_fn
from repro.perf import parse_cached, sweep

ARCHS = ["qwen2-7b", "mamba2-370m", "deepseek-v2-lite-16b",
         "qwen3-moe-235b-a22b"]
ARCHS_SMALL = ["qwen2-7b"]            # CI smoke grid


def _compiled_text(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    if cfg.cross_attn:
        batch["media"] = jax.ShapeDtypeStruct(
            (2, cfg.cross_attn.n_media_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (2, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    fn = jax.jit(lambda p, b: loss_fn(cfg, p, b))
    return fn.lower(params, batch).compile().as_text()


def main(small: bool = False):
    rows = []
    for arch in (ARCHS_SMALL if small else ARCHS):
        t0 = time.perf_counter()
        graph = parse_cached(_compiled_text(arch))
        dt = (time.perf_counter() - t0) * 1e6
        reports = sweep({arch: graph}, devices=list(list_devices()),
                        engines=("mfma",),
                        overlays=[Overlay(mfma_scale=s) for s in (1.0, 2.0)])
        for r in reports:
            scale = r.metrics["mfma_scale"]
            rows.append((
                f"whatif/{arch}/{r.device}/x{scale:g}", dt,
                f"mfma={r.metrics['total_mfma']} "
                f"mce_us={r.total_time_s * 1e6:.1f} "
                f"mix={len(r.metrics['instr_mix'])}kinds"))
    return rows


if __name__ == "__main__":
    for r in main(small="--small" in sys.argv):
        print(",".join(str(x) for x in r))
