"""Framework-scale what-if (the paper's Section V-B payoff): decompose the
compiled smoke-scale train/decode steps of assigned architectures into MFMA
streams and predict matrix-unit-bound time on EVERY device in the
``repro.arch`` registry (MI200/MI300/MI300X, TPU v5e/v5p), under
``mfma_scale`` overlays in {1, 2}.

This is the gem5-for-PyTorch story at static-analysis speed: the same HLO
the dry-run validates is re-costed against each device's capability spec.
"""

from __future__ import annotations

import os

# lower/compile only (never executes): analyse the faithful bf16 program,
# not the CPU-execution f32 upcast (see repro.models.layers.mm)
os.environ.setdefault("REPRO_CPU_F32_DOTS", "0")

import time

import jax
import jax.numpy as jnp

from repro.arch import Overlay, list_devices
from repro.configs import get_config
from repro.core.hlo_analysis import analyze
from repro.core.hlo_bridge import predict_dots
from repro.core.machine import get_machine
from repro.models import init_params
from repro.models.model import loss_fn

ARCHS = ["qwen2-7b", "mamba2-370m", "deepseek-v2-lite-16b",
         "qwen3-moe-235b-a22b"]


def _compiled_text(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    if cfg.cross_attn:
        batch["media"] = jax.ShapeDtypeStruct(
            (2, cfg.cross_attn.n_media_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (2, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    fn = jax.jit(lambda p, b: loss_fn(cfg, p, b))
    return fn.lower(params, batch).compile().as_text()


def main():
    rows = []
    for arch in ARCHS:
        t0 = time.perf_counter()
        txt = _compiled_text(arch)
        stats = analyze(txt)
        dt = (time.perf_counter() - t0) * 1e6
        for machine_name in list_devices():
            for scale in (1.0, 2.0):
                m = get_machine(machine_name,
                                overlay=Overlay(mfma_scale=scale))
                pred = predict_dots(m, stats.dots)
                rows.append((
                    f"whatif/{arch}/{machine_name}/x{scale:g}", dt,
                    f"mfma={pred.total_mfma} mce_us={pred.mce_time_s * 1e6:.1f} "
                    f"mix={len(pred.instr_mix)}kinds"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
