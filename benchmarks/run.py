"""Benchmark harness: one module per paper table (+ framework extensions).

Prints ``name,us_per_call,derived`` CSV rows:
  mfma_latency        Tables II-V  (MI200/MI300 latency vs Expected)
  mfma_scale          Table VI     (--mfma-scale what-if)
  whatif_workloads    Section V-B at framework scale (HLO -> MFMA streams)
  scoreboard_bench    Section III occupancy/utilisation study
  kernels_bench       Pallas kernels (interpret mode, vs oracles)
"""

import sys
import traceback


def main() -> int:
    from benchmarks import (kernels_bench, mfma_latency, mfma_scale,
                            scoreboard_bench, whatif_workloads)
    mods = [("mfma_latency", mfma_latency), ("mfma_scale", mfma_scale),
            ("whatif_workloads", whatif_workloads),
            ("scoreboard_bench", scoreboard_bench),
            ("kernels_bench", kernels_bench)]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in mods:
        try:
            for row in mod.main():
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}")
        except Exception:
            failed += 1
            print(f"{name},ERROR,", file=sys.stderr)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
