"""CI fleet-planner trajectory: time the capacity-planning hot paths and
write a ``BENCH_fleet.json`` artifact comparable across runs.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--small]
        [--out BENCH_fleet.json] [--check-against BENCH_fleet.json]
        [--threshold 0.3]

Rows (name, us_per_call, derived):

* ``frontier/builtin_grid``   — one :func:`repro.fleet.frontier` call over
  every built-in scenario x the five catalog devices (the CLI's default
  workload; the planner must stay interactive);
* ``frontier/overlay_grid``   — chat x mi300 under an mfma_scale overlay
  grid (the what-if path through ``perf.sweep``);
* ``serve_cost/chat_mi300``   — a single scenario-device cell (analytic
  graph build + two roofline predictions);
* ``simulate/mixed_trace``    — the host-side scheduler replica on a
  64-request trace (the calibration inner loop).

``--check-against`` reuses the speed-normalised trend guard from
``benchmarks/perf_smoke.py`` — the run fails when any row regresses more
than ``--threshold`` beyond the machine-speed factor.  The derived
columns double as correctness gates: the build grid must come back fully
feasible (every scenario plannable on every device) or the bench fails
regardless of timing.
"""

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

DEVICES = ("mi200", "mi300", "mi300x", "tpu_v5e", "tpu_v5p")


def _best_of(fn, repeats):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def _mixed_trace(n=64, seed=0):
    import numpy as np

    from repro.serve.api import Request
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0
    for i in range(n):
        t += int(rng.poisson(1))
        long = i % 4 == 1
        s = int(rng.integers(96, 130)) if long else int(rng.integers(6, 48))
        steps = int(rng.integers(3, 9)) if long else int(rng.integers(4, 16))
        reqs.append(Request(prompt=rng.integers(0, 512, (s,))
                            .astype(np.int32), n_steps=steps, arrival=t))
    return reqs


def main(small: bool = False):
    """Run the grid; returns [(name, us_per_call, derived), ...]."""
    from repro.arch.overlay import IDENTITY, overlay_grid
    from repro.fleet import frontier, list_scenarios, serve_cost, \
        simulate_trace

    repeats = 2 if small else 3
    rows = []

    us, rep = _best_of(lambda: frontier(list_scenarios(), DEVICES), repeats)
    feasible = sum(r.feasible for r in rep.rows)
    if feasible != len(rep.rows):
        raise SystemExit(f"[fleet_bench] FAIL: only {feasible}/"
                         f"{len(rep.rows)} frontier cells feasible")
    rows.append(("frontier/builtin_grid", us,
                 f"rows={len(rep.rows)} feasible={feasible}"))

    ovs = [IDENTITY] + overlay_grid(mfma_scale=(0.5, 2.0))
    us, rep = _best_of(lambda: frontier("chat", ("mi300",), overlays=ovs),
                       repeats)
    qps = {round(r.max_qps, 3) for r in rep.rows}
    if len(qps) < 2:
        raise SystemExit("[fleet_bench] FAIL: overlay grid did not move "
                         "the frontier")
    rows.append(("frontier/overlay_grid", us,
                 f"overlays={len(ovs)} distinct_qps={len(qps)}"))

    us, cost = _best_of(lambda: serve_cost("chat", "mi300"), repeats)
    rows.append(("serve_cost/chat_mi300", us,
                 f"tick={cost.decode_tick_s * 1e3:.2f}ms "
                 f"bound={cost.decode_bound}"))

    trace = _mixed_trace()
    us, sim = _best_of(lambda: simulate_trace(
        trace, max_len=160, max_batch=8, page=32, prefill_chunk=32),
        repeats)
    rows.append(("simulate/mixed_trace", us,
                 f"ticks={sim.ticks} decode={sim.decode_steps} "
                 f"prefill={sim.prefill_chunks}"))
    return rows


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--small", action="store_true",
                    help="CI smoke: fewer repeats")
    ap.add_argument("--check-against", default=None, metavar="BASELINE",
                    help="fail on >threshold us_per_call regression vs "
                         "this baseline JSON")
    ap.add_argument("--threshold", type=float, default=0.3,
                    help="allowed fractional regression (default 0.3)")
    args = ap.parse_args()

    rows = main(small=args.small)
    payload = {
        "schema": "bench_fleet/v1",
        "python": platform.python_version(),
        "results": {"fleet_bench": [
            {"name": n, "us_per_call": round(float(us), 3), "derived": d}
            for n, us, d in rows]},
    }
    Path(args.out).write_text(json.dumps(payload, indent=1))
    for n, us, d in rows:
        print(f"[fleet_bench] {n:28s} {us:10.1f}us  {d}")
    print(f"[fleet_bench] {len(rows)} rows -> {args.out}")

    if args.check_against:
        from benchmarks.perf_smoke import check_against
        baseline = json.loads(Path(args.check_against).read_text())
        regressions, speed = check_against(payload, baseline,
                                           args.threshold)
        if regressions:
            for (bench, name), base, new in regressions:
                print(f"[fleet_bench] REGRESSION {bench}/{name}: "
                      f"{base:.1f}us -> {new:.1f}us "
                      f"({new / base:.2f}x vs machine-speed factor "
                      f"{speed:.2f}x)")
            return 1
        print(f"[fleet_bench] trend guard OK vs {args.check_against} "
              f"(machine-speed factor {speed:.2f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(cli())
