"""Trace-replay serve benchmark: continuous batching vs the synchronous
bucket engine, plus the prefix-cache / chunked-prefill scenarios.

    PYTHONPATH=src python benchmarks/serve_bench.py [--small]
        [--out BENCH_serve.json] [--check-against BENCH_serve.json]
        [--threshold 0.25] [--min-speedup 1.5]
        [--min-prefix-hit 0.5] [--min-prefix-speedup 1.1]

Scenarios (all on the same reduced model config):

* **base** — ragged (arrival x prompt x output) mix, synchronous bucket
  replay vs the continuous engine.  The synchronous baseline does what
  ``ServeEngine`` can do: FIFO batches of ``max_batch``, every prompt
  right-padded to the batch max, every request decoded for the
  batch-max step count — the padding and convoy waste continuous
  batching exists to remove.
* **shared_prefix** — requests sharing a 2-page system prompt (the
  shared-system-prompt trace recipe: one fixed 256-token prefix, short
  unique tails).  The same trace runs with the prefix cache on and off
  (``prefix_cache=False``): the cached run admits later requests by
  refcount bumps + tail-only chunk prefill, so per-token latency and
  TTFT drop while the hit rate shows up in the stats payload.
* **long_prompt** — long multi-page prompts arriving amid short
  decode-heavy traffic; chunked incremental prefill (32-token chunks
  interleaved with decode ticks) vs monolithic admission
  (``prefill_chunk=max_len``: the whole prompt in one stall).  The
  headline here is the p99 per-token gap, the stall chunking bounds.
* **overload** — offered load past capacity: burst arrivals with
  deadlines on a deliberately small engine (a 256-token bucket whose
  requests outgrow one page, a pool too small for every slot's growth,
  a bounded queue, deadline-aware shedding).  The graceful-degradation row: requests shed/preempt/time
  out instead of queueing without bound, and the run reports the shed
  rate (``--max-shed-rate`` gates it) plus a ``overload_p99_token``
  trend row so p99-under-preemption rides the regression guard.

All replays are timed warm (one run to populate jit caches, then the
timed pass).  Reported per engine: tokens/s over *requested* tokens,
p50/p99 per-token latency, TTFT (admission -> first emit) p50/p99, and
prefix-cache hit rate where applicable.  ``--check-against`` applies
the same speed-normalised >threshold regression gate as
``perf_smoke.py``; ``--min-speedup`` fails the run if continuous
batching stops beating the synchronous baseline; ``--min-prefix-hit`` /
``--min-prefix-speedup`` gate the shared-prefix scenario's hit rate and
its cached-vs-nocache per-token speedup.
"""

import argparse
import json
import math
import platform
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

MAX_LEN = 128
MAX_BATCH = 8

# shared-prefix / long-prompt scenarios need multi-page prompts: pages
# are MXU-aligned (128 rows), so sharing starts at prompts > 128 tokens
SP_MAX_LEN = 384
SP_PREFIX = 256

# overload scenario bucket: big enough that requests cross a page (the
# page is MXU-pinned at 128 rows, so growth needs max_len > 128) while
# the pool stays smaller than max_batch * 2 pages
OV_MAX_LEN = 256

# the generators themselves live in the repro.serve.traces registry —
# the fleet planner replays the same mixes the bench measures


def _latency_stats(results, t0):
    """Per-token gap latencies + TTFT (admission -> first emit)."""
    lats, ttfts = [], []
    for r in results:
        prev = t0
        for t in r.emit_times:
            lats.append(t - prev)
            prev = t
        if r.emit_times:
            ttfts.append(r.emit_times[0] - r.admit_time)
    lats = np.asarray(sorted(lats))
    ttfts = np.asarray(sorted(ttfts)) if ttfts else np.zeros(1)
    return {
        "p50_token_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_token_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)) * 1e3, 3),
        "ttft_p99_ms": round(float(np.percentile(ttfts, 99)) * 1e3, 3),
    }


_REPEATS = 5       # recorded runs per engine; best wall wins (CI VMs see
                    # bursty neighbour noise that spikes individual runs
                    # by 10-20%, and min-filtering over enough repeats is
                    # the standard way to reject it)


def run_continuous(cfg, params, trace, *, max_len=MAX_LEN,
                   max_batch=MAX_BATCH, **engine_kw):
    from repro.serve import PagedServeEngine

    eng = PagedServeEngine(cfg, params, max_len=max_len,
                           max_batch=max_batch, **engine_kw)
    reqs = list(trace)                             # typed Request trace
    eng.run(reqs)                                  # warm the jit caches
    wall, t0, results, stats = math.inf, 0.0, None, None
    for _ in range(_REPEATS):
        t0_i = time.perf_counter()
        results_i, stats_i = eng.run(reqs)
        wall_i = time.perf_counter() - t0_i
        if wall_i < wall:
            wall, t0, results, stats = wall_i, t0_i, results_i, stats_i
    tokens = stats["tokens"]
    out = {
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "occupancy_mean": round(stats["occupancy_mean"], 4),
        "occupancy_max": round(stats["occupancy_max"], 4),
        "decode_steps": stats["decode_steps"],
        "prefill_chunks": stats["prefill_chunks"],
        "prefix_hit_rate": round(stats["prefix_hit_rate"], 4),
        "prefix_blocks_reused": stats["prefix_blocks_reused"],
    }
    out.update(_latency_stats(results, t0))
    # graceful-degradation accounting (zeros on uncontended scenarios)
    out["completed"] = stats["completed"]
    out["shed"] = stats["shed"]
    out["timeouts"] = stats["timeouts"]
    out["preemptions"] = stats["preemptions"]
    out["shed_rate"] = round((stats["shed"] + stats["timeouts"])
                             / max(1, stats["requests"]), 4)
    return out


def run_sync(cfg, params, trace):
    from repro.serve import ServeEngine

    groups = [trace[i:i + MAX_BATCH]
              for i in range(0, len(trace), MAX_BATCH)]
    # bucketed serving must hold padded-prompt + batch-max decode for its
    # worst batch — the padding waste the paged cache removes
    ml = max(max(len(r.prompt) for r in g) + max(r.n_steps for r in g)
             for g in groups)
    eng = ServeEngine(cfg, params, max_len=32 * math.ceil(ml / 32))

    eng.run(trace, batch=MAX_BATCH)                # warm the jit caches
    wall, results, stats = math.inf, None, None
    for _ in range(_REPEATS):
        t0 = time.perf_counter()
        results_i, stats_i = eng.run(trace, batch=MAX_BATCH)
        wall_i = time.perf_counter() - t0
        if wall_i < wall:
            wall, results, stats = wall_i, results_i, stats_i
    # every token of a group completes at group end: each requested
    # token's latency is its share of the group wall
    lats = []
    for gi in range(stats["batches"]):
        group = [r for r in results if r.admitted == gi]
        requested = sum(len(r.tokens) for r in group)
        gwall = max(r.emit_times[-1] for r in group) - group[0].admit_time
        lats += [gwall / max(1, requested)] * requested
    tokens = stats["tokens"]                       # requested tokens only
    lats = np.asarray(sorted(lats))
    return {
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "p50_token_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_token_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "batches": stats["batches"],
        "decode_steps": stats["decode_steps"],
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized trace (fewer requests)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-against", default=None, metavar="BASELINE",
                    help="fail on >threshold us_per_token regression vs "
                         "this baseline JSON (speed-normalised)")
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless continuous tokens/s >= this factor "
                         "of the synchronous baseline")
    ap.add_argument("--min-prefix-hit", type=float, default=None,
                    help="fail unless the shared-prefix scenario's "
                         "prefix-cache hit rate reaches this fraction")
    ap.add_argument("--min-prefix-speedup", type=float, default=None,
                    help="fail unless prefix caching beats the no-sharing "
                         "engine on shared-prefix per-token latency by "
                         "this factor")
    ap.add_argument("--max-shed-rate", type=float, default=None,
                    help="fail if the overload scenario sheds/times out "
                         "more than this fraction of requests")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import get_trace

    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_requests = args.requests or (16 if args.small else 48)
    trace = get_trace("base")(n_requests, cfg.vocab_size, seed=args.seed)

    sync = run_sync(cfg, params, trace)
    cont = run_continuous(cfg, params, trace)
    speedup = round(cont["tokens_per_s"] / sync["tokens_per_s"], 3)
    cont["speedup_vs_sync"] = speedup

    n_shared = max(6, n_requests // 2)
    shared = get_trace("shared_prefix")(n_shared, cfg.vocab_size,
                                        seed=args.seed)
    # page=128 (not the planner's 384 pick at this cap): the 256-token
    # system prompt must span whole pages or nothing hashes into the
    # prefix index and the cached run degenerates to the nocache one
    sp_cached = run_continuous(cfg, params, shared, max_len=SP_MAX_LEN,
                               max_batch=4, page=128)
    sp_nocache = run_continuous(cfg, params, shared, max_len=SP_MAX_LEN,
                                max_batch=4, page=128, prefix_cache=False)
    sp_speedup = round(sp_nocache["wall_s"] / sp_cached["wall_s"], 3)
    sp_cached["speedup_vs_nocache"] = sp_speedup

    n_long = max(6, n_requests // 2)
    longp = get_trace("long_prompt")(n_long, cfg.vocab_size, seed=args.seed)
    lp_chunked = run_continuous(cfg, params, longp, max_len=SP_MAX_LEN,
                                max_batch=4, page=128, prefill_chunk=32)
    lp_mono = run_continuous(cfg, params, longp, max_len=SP_MAX_LEN,
                             max_batch=4, page=128,
                             prefill_chunk=SP_MAX_LEN)

    # overload: bursts past capacity on a deliberately degraded engine —
    # a 256-token bucket whose requests grow past one 128-row page, on a
    # pool smaller than every slot's worst case (organic preemption),
    # with a bounded queue and deadline-aware shedding of doomed work
    from repro.serve import DeadlineAwareShed
    n_over = max(12, n_requests)
    over_trace = get_trace("overload")(n_over, cfg.vocab_size,
                                       seed=args.seed, max_len=OV_MAX_LEN)
    overload = run_continuous(cfg, params, over_trace, max_len=OV_MAX_LEN,
                              max_batch=MAX_BATCH, page=128,
                              n_blocks=MAX_BATCH + 2,
                              max_queue=MAX_BATCH,
                              admission=DeadlineAwareShed(slack=2))

    rows = []
    for name, r in (("sync", sync), ("continuous", cont),
                    ("shared_prefix_cached", sp_cached),
                    ("shared_prefix_nocache", sp_nocache),
                    ("longprompt_chunked", lp_chunked),
                    ("longprompt_monolithic", lp_mono),
                    ("overload", overload)):
        us = 1e6 * r["wall_s"] / r["tokens"]
        rows.append({"name": f"{name}_us_per_token",
                     "us_per_call": round(us, 3), "derived": r})
    # p99 under preemption as its own trend row: the regression guard
    # compares us_per_call, so tail degradation can't hide behind a
    # healthy mean when the scheduler is churning victims
    rows.append({"name": "overload_p99_token",
                 "us_per_call": round(overload["p99_token_ms"] * 1e3, 3)})
    payload = {
        "schema": "bench_serve/v3",
        "python": platform.python_version(),
        "config": {"arch": cfg.name, "max_len": MAX_LEN,
                   "max_batch": MAX_BATCH, "requests": n_requests,
                   "sp_max_len": SP_MAX_LEN, "sp_prefix": SP_PREFIX,
                   "shared_requests": n_shared, "long_requests": n_long,
                   "overload_requests": n_over, "ov_max_len": OV_MAX_LEN,
                   "small": args.small, "seed": args.seed},
        "results": {"serve": rows},
    }
    Path(args.out).write_text(json.dumps(payload, indent=1))
    print(f"[serve_bench] {n_requests} requests, "
          f"{sync['tokens']} tokens -> {args.out}")
    print(f"[serve_bench] sync       : {sync['tokens_per_s']:8.1f} tok/s  "
          f"p50 {sync['p50_token_ms']:.2f}ms  p99 {sync['p99_token_ms']:.2f}ms"
          f"  ({sync['decode_steps']} decode steps)")
    print(f"[serve_bench] continuous : {cont['tokens_per_s']:8.1f} tok/s  "
          f"p50 {cont['p50_token_ms']:.2f}ms  p99 {cont['p99_token_ms']:.2f}ms"
          f"  ({cont['decode_steps']} decode steps, "
          f"occupancy {cont['occupancy_mean']:.0%})")
    print(f"[serve_bench] speedup    : {speedup:.2f}x")
    print(f"[serve_bench] shared-prefix cached : "
          f"{sp_cached['tokens_per_s']:8.1f} tok/s  "
          f"ttft p50 {sp_cached['ttft_p50_ms']:.2f}ms  "
          f"hit rate {sp_cached['prefix_hit_rate']:.0%}")
    print(f"[serve_bench] shared-prefix nocache: "
          f"{sp_nocache['tokens_per_s']:8.1f} tok/s  "
          f"ttft p50 {sp_nocache['ttft_p50_ms']:.2f}ms")
    print(f"[serve_bench] prefix-cache speedup : {sp_speedup:.2f}x "
          "(per-token latency, shared-prefix trace)")
    print(f"[serve_bench] long-prompt chunked  : "
          f"p99 {lp_chunked['p99_token_ms']:.2f}ms  "
          f"ttft p99 {lp_chunked['ttft_p99_ms']:.2f}ms  "
          f"({lp_chunked['prefill_chunks']} chunks)")
    print(f"[serve_bench] long-prompt monolith : "
          f"p99 {lp_mono['p99_token_ms']:.2f}ms  "
          f"ttft p99 {lp_mono['ttft_p99_ms']:.2f}ms  "
          f"({lp_mono['prefill_chunks']} chunks)")
    print(f"[serve_bench] overload             : "
          f"p99 {overload['p99_token_ms']:.2f}ms  "
          f"shed rate {overload['shed_rate']:.0%}  "
          f"({overload['completed']}/{n_over} completed, "
          f"{overload['shed']} shed, {overload['timeouts']} timed out, "
          f"{overload['preemptions']} preemptions)")

    rc = 0
    if args.min_speedup is not None and speedup < args.min_speedup:
        print(f"[serve_bench] FAIL: speedup {speedup:.2f}x < required "
              f"{args.min_speedup:.2f}x")
        rc = 1
    if (args.min_prefix_hit is not None
            and sp_cached["prefix_hit_rate"] < args.min_prefix_hit):
        print(f"[serve_bench] FAIL: prefix hit rate "
              f"{sp_cached['prefix_hit_rate']:.2f} < required "
              f"{args.min_prefix_hit:.2f}")
        rc = 1
    if (args.min_prefix_speedup is not None
            and sp_speedup < args.min_prefix_speedup):
        print(f"[serve_bench] FAIL: prefix-cache speedup {sp_speedup:.2f}x "
              f"< required {args.min_prefix_speedup:.2f}x")
        rc = 1
    if (args.max_shed_rate is not None
            and overload["shed_rate"] > args.max_shed_rate):
        print(f"[serve_bench] FAIL: overload shed rate "
              f"{overload['shed_rate']:.2f} > allowed "
              f"{args.max_shed_rate:.2f}")
        rc = 1
    if args.check_against:
        from benchmarks.perf_smoke import check_against
        baseline = json.loads(Path(args.check_against).read_text())
        regressions, speed = check_against(payload, baseline,
                                           args.threshold)
        if regressions:
            for (bench, name), base, new in regressions:
                print(f"[serve_bench] REGRESSION {bench}/{name}: "
                      f"{base:.3f}us -> {new:.3f}us "
                      f"({new / base:.2f}x vs machine factor {speed:.2f}x)")
            rc = 1
        else:
            print(f"[serve_bench] trend guard OK "
                  f"(machine factor {speed:.2f}x vs {args.check_against})")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
