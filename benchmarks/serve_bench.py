"""Trace-replay serve benchmark: continuous batching vs the synchronous
bucket engine on a ragged (arrival x prompt-length x output-length) mix.

    PYTHONPATH=src python benchmarks/serve_bench.py [--small]
        [--out BENCH_serve.json] [--check-against BENCH_serve.json]
        [--threshold 0.25] [--min-speedup 1.5]

Both engines serve the SAME request trace on the same reduced model
config.  The synchronous baseline does what ``ServeEngine`` can do:
FIFO batches of ``max_batch``, every prompt right-padded to the batch
max, every request decoded for the batch-max step count — the padding
and convoy waste continuous batching exists to remove.  The continuous
engine slot-fills the ragged trace through one compiled decode step
over the block-paged KV cache.

Both replays are timed warm (the trace runs once to populate jit
caches, then the timed pass) so the number is steady-state serving
throughput, not compile time.  Reported per engine: tokens/s over
*requested* tokens, p50/p99 per-token latency, and (continuous only)
cache-block occupancy.  ``--check-against`` applies the same
speed-normalised >threshold regression gate as ``perf_smoke.py``;
``--min-speedup`` additionally fails the run if continuous batching
stops beating the synchronous baseline by the given factor.
"""

import argparse
import json
import math
import platform
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

MAX_LEN = 128
MAX_BATCH = 8


def make_trace(n_requests, vocab, seed=0):
    """Ragged request mix: mostly short chat turns, a heavy tail of long
    generations, Poisson-ish arrivals in scheduler ticks."""
    rng = np.random.default_rng(seed)
    reqs = []
    tick = 0
    for i in range(n_requests):
        tick += int(rng.poisson(1))
        s = int(rng.integers(6, 72))
        if rng.random() < 0.2:                     # long-tail generations
            n = int(rng.integers(48, 96))
        else:
            n = int(rng.integers(4, 16))
        n = min(n, MAX_LEN - s)
        prompt = rng.integers(0, vocab, (s,)).astype(np.int32)
        reqs.append((prompt, n, tick))
    return reqs


def run_continuous(cfg, params, trace):
    from repro.serve import PagedServeEngine, Request

    eng = PagedServeEngine(cfg, params, max_len=MAX_LEN,
                           max_batch=MAX_BATCH)
    reqs = [Request(prompt=p, n_steps=n, arrival=a) for p, n, a in trace]
    eng.run(reqs)                                  # warm the jit caches
    t0 = time.perf_counter()
    results, stats = eng.run(reqs)
    wall = time.perf_counter() - t0
    tokens = stats["tokens"]
    # per-token latency: gap to the previous emission of the same
    # request (first token: gap from replay start)
    lats = []
    for r in results:
        prev = t0
        for t in r.emit_times:
            lats.append(t - prev)
            prev = t
    lats = np.asarray(sorted(lats))
    return {
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "p50_token_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_token_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "occupancy_mean": round(stats["occupancy_mean"], 4),
        "occupancy_max": round(stats["occupancy_max"], 4),
        "decode_steps": stats["decode_steps"],
    }


def run_sync(cfg, params, trace):
    from repro.serve import ServeEngine

    batches = [trace[i:i + MAX_BATCH]
               for i in range(0, len(trace), MAX_BATCH)]
    # bucketed serving must hold padded-prompt + batch-max decode for its
    # worst batch — the padding waste the paged cache removes
    ml = max(max(len(p) for p, _, _ in b) + max(n for _, n, _ in b)
             for b in batches)
    eng = ServeEngine(cfg, params, max_len=32 * math.ceil(ml / 32))

    def replay(record):
        lats = []
        t0 = time.perf_counter()
        for batch in batches:
            s_max = max(len(p) for p, _, _ in batch)
            n_max = max(n for _, n, _ in batch)
            padded = np.stack([np.pad(p, (0, s_max - len(p)))
                               for p, _, _ in batch])
            eng.generate(padded, n_steps=n_max, temperature=0.0)
            if record:
                # every token of the batch completes at batch end: each
                # requested token's latency is its share of the batch wall
                done = time.perf_counter()
                requested = sum(n for _, n, _ in batch)
                lats += [(done - t0) / max(1, requested)] * requested
                t0 = done
        return lats

    replay(record=False)                           # warm the jit caches
    t0 = time.perf_counter()
    lats = replay(record=True)
    wall = time.perf_counter() - t0
    tokens = sum(n for _, n, _ in trace)           # requested tokens only
    lats = np.asarray(sorted(lats))
    return {
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "p50_token_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_token_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "batches": len(batches),
        "decode_steps": sum(max(n for _, n, _ in b) for b in batches),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized trace (fewer requests)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-against", default=None, metavar="BASELINE",
                    help="fail on >threshold us_per_token regression vs "
                         "this baseline JSON (speed-normalised)")
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless continuous tokens/s >= this factor "
                         "of the synchronous baseline")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_requests = args.requests or (16 if args.small else 48)
    trace = make_trace(n_requests, cfg.vocab_size, seed=args.seed)

    sync = run_sync(cfg, params, trace)
    cont = run_continuous(cfg, params, trace)
    speedup = round(cont["tokens_per_s"] / sync["tokens_per_s"], 3)
    cont["speedup_vs_sync"] = speedup

    rows = []
    for name, r in (("sync", sync), ("continuous", cont)):
        us = 1e6 * r["wall_s"] / r["tokens"]
        rows.append({"name": f"{name}_us_per_token",
                     "us_per_call": round(us, 3), "derived": r})
    payload = {
        "schema": "bench_serve/v1",
        "python": platform.python_version(),
        "config": {"arch": cfg.name, "max_len": MAX_LEN,
                   "max_batch": MAX_BATCH, "requests": n_requests,
                   "small": args.small, "seed": args.seed},
        "results": {"serve": rows},
    }
    Path(args.out).write_text(json.dumps(payload, indent=1))
    print(f"[serve_bench] {n_requests} requests, "
          f"{sync['tokens']} tokens -> {args.out}")
    print(f"[serve_bench] sync       : {sync['tokens_per_s']:8.1f} tok/s  "
          f"p50 {sync['p50_token_ms']:.2f}ms  p99 {sync['p99_token_ms']:.2f}ms"
          f"  ({sync['decode_steps']} decode steps)")
    print(f"[serve_bench] continuous : {cont['tokens_per_s']:8.1f} tok/s  "
          f"p50 {cont['p50_token_ms']:.2f}ms  p99 {cont['p99_token_ms']:.2f}ms"
          f"  ({cont['decode_steps']} decode steps, "
          f"occupancy {cont['occupancy_mean']:.0%})")
    print(f"[serve_bench] speedup    : {speedup:.2f}x")

    rc = 0
    if args.min_speedup is not None and speedup < args.min_speedup:
        print(f"[serve_bench] FAIL: speedup {speedup:.2f}x < required "
              f"{args.min_speedup:.2f}x")
        rc = 1
    if args.check_against:
        from benchmarks.perf_smoke import check_against
        baseline = json.loads(Path(args.check_against).read_text())
        regressions, speed = check_against(payload, baseline,
                                           args.threshold)
        if regressions:
            for (bench, name), base, new in regressions:
                print(f"[serve_bench] REGRESSION {bench}/{name}: "
                      f"{base:.3f}us -> {new:.3f}us "
                      f"({new / base:.2f}x vs machine factor {speed:.2f}x)")
            rc = 1
        else:
            print(f"[serve_bench] trend guard OK "
                  f"(machine factor {speed:.2f}x vs {args.check_against})")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
