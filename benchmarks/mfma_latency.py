"""Paper Tables II-V: MFMA latency, real-HW 'Expected' vs this simulator.

Each row times the Listing-1 microbenchmark through the event-driven
scoreboard for N in {2..5} and compares against the Expected column.
"""

from __future__ import annotations

import time

from repro.core.machine import get_machine
from repro.core.microbench import latency_table

EXPECTED = {
    "mi200": {"fp64_16x16x4fp64": 32, "fp32_4x4x1fp32": 8,
              "fp32_16x16x4fp32": 32, "fp32_16x16x16fp16": 32,
              "i32_16x16x16i8": 32, "fp64_4x4x4fp64": 16,
              "fp32_4x4x4fp16": 8},
    "mi300": {"fp64_16x16x4fp64": 32, "fp32_4x4x1fp32": 8,
              "fp32_16x16x4fp32": 32, "fp32_16x16x16fp16": 16,
              "fp64_4x4x4fp64": 16, "fp32_4x4x4fp16": 8},
}


def run(gpu: str):
    rows = []
    m = get_machine(gpu)
    t0 = time.perf_counter()
    table = latency_table(m)
    dt = (time.perf_counter() - t0) * 1e6
    n_meas = sum(len(v) for v in table.values())
    for name, per_n in table.items():
        exp = EXPECTED[gpu][name]
        for n, got in per_n.items():
            err = abs(got - exp) / exp * 100
            rows.append((f"table_{gpu}/{name}/N{n}", dt / n_meas,
                         f"cycles={got:g} expected={exp} err={err:.2f}%"))
    mean_err = sum(abs(per_n[n] - EXPECTED[gpu][k]) / EXPECTED[gpu][k]
                   for k, per_n in table.items() for n in per_n) \
        / n_meas * 100
    rows.append((f"table_{gpu}/mean_error", dt, f"{mean_err:.3f}% "
                 "(paper: 1.455% MI200 / 1.332% MI300 incl. KVM jitter)"))
    return rows


def main():
    rows = []
    for gpu in ("mi200", "mi300"):
        rows += run(gpu)
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
