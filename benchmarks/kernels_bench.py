"""Pallas kernel micro-bench: wall time per call (interpret mode on CPU —
correctness-shaped, not TPU-performance-shaped) + oracle agreement."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.plan import plan_for


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(device=None):
    """Blocks come from the spec-driven planner (the production path);
    each row names the plan it executed."""
    rows = []
    r = np.random.RandomState(0)
    a = jnp.asarray(r.randn(256, 256), jnp.bfloat16)
    b = jnp.asarray(r.randn(256, 256), jnp.bfloat16)
    c = jnp.asarray(r.randn(256, 256), jnp.float32)
    p = plan_for("mfma_gemm", {"M": 256, "N": 256, "K": 256},
                 dtype=a.dtype, device=device)
    us = _time(lambda *x: ops.mfma_gemm(*x, plan=p), a, b, c)
    err = float(jnp.max(jnp.abs(
        ops.mfma_gemm(a, b, c, plan=p) - ref.mfma_gemm_ref(a, b, c))))
    rows.append(("kernel/mfma_gemm_256", us,
                 f"max_err={err:.3f} {p.describe()}"))

    q = jnp.asarray(r.randn(1, 256, 4, 64), jnp.bfloat16)
    k = jnp.asarray(r.randn(1, 256, 2, 64), jnp.bfloat16)
    v = jnp.asarray(r.randn(1, 256, 2, 64), jnp.bfloat16)
    p = plan_for("flash_attention",
                 {"B": 1, "S": 256, "T": 256, "H": 4, "KV": 2, "hd": 64},
                 dtype=q.dtype, device=device)
    us = _time(lambda *x: ops.flash_attention(*x, plan=p), q, k, v)
    rows.append(("kernel/flash_attention_256", us, p.describe()))

    x = jnp.asarray(r.randn(1, 128, 2, 16), jnp.float32)
    dt_in = jnp.asarray(np.abs(r.randn(1, 128, 2)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.ones(2), jnp.float32)
    Bm = jnp.asarray(r.randn(1, 128, 1, 16), jnp.float32)
    p = plan_for("mamba2_ssd", {"B": 1, "S": 128, "nh": 2, "hd": 16,
                                "ds": 16}, dtype=x.dtype, device=device)
    us = _time(lambda *xs: ops.mamba2_ssd(*xs, plan=p), x, dt_in, A, Bm, Bm)
    rows.append(("kernel/mamba2_ssd_128", us, p.describe()))

    xe = jnp.asarray(r.randn(4, 128, 128), jnp.bfloat16)
    we = jnp.asarray(r.randn(4, 128, 128), jnp.bfloat16)
    p = plan_for("moe_gmm", {"E": 4, "C": 128, "K": 128, "N": 128},
                 dtype=xe.dtype, device=device)
    us = _time(lambda *xs: ops.moe_gmm(*xs, plan=p), xe, we)
    rows.append(("kernel/moe_gmm_4x128", us, p.describe()))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
