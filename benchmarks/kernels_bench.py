"""Pallas kernel micro-bench: wall time per call (interpret mode on CPU —
correctness-shaped, not TPU-performance-shaped) + oracle agreement."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    rows = []
    r = np.random.RandomState(0)
    a = jnp.asarray(r.randn(256, 256), jnp.bfloat16)
    b = jnp.asarray(r.randn(256, 256), jnp.bfloat16)
    c = jnp.asarray(r.randn(256, 256), jnp.float32)
    us = _time(lambda *x: ops.mfma_gemm(*x, block_m=128, block_n=128,
                                        block_k=128), a, b, c)
    err = float(jnp.max(jnp.abs(
        ops.mfma_gemm(a, b, c, block_m=128, block_n=128, block_k=128)
        - ref.mfma_gemm_ref(a, b, c))))
    rows.append(("kernel/mfma_gemm_256", us, f"max_err={err:.3f}"))

    q = jnp.asarray(r.randn(1, 256, 4, 64), jnp.bfloat16)
    k = jnp.asarray(r.randn(1, 256, 2, 64), jnp.bfloat16)
    v = jnp.asarray(r.randn(1, 256, 2, 64), jnp.bfloat16)
    us = _time(lambda *x: ops.flash_attention(*x, block_q=128, block_kv=128),
               q, k, v)
    rows.append(("kernel/flash_attention_256", us, "vs ref in tests"))

    x = jnp.asarray(r.randn(1, 128, 2, 16), jnp.float32)
    dt_in = jnp.asarray(np.abs(r.randn(1, 128, 2)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.ones(2), jnp.float32)
    Bm = jnp.asarray(r.randn(1, 128, 1, 16), jnp.float32)
    us = _time(lambda *xs: ops.mamba2_ssd(*xs, chunk=32), x, dt_in, A, Bm, Bm)
    rows.append(("kernel/mamba2_ssd_128", us, "chunk=32"))

    xe = jnp.asarray(r.randn(4, 64, 128), jnp.bfloat16)
    we = jnp.asarray(r.randn(4, 128, 64), jnp.bfloat16)
    us = _time(lambda *xs: ops.moe_gmm(*xs, block_m=64, block_n=64,
                                       block_k=128), xe, we)
    rows.append(("kernel/moe_gmm_4x64", us, "E=4"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
