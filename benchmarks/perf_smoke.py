"""CI perf-trajectory smoke: run the what-if and scoreboard benchmarks on
a small grid and write a ``BENCH_perf.json`` artifact, so every CI run
appends a comparable point to the performance history.

    PYTHONPATH=src python benchmarks/perf_smoke.py [--out BENCH_perf.json]
        [--check-against BENCH_baseline.json] [--threshold 0.25]

The artifact records each benchmark row (name, us_per_call, derived) plus
the parse-cache counters — a regression that re-parses modules per
estimator shows up as ``cache.parses`` climbing above the workload count.

``--check-against`` is the CI trend guard: rows are matched by
(benchmark, name) against the committed baseline and the run FAILS (exit
1) when any row regresses by more than ``--threshold`` (default 25%) —
the artifact-only era let a 10x pipeline slowdown merge unnoticed.
Because the baseline's wall-clock numbers come from a different machine
than the CI runner, comparison is *speed-normalised*: the median
new/baseline ratio across all matched rows is treated as the machine
speed factor, and a row only fails when it regresses >threshold beyond
that factor.  (A uniform all-rows slowdown therefore reads as "slower
machine" — absolute trends live in the uploaded artifact's history.)
Rows new since the baseline are reported but never fail; refresh the
baseline by copying a trusted run's ``--out`` file over
``BENCH_baseline.json``.
"""

import argparse
import dataclasses
import json
import platform
import sys
import time
from pathlib import Path

# runnable both as `python benchmarks/perf_smoke.py` and `-m benchmarks...`
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def check_against(payload: dict, baseline: dict, threshold: float):
    """(regressing rows, machine speed factor).

    Rows are matched by (benchmark, row name); rows absent from the
    baseline are skipped (new benchmarks must not fail the guard on
    their first run).  The speed factor is the median new/baseline
    ratio over matched rows — a uniformly faster/slower machine shifts
    every row together, so only rows regressing > threshold *beyond*
    that shift count.
    """
    base_rows = {(bench, r["name"]): r["us_per_call"]
                 for bench, rows in baseline.get("results", {}).items()
                 for r in rows}
    pairs = []
    for bench, rows in payload["results"].items():
        for r in rows:
            base = base_rows.get((bench, r["name"]))
            if base is None:
                print(f"[perf_smoke] note: {bench}/{r['name']} not in "
                      "baseline (new row, skipped)")
                continue
            pairs.append(((bench, r["name"]), base, r["us_per_call"]))
    if not pairs:
        return [], 1.0
    ratios = sorted(new / base for _, base, new in pairs)
    # clamped at 1.0: a slower machine relaxes the bar, but rows are
    # never penalised just because OTHER rows happened to run faster
    # (compile-dominated rows show large benign run-to-run variance)
    speed = max(ratios[len(ratios) // 2], 1.0)
    allowed = speed * (1.0 + threshold)
    return [(key, base, new) for key, base, new in pairs
            if new / base > allowed], speed


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_perf.json")
    ap.add_argument("--check-against", default=None, metavar="BASELINE",
                    help="fail on >threshold us_per_call regression vs "
                         "this baseline JSON")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    args = ap.parse_args()

    from benchmarks import scoreboard_bench, whatif_workloads
    from repro.perf import cache_stats, clear_cache

    clear_cache()
    results = {}
    wall = {}
    for name, mod in (("whatif_workloads", whatif_workloads),
                      ("scoreboard_bench", scoreboard_bench)):
        t0 = time.perf_counter()
        rows = mod.main(small=True)
        wall[name] = round(time.perf_counter() - t0, 3)
        results[name] = [
            {"name": n, "us_per_call": round(float(us), 3), "derived": d}
            for n, us, d in rows]

    payload = {
        "schema": "bench_perf/v1",
        "python": platform.python_version(),
        "wall_s": wall,
        "cache": dataclasses.asdict(cache_stats()),
        "results": results,
    }
    Path(args.out).write_text(json.dumps(payload, indent=1))
    n_rows = sum(len(v) for v in results.values())
    print(f"[perf_smoke] {n_rows} rows -> {args.out} "
          f"(cache parses={payload['cache']['parses']}, "
          f"hits={payload['cache']['hits']})")

    if args.check_against:
        baseline = json.loads(Path(args.check_against).read_text())
        regressions, speed = check_against(payload, baseline,
                                           args.threshold)
        if regressions:
            for (bench, name), base, new in regressions:
                print(f"[perf_smoke] REGRESSION {bench}/{name}: "
                      f"{base:.3f}us -> {new:.3f}us "
                      f"({new / base:.2f}x vs machine-speed factor "
                      f"{speed:.2f}x; >{args.threshold * 100:.0f}% over)")
            return 1
        print(f"[perf_smoke] trend guard OK: no row regressed "
              f">{args.threshold * 100:.0f}% beyond the {speed:.2f}x "
              f"machine-speed factor vs {args.check_against}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
