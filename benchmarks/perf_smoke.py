"""CI perf-trajectory smoke: run the what-if and scoreboard benchmarks on
a small grid and write a ``BENCH_perf.json`` artifact, so every CI run
appends a comparable point to the performance history.

    PYTHONPATH=src python benchmarks/perf_smoke.py [--out BENCH_perf.json]

The artifact records each benchmark row (name, us_per_call, derived) plus
the parse-cache counters — a regression that re-parses modules per
estimator shows up as ``cache.parses`` climbing above the workload count.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import sys
import time
from pathlib import Path

# runnable both as `python benchmarks/perf_smoke.py` and `-m benchmarks...`
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_perf.json")
    args = ap.parse_args()

    from benchmarks import scoreboard_bench, whatif_workloads
    from repro.perf import cache_stats, clear_cache

    clear_cache()
    results = {}
    wall = {}
    for name, mod in (("whatif_workloads", whatif_workloads),
                      ("scoreboard_bench", scoreboard_bench)):
        t0 = time.perf_counter()
        rows = mod.main(small=True)
        wall[name] = round(time.perf_counter() - t0, 3)
        results[name] = [
            {"name": n, "us_per_call": round(float(us), 3), "derived": d}
            for n, us, d in rows]

    payload = {
        "schema": "bench_perf/v1",
        "python": platform.python_version(),
        "wall_s": wall,
        "cache": dataclasses.asdict(cache_stats()),
        "results": results,
    }
    Path(args.out).write_text(json.dumps(payload, indent=1))
    n_rows = sum(len(v) for v in results.values())
    print(f"[perf_smoke] {n_rows} rows -> {args.out} "
          f"(cache parses={payload['cache']['parses']}, "
          f"hits={payload['cache']['hits']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
