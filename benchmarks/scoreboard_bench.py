"""Scoreboard-simulator throughput study (supports paper Section III's
occupancy discussion): MCE utilisation vs wavefront occupancy per CU, and
simulator wall-time per simulated instruction.

The tile-loop builder/simulator is the unified pipeline's home
(``repro.perf.engines``) — the same stream the ``ScoreboardEngine``
extrapolates whole workloads from."""

from __future__ import annotations

import sys
import time

from repro.core.machine import get_machine
from repro.perf.engines import simulate_gemm_cu


def main(small: bool = False):
    rows = []
    occupancies = (1, 4) if small else (1, 2, 4, 8, 16)
    for gpu in ("mi200", "mi300"):
        m = get_machine(gpu)
        for n_wf in occupancies:
            t0 = time.perf_counter()
            r = simulate_gemm_cu(m, "fp32_16x16x4fp32", tiles_per_wf=32,
                                 n_wf=n_wf)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"scoreboard/{gpu}/wf{n_wf}", dt / r["total_mfma"],
                f"util={r['mce_utilization']:.3f} "
                f"makespan={r['makespan']} analytic={r['analytic_cycles']:g}"))
    return rows


if __name__ == "__main__":
    for r in main(small="--small" in sys.argv):
        print(",".join(str(x) for x in r))
