"""Paper Table VI: --mfma-scale what-if (MI300, scale in {1, 2} + sweep)."""

from __future__ import annotations

import time

from repro.core.machine import get_machine
from repro.core.whatif import scale_sweep, scale_table


def main():
    rows = []
    m = get_machine("mi300")
    t0 = time.perf_counter()
    table = scale_table(m, scales=(1.0, 2.0))
    dt = (time.perf_counter() - t0) * 1e6 / max(1, 2 * len(table))
    for name, per_scale in table.items():
        rows.append((f"table6/{name}", dt,
                     f"scale1={per_scale[1.0]:g} scale2={per_scale[2.0]:g} "
                     f"ratio={per_scale[2.0] / per_scale[1.0]:.2f}"))
    # beyond-paper: fractional/extreme scales stay exact
    sweep = scale_sweep(m, "fp32_16x16x16fp16", (0.25, 0.5, 1.5, 4.0))
    for s, got in sweep.items():
        rows.append((f"table6_sweep/fp32_16x16x16fp16/x{s:g}", dt,
                     f"cycles={got:g} expected={round(16 * s)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
