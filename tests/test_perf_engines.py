"""Cost engines: shared Report schema on every registered device, exact
parity with the legacy estimators (hlo_bridge.predict, launch.roofline),
and scoreboard-vs-analytic agreement — including under overlay scenarios."""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.arch import IDENTITY, Overlay, get_device, list_devices
from repro.core import hlo_bridge as hb
from repro.core.machine import get_machine
from repro.launch.roofline import roofline_row
from repro.perf import (MfmaAnalyticEngine, RooflineEngine, Report,
                        ScoreboardEngine, parse_cached, predict)
from repro.perf.hlo_ir import KernelGraph

ENGINES = {"roofline": RooflineEngine, "mfma": MfmaAnalyticEngine,
           "scoreboard": ScoreboardEngine}

# the engine/legacy parity tests call deprecated hlo_bridge.predict on
# purpose — exact-equality is the contract that lets it be deleted later
pytestmark = pytest.mark.filterwarnings(
    "ignore:repro.core.hlo_bridge:DeprecationWarning")

# overlay scenarios the parity sweep covers (no table patches: those would
# bolt a cycle table onto MXU devices)
OVERLAYS = [IDENTITY, Overlay(mfma_scale=2.0),
            Overlay(mfma_scale=0.5, clock_scale=1.2)]


@pytest.fixture(scope="module")
def gemm_txt():
    a = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
    return jax.jit(lambda x, y: x @ y).lower(a, a).compile().as_text()


@pytest.fixture(scope="module")
def mlp_txt():
    """Two dots + elementwise: a (loop-free) multi-op dry-run fixture."""
    a = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    w1 = jax.ShapeDtypeStruct((128, 256), jnp.bfloat16)
    w2 = jax.ShapeDtypeStruct((256, 32), jnp.bfloat16)

    def fn(x, u, v):
        return jax.nn.gelu(x @ u) @ v

    return jax.jit(fn).lower(a, w1, w2).compile().as_text()


# ---------------------------------------------------------------------------
# Shared schema on EVERY registered device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_name", list(ENGINES))
@pytest.mark.parametrize("device", list_devices())
def test_every_engine_every_device_shared_schema(engine_name, device,
                                                 gemm_txt):
    rep = predict(gemm_txt, device=device, engine=engine_name)
    assert isinstance(rep, Report)
    assert rep.engine == engine_name
    assert rep.device == device
    assert rep.scenario == "baseline"
    assert rep.total_time_s > 0 and math.isfinite(rep.total_time_s)
    assert rep.bound in ("compute", "memory", "collective", "matrix")
    assert 0.0 <= rep.utilization <= 1.0 + 1e-9
    assert rep.per_op and all(o.time_s >= 0 for o in rep.per_op)
    assert rep.as_dict()["engine"] == engine_name  # JSON-able


# ---------------------------------------------------------------------------
# Exact parity: MfmaAnalyticEngine vs legacy hlo_bridge.predict
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlay", OVERLAYS, ids=lambda o: o.describe())
@pytest.mark.parametrize("device", list_devices())
def test_mfma_engine_matches_legacy_predict(device, overlay, gemm_txt,
                                            mlp_txt):
    for txt in (gemm_txt, mlp_txt):
        machine = get_machine(device, overlay=overlay)
        legacy = hb.predict(machine, txt)
        rep = predict(txt, device=machine, engine="mfma")
        assert rep.total_time_s == legacy.mce_time_s          # exact
        assert rep.metrics["mce_cycles"] == legacy.mce_cycles
        assert rep.metrics["total_mfma"] == legacy.total_mfma
        assert rep.metrics["instr_mix"] == legacy.instr_mix
        assert rep.metrics["matrix_flops"] == legacy.matrix_flops


def test_mfma_engine_loop_aware_counts():
    """On a scanned module the engine uses exact per-dot trip counts —
    equivalent to legacy predict renormalised by loop-aware flops."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)

    def fn(x):
        def body(h, _):
            return (h @ x).astype(h.dtype), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    txt = jax.jit(fn).lower(a).compile().as_text()
    graph = parse_cached(txt)
    machine = get_machine("mi300")
    legacy = hb.predict(machine, txt, cost_flops=graph.flops)
    rep = predict(graph, device=machine, engine="mfma")
    assert rep.total_time_s == pytest.approx(legacy.mce_time_s)
    assert rep.metrics["total_mfma"] == legacy.total_mfma


# ---------------------------------------------------------------------------
# Exact parity: RooflineEngine vs legacy launch.roofline row math
# ---------------------------------------------------------------------------

def _rec(f=1.2e12, b=3.4e9, c=5.6e8, flash=1.1e8):
    return {"arch": "qwen2-7b", "shape": "train_4k", "mesh": "16x16",
            "n_devices": 256, "n_params": int(7e9),
            "hlo": {"flops_per_device": f, "bytes_per_device": b,
                    "collective_wire_bytes": c, "flash_block_bytes": flash,
                    "collectives": {}},
            "memory": {"total_bytes_per_device": 8 * 2**30}}


@pytest.mark.parametrize("device", list_devices())
def test_roofline_engine_matches_legacy_row(device):
    rec = _rec()
    spec = get_device(device)
    row = roofline_row(rec, spec)
    hlo = rec["hlo"]
    g = KernelGraph.from_totals(
        flops=hlo["flops_per_device"], bytes_accessed=hlo["bytes_per_device"],
        collective_wire=hlo["collective_wire_bytes"],
        flash_block_bytes=hlo["flash_block_bytes"])
    rep = RooflineEngine().estimate(g, spec)
    assert rep.compute_time_s == row["compute_t"]
    assert rep.memory_time_s == row["memory_t"]
    assert rep.collective_time_s == row["collective_t"]
    assert rep.bound == row["dominant"]
    # the legacy hand-math for the kernel-adjusted memory term
    assert rep.memory_time_s == pytest.approx(
        (hlo["bytes_per_device"] - hlo["flash_block_bytes"])
        / spec.memory.hbm_bw)
    xla = RooflineEngine(kernel_adjusted=False).estimate(g, spec)
    assert xla.memory_time_s == row["memory_t_xla"]


@pytest.mark.parametrize("overlay", OVERLAYS[1:], ids=lambda o: o.describe())
def test_roofline_engine_overlay_scenarios(overlay):
    """Under an overlay the engine matches the legacy row computed on the
    overlay-transformed spec (plus the engine-level mfma_scale term)."""
    rec = _rec()
    spec = get_device("tpu_v5e")
    machine = get_machine("tpu_v5e", overlay=overlay)
    rep = predict(KernelGraph.from_totals(
        flops=rec["hlo"]["flops_per_device"],
        bytes_accessed=rec["hlo"]["bytes_per_device"],
        collective_wire=rec["hlo"]["collective_wire_bytes"],
        flash_block_bytes=rec["hlo"]["flash_block_bytes"]),
        device=machine, engine="roofline")
    # legacy equivalent: apply the spec-level overlay knobs by hand...
    legacy_spec = overlay.apply(spec) if overlay.mfma_scale == 1.0 else \
        Overlay(clock_scale=overlay.clock_scale,
                mem_latency_scale=overlay.mem_latency_scale,
                bw_scale=overlay.bw_scale).apply(spec)
    row = roofline_row(rec, legacy_spec)
    # ...and divide the peak by the machine-level mfma_scale knob
    assert rep.compute_time_s == pytest.approx(
        row["compute_t"] * overlay.mfma_scale)
    assert rep.memory_time_s == pytest.approx(row["memory_t"])
    assert rep.collective_time_s == pytest.approx(row["collective_t"])


# ---------------------------------------------------------------------------
# Scoreboard engine: simulated vs analytic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("device", ["mi200", "mi300"])
def test_scoreboard_validates_analytic(device, gemm_txt):
    ana = predict(gemm_txt, device=device, engine="mfma")
    sim = predict(gemm_txt, device=device, engine="scoreboard")
    assert sim.metrics["simulated"] == 1.0
    assert sim.metrics["total_mfma"] == ana.metrics["total_mfma"]
    # measured throughput reaches the analytic bound within issue overhead
    assert ana.total_time_s <= sim.total_time_s <= 1.15 * ana.total_time_s
    assert sim.utilization >= 0.90


@pytest.mark.parametrize("device", ["tpu_v5e", "mi300"])
def test_mxu_utilization_bounded_under_scale_overlay(device, gemm_txt):
    """A faster-MCE scenario must not report >1 utilization: the MXU cost
    path scales pass time by mfma_scale, so the peak must scale too."""
    for scale in (0.25, 1.0, 4.0):
        rep = predict(gemm_txt, device=device, engine="mfma",
                      overlays=Overlay(mfma_scale=scale))
        assert 0.0 < rep.utilization <= 1.0 + 1e-9, (device, scale)


def test_scoreboard_mxu_fallback(gemm_txt):
    rep = predict(gemm_txt, device="tpu_v5e", engine="scoreboard")
    assert rep.engine == "scoreboard"
    assert rep.metrics["simulated"] == 0.0   # no instruction stream on MXU
    ana = predict(gemm_txt, device="tpu_v5e", engine="mfma")
    assert rep.total_time_s == ana.total_time_s


def test_scoreboard_scale_overlay_scales_time(gemm_txt):
    base = predict(gemm_txt, device="mi300", engine="scoreboard")
    x2 = predict(gemm_txt, device="mi300", engine="scoreboard",
                 overlays=Overlay(mfma_scale=2.0))
    assert x2.total_time_s == pytest.approx(2 * base.total_time_s, rel=0.05)


# ---------------------------------------------------------------------------
# Custom engines plug into the same pipeline
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Report.plan: predicted tiles == the tiles the kernel layer executes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_name", list(ENGINES))
@pytest.mark.parametrize("device", list_devices())
def test_report_plan_matches_kernel_planner(engine_name, device, gemm_txt):
    """Every engine reports the SAME TilePlan plan_for derives for the
    module's dominant dot — the cross-check hook between prediction and
    execution."""
    from repro.kernels.plan import plan_for
    rep = predict(gemm_txt, device=device, engine=engine_name)
    expected = plan_for("mfma_gemm", {"M": 256, "N": 256, "K": 256},
                        dtype="bf16", device=device, pad=True)
    assert rep.plan is not None
    for k, v in expected.blocks.items():
        assert rep.plan[k] == v, (engine_name, device, rep.plan)
    assert rep.plan["device"] == device
    # and the ops layer would execute exactly these tiles
    assert expected.kwargs() == {k: rep.plan[k] for k in expected.blocks}


def test_scoreboard_int8_dot_plans_and_costs():
    """Integer-dtype dots (s8 -> i32_16x16x16i8 on mi200) must plan via
    the shared HLO byte table instead of crashing the scoreboard engine
    (regression: plan._itemsize once lacked the s8/u8 names)."""
    from repro.perf.hlo_ir import KernelOp
    op = KernelOp(kind="dot", opcode="dot", dtype="s8",
                  batch=1, m=128, n=128, k=128)
    g = KernelGraph(ops=[op], flops=float(op.flops),
                    bytes_accessed=3 * 128 * 128, key="s8-gemm")
    rep = predict(g, device="mi200", engine="scoreboard")
    assert rep.total_time_s > 0
    assert rep.plan is not None and rep.plan["dtype"] == "s8"


def test_scoreboard_degrades_on_unplannable_device(gemm_txt):
    """A what-if device whose fast memory can't hold one aligned tile set
    must still produce a Report (plan column empty), like the other
    engines — not crash predict()."""
    from repro.core.machine import MachineModel
    tiny = get_device("mi200").derive("mi200_tiny_vmem", vmem_bytes=300 << 10)
    machine = MachineModel.from_spec(tiny)
    rep = predict(gemm_txt, device=machine, engine="scoreboard")
    assert rep.total_time_s > 0 and rep.metrics["simulated"] == 1.0
    assert rep.plan is None and rep.plan_summary() == "-"


def test_plan_for_dot_budget_failure_is_not_masked():
    """Only unknown dtypes fall back to bf16; a budget overflow must
    propagate instead of silently reporting tiles of another dtype
    (Report.plan exists to cross-check what would really execute)."""
    from repro.perf.engines import plan_for_dot
    from repro.perf.hlo_ir import KernelOp
    from repro.core.machine import MachineModel
    # budget 225 KiB: the minimal bf16 tile set (192 KiB) fits, f32
    # (256 KiB) does not — a silent bf16 fallback would mislabel the plan
    spec = get_device("mi200").derive("mi200_small_vmem",
                                      vmem_bytes=450 << 10)
    machine = MachineModel.from_spec(spec)
    f32_dot = KernelOp(kind="dot", opcode="dot", dtype="f32",
                       batch=1, m=256, n=256, k=256)
    with pytest.raises(ValueError, match="working-set budget"):
        plan_for_dot(machine, f32_dot)
    odd = KernelOp(kind="dot", opcode="dot", dtype="c64",
                   batch=1, m=256, n=256, k=256)
    assert plan_for_dot(machine, odd).dtype == "bf16"  # dtype fallback


def test_report_plan_none_for_totals_only_graph():
    g = KernelGraph.from_totals(flops=1e12, bytes_accessed=1e9,
                                collective_wire=0.0)
    rep = predict(g, device="mi300", engine="roofline")
    assert rep.plan is None
    assert rep.plan_summary() == "-"


def test_scoreboard_measures_the_reported_plan(gemm_txt):
    """The representative-tile stream is derived from the reported plan
    via the microbench path (identical TilePlan end to end)."""
    from repro.core.microbench import (measure_plan_throughput,
                                       plan_microops)
    from repro.core.machine import get_machine
    from repro.perf.engines import plan_for_dot
    from repro.perf import parse_cached

    machine = get_machine("mi300")
    graph = parse_cached(gemm_txt)
    (d, cnt), = graph.dot_pairs()
    plan = plan_for_dot(machine, d)
    rep = predict(graph, device="mi300", engine="scoreboard")
    assert {k: rep.plan[k] for k in plan.blocks} == dict(plan.blocks)
    meas = measure_plan_throughput(machine, "fp32_16x16x16fp16", plan)
    assert meas["tiles_per_wf"] >= 1
    assert meas["tiles_per_wf"] <= max(
        1, -(-plan_microops(plan, "fp32_16x16x16fp16")
             // machine.mce_per_cu))
    # measured throughput appears in the per-op detail with the tile
    assert any("tile " in op.detail for op in rep.per_op)


def test_register_custom_engine(gemm_txt):
    from repro.perf import register_engine
    from repro.perf.report import Report as R

    class FlopsPerByteEngine:
        name = "intensity"

        def estimate(self, graph, machine):
            return R(engine=self.name, device="any",
                     total_time_s=graph.flops / max(graph.bytes_accessed, 1),
                     bound="compute")

    register_engine("intensity", FlopsPerByteEngine)
    rep = predict(gemm_txt, device="mi300", engine="intensity")
    assert rep.engine == "intensity" and rep.total_time_s > 0
