import os

# Tests EXECUTE on CPU: keep the f32-upcast for bf16 dots inside while
# bodies (XLA:CPU DotThunk limitation).  The dry-run sets this to 0.
os.environ.setdefault("REPRO_CPU_F32_DOTS", "1")
# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (assignment requirement).  Multi-device tests
# spawn subprocesses with their own XLA_FLAGS.
