"""Scoreboard semantics (paper Section III) + hypothesis properties."""

import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro.core.machine import get_machine
from repro.core.program import Wavefront, Workload, mfma, s_memtime, v_alu
from repro.core.scoreboard import simulate, simulate_program

M200 = get_machine("mi200")
LAT = M200.mfma_cycles("fp32_16x16x16fp16")  # 32


def _chain(n, name="fp32_16x16x16fp16"):
    """n data-dependent MFMAs (D=C accumulate chain)."""
    return [mfma(name, d="d", a="a", b="b", c="d", tag=f"m{i}")
            for i in range(n)]


def _indep(n, name="fp32_16x16x16fp16"):
    return [mfma(name, d=f"d{i}", a=f"a{i}", b=f"b{i}", c=f"c{i}")
            for i in range(n)]


def test_no_intra_wf_pipelining_dependent():
    """Dependent MFMAs serialise at full latency."""
    res = simulate_program(M200, _chain(4))
    issues = [r.issue for r in res.records if r.opcode == "mfma"]
    assert [b - a for a, b in zip(issues, issues[1:])] == [LAT] * 3


def test_no_intra_wf_pipelining_independent():
    """Even INDEPENDENT MFMAs on one SIMD can't overlap in the MCE: the
    NRDY_MATRIX_CORE counter drains first (no multi-stage pipelining)."""
    res = simulate_program(M200, _indep(4))
    issues = [r.issue for r in res.records if r.opcode == "mfma"]
    assert [b - a for a, b in zip(issues, issues[1:])] == [LAT] * 3


def test_cross_simd_parallelism():
    """WFs on different SIMD units use different MCEs concurrently."""
    wfs = [Wavefront(i, _indep(4), cu=0, simd=i) for i in range(4)]
    res = simulate(M200, Workload(wfs))
    solo = simulate(M200, Workload([Wavefront(0, _indep(4), cu=0, simd=0)]))
    assert res.makespan == solo.makespan  # 4 SIMDs: perfect overlap


def test_same_simd_wfs_serialise():
    """Two WFs on the same SIMD contend for its single MCE."""
    wfs = [Wavefront(i, _indep(2), cu=0, simd=0) for i in range(2)]
    res = simulate(M200, Workload(wfs))
    assert res.makespan >= 4 * LAT
    assert res.stall_cycles.get("nrdy_matrix_core", 0) > 0


def test_independent_valu_overlaps_mce():
    """Non-MCE work without data deps proceeds while the MCE is busy."""
    prog = [mfma("fp32_16x16x16fp16", d="d", a="a", b="b", c="c"),
            v_alu("x", "y"),
            v_alu("z", "x")]
    res = simulate_program(M200, prog)
    mf, va1, va2 = res.records
    assert va1.issue < mf.complete  # VALU issued under MCE shadow
    assert va2.issue < mf.complete


def test_dependent_valu_stalls_on_mfma():
    prog = [mfma("fp32_16x16x16fp16", d="d", a="a", b="b", c="c"),
            v_alu("x", "d")]  # reads MFMA result
    res = simulate_program(M200, prog)
    mf, va = res.records
    assert va.issue >= mf.complete


def test_memtime_samples_issue_cycle():
    res = simulate_program(M200, [s_memtime("t0", tag="t0"),
                                  s_memtime("t1", tag="t1")])
    # blocking: second probe issues exactly t_memtime later
    assert res.value("t1") - res.value("t0") == M200.t_memtime


@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 8),
       name=st.sampled_from(["fp64_16x16x4fp64", "fp32_4x4x1fp32",
                             "fp32_16x16x4fp32", "fp32_16x16x16fp16",
                             "fp64_4x4x4fp64", "fp32_4x4x4fp16"]))
def test_property_chain_time_linear(n, name):
    """T_total of a dependent chain == (N-1)*lat + t_memtime + t_inst
    (the closed form Eq. 1 inverts) for every instruction and N."""
    from repro.core.microbench import build_listing1, t_total
    lat = M200.mfma_cycles(name)
    res = simulate_program(M200, build_listing1(name, n))
    assert t_total(res) == (n - 1) * lat + M200.t_memtime + M200.t_inst


@settings(max_examples=40, deadline=None)
@given(n_wf=st.integers(1, 12), tiles=st.integers(1, 8))
def test_property_makespan_bounds(n_wf, tiles):
    """Makespan is bounded by work/TPUT below and serial execution above,
    and adding WFs never increases total makespan per unit work."""
    wfs = [Wavefront(i, _indep(tiles), cu=0, simd=i % M200.simd_per_cu)
           for i in range(n_wf)]
    res = simulate(M200, Workload(wfs))
    total = n_wf * tiles
    lower = -(-total // M200.simd_per_cu) * LAT  # ceil division
    upper = total * LAT
    assert lower <= res.makespan <= upper


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_deterministic(seed):
    """Identical workloads simulate identically (no KVM jitter)."""
    import random
    rng = random.Random(seed)
    n_wf = rng.randint(1, 6)
    wfs = [Wavefront(i, _indep(rng.randint(1, 5)), cu=0, simd=rng.randint(0, 3))
           for i in range(n_wf)]
    r1 = simulate(M200, Workload(wfs))
    r2 = simulate(M200, Workload(wfs))
    assert r1.makespan == r2.makespan
    assert r1.mce_busy == r2.mce_busy
