"""Content-hashed caching: a full engines x overlays x devices sweep
parses each HLO module exactly once; artifacts memoise on content."""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.arch import Overlay
from repro.perf import cache_stats, clear_cache, predict, sweep
from repro.perf.cache import load_artifact, parse_cached


@pytest.fixture(autouse=True)
def fresh_cache():
    clear_cache()
    yield
    clear_cache()


def _txt(n):
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    return jax.jit(lambda x, y: x @ y).lower(a, a).compile().as_text()


def test_three_engine_two_overlay_sweep_parses_once():
    """The acceptance sweep: 3 engines x 2 overlays x 2 devices over one
    module -> exactly ONE parse (legacy stack parsed once per estimator)."""
    txt = _txt(128)
    reports = sweep({"gemm": txt}, devices=("mi200", "mi300"),
                    engines=("roofline", "mfma", "scoreboard"),
                    overlays=(Overlay(), Overlay(mfma_scale=2.0)))
    assert len(reports) == 2 * 3 * 2
    assert cache_stats().parses == 1
    # asking again — any consumer, any engine — is a content-hash hit
    predict(txt, device="mi300x", engine="roofline")
    st = cache_stats()
    assert st.parses == 1 and st.hits == 1


def test_distinct_modules_parse_once_each():
    t1, t2 = _txt(128), _txt(192)
    sweep({"a": t1, "b": t2}, engines=("roofline", "mfma"),
          overlays=(Overlay(), Overlay(clock_scale=1.2)))
    predict(t1, device="mi300", engine="mfma")   # re-ask: cache hit
    st = cache_stats()
    assert st.parses == 2
    assert st.hits >= 1


def test_identical_text_shares_entry():
    t = _txt(128)
    parse_cached(t)
    parse_cached(str(t))   # different str object, same content hash
    st = cache_stats()
    assert st.parses == 1 and st.hits == 1


def test_tpu_correct_flag_is_part_of_key():
    t = _txt(128)
    parse_cached(t, tpu_correct=True)
    parse_cached(t, tpu_correct=False)
    assert cache_stats().parses == 2


def test_artifact_cache_content_hashed(tmp_path):
    rec = {"arch": "qwen2-7b", "shape": "train_4k", "n_devices": 4,
           "hlo": {"flops_per_device": 1e9, "bytes_per_device": 1e6,
                   "collective_wire_bytes": 0.0}}
    p = tmp_path / "cell.json"
    p.write_text(json.dumps(rec))
    a = load_artifact(p)
    b = load_artifact(p)
    assert a is b
    st = cache_stats()
    assert st.artifact_loads == 1 and st.artifact_hits == 1
    # rewriting the file invalidates by content, not by path
    rec["hlo"]["flops_per_device"] = 2e9
    p.write_text(json.dumps(rec))
    c = load_artifact(p)
    assert c["hlo"]["flops_per_device"] == 2e9
    assert cache_stats().artifact_loads == 2


def test_artifact_path_predicts_roofline(tmp_path):
    rec = {"arch": "qwen2-7b", "shape": "train_4k", "n_devices": 4,
           "hlo": {"flops_per_device": 1e12, "bytes_per_device": 1e9,
                   "collective_wire_bytes": 1e8}}
    p = tmp_path / "qwen2-7b_train_4k_single.json"
    p.write_text(json.dumps(rec))
    rep = predict(str(p), device="tpu_v5e", engine="roofline")
    assert rep.total_time_s > 0
    assert rep.workload == "qwen2-7b/train_4k"
    # pathlib.Path works too (os.PathLike coercion)
    rep2 = predict(p, device="tpu_v5e", engine="roofline")
    assert rep2.total_time_s == rep.total_time_s
