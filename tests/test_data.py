"""Synthetic data pipeline: determinism, learnable structure, prefetch."""

import numpy as np

from repro.data.pipeline import SyntheticLM, prefetch_to_device


def test_deterministic_by_step():
    d1 = SyntheticLM(512, batch=4, seq_len=32, seed=9)
    d2 = SyntheticLM(512, batch=4, seq_len=32, seed=9)
    b1, b2 = d1(17), d2(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_steps_differ():
    d = SyntheticLM(512, batch=4, seq_len=32, seed=9)
    assert not np.array_equal(d(0)["tokens"], d(1)["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(512, batch=2, seq_len=16, seed=0)
    b = d(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_correlation_structure():
    """With correlation=1.0 the next token is a fixed permutation of the
    current one — a model CAN learn this stream."""
    d = SyntheticLM(128, batch=8, seq_len=64, seed=3, correlation=1.0)
    b = d(0)
    toks, labs = b["tokens"], b["labels"]
    assert (labs == d._perm[toks]).mean() == 1.0


def test_tokens_in_range():
    d = SyntheticLM(100, batch=4, seq_len=32, seed=1)
    b = d(5)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_prefetch_yields_all():
    d = SyntheticLM(64, batch=2, seq_len=8, seed=0)
    src = (d(i) for i in range(5))
    got = list(prefetch_to_device(src, size=2))
    assert len(got) == 5
    np.testing.assert_array_equal(np.asarray(got[3]["tokens"]),
                                  d(3)["tokens"])
