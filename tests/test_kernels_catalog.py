"""Catalog-driven parity + planner contracts.

The interpret-mode numerical-parity sweep runs EVERY catalog kernel
against its ``kernels/ref.py`` oracle across fp32/bf16 with
planner-chosen tiles on EVERY registered device (mi200 -> tpu_v5p) —
the compute layer cannot silently rot for any (kernel, device, dtype)
cell again.  The planner contracts pin the acceptance criteria:
MXU-aligned, VMEM-budget-respecting tiles for every device, and the
scoreboard engine consuming the identical TilePlan the kernel executes.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.arch import get_device, list_devices
from repro.kernels import get_kernel, list_kernels, plan_for
from repro.kernels.plan import tile_align

RNG = np.random.RandomState(42)

DEVICES = list(list_devices())

#: Small but multi-tile shapes, MXU-aligned where the contract requires.
SHAPES = {
    "mfma_gemm": {"M": 128, "N": 128, "K": 256},
    "moe_gmm": {"E": 2, "C": 128, "K": 128, "N": 128},
    "flash_attention": {"B": 1, "S": 128, "T": 128, "H": 2, "KV": 1,
                        "hd": 64},
    "decode_attention": {"B": 1, "T": 256, "H": 4, "KV": 2, "hd": 32},
    "paged_decode_attention": {"B": 2, "T": 512, "H": 4, "KV": 2, "hd": 32,
                               "page": 128},
    "mamba2_ssd": {"B": 1, "S": 64, "nh": 2, "hd": 16, "ds": 16},
}

#: Big shapes for the alignment/budget contract (planner must tile, not
#: swallow, these).
BIG_SHAPES = {
    "mfma_gemm": {"M": 4096, "N": 4096, "K": 4096},
    "moe_gmm": {"E": 16, "C": 1024, "K": 4096, "N": 2048},
    "flash_attention": {"B": 8, "S": 4096, "T": 4096, "H": 32, "KV": 8,
                        "hd": 128},
    "decode_attention": {"B": 8, "T": 8192, "H": 32, "KV": 8, "hd": 128},
    "paged_decode_attention": {"B": 8, "T": 8192, "H": 32, "KV": 8,
                               "hd": 128, "page": 512},
    "mamba2_ssd": {"B": 8, "S": 4096, "nh": 32, "hd": 64, "ds": 128},
}


def _case(kernel: str, s, dt):
    """(op args, ref args) for one kernel; dtype applies to activations."""
    if kernel == "mfma_gemm":
        a = jnp.asarray(RNG.randn(s["M"], s["K"]), dt)
        b = jnp.asarray(RNG.randn(s["K"], s["N"]), dt)
        c = jnp.asarray(RNG.randn(s["M"], s["N"]), jnp.float32)
        return (a, b, c), (a, b, c)
    if kernel == "moe_gmm":
        x = jnp.asarray(RNG.randn(s["E"], s["C"], s["K"]), dt)
        w = jnp.asarray(RNG.randn(s["E"], s["K"], s["N"]), dt)
        return (x, w), (x, w)
    if kernel == "flash_attention":
        q = jnp.asarray(RNG.randn(s["B"], s["S"], s["H"], s["hd"]), dt)
        k = jnp.asarray(RNG.randn(s["B"], s["T"], s["KV"], s["hd"]), dt)
        v = jnp.asarray(RNG.randn(s["B"], s["T"], s["KV"], s["hd"]), dt)
        return (q, k, v), (q, k, v)
    if kernel == "decode_attention":
        q = jnp.asarray(RNG.randn(s["B"], s["H"], s["hd"]), dt)
        k = jnp.asarray(RNG.randn(s["B"], s["T"], s["KV"], s["hd"]), dt)
        v = jnp.asarray(RNG.randn(s["B"], s["T"], s["KV"], s["hd"]), dt)
        kv_len = jnp.int32(s["T"] - 63)
        return (q, k, v, kv_len), (q, k, v, kv_len)
    if kernel == "paged_decode_attention":
        page, B = s["page"], s["B"]
        nb = s["T"] // page
        P = B * nb + 1                       # + the reserved null block
        q = jnp.asarray(RNG.randn(B, s["H"], s["hd"]), dt)
        k_pool = jnp.asarray(RNG.randn(P, page, s["KV"], s["hd"]), dt)
        v_pool = jnp.asarray(RNG.randn(P, page, s["KV"], s["hd"]), dt)
        # shuffled tables: logical order != physical order, like a real
        # free-list allocation pattern
        perm = RNG.permutation(np.arange(1, P))
        tables = jnp.asarray(perm.reshape(B, nb), jnp.int32)
        # ragged per-request lengths incl. a partial last block
        kv_len = jnp.asarray(
            [s["T"] - 63 - 17 * (i % 3) for i in range(B)], jnp.int32)
        args = (q, k_pool, v_pool, tables, kv_len)
        return args, args
    if kernel == "mamba2_ssd":
        x = jnp.asarray(RNG.randn(s["B"], s["S"], s["nh"], s["hd"]) * 0.5, dt)
        dt_in = jnp.asarray(
            np.abs(RNG.randn(s["B"], s["S"], s["nh"])) * 0.4 + 0.05,
            jnp.float32)
        A = jnp.asarray(-np.abs(RNG.randn(s["nh"])) - 0.1, jnp.float32)
        Bm = jnp.asarray(RNG.randn(s["B"], s["S"], 1, s["ds"]) * 0.5,
                         jnp.float32)
        Cm = jnp.asarray(RNG.randn(s["B"], s["S"], 1, s["ds"]) * 0.5,
                         jnp.float32)
        return (x, dt_in, A, Bm, Cm), (x, dt_in, A, Bm, Cm)
    raise AssertionError(kernel)


def _tol(kernel, dt):
    if dt == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    loose = kernel in ("flash_attention", "decode_attention",
                       "paged_decode_attention", "mamba2_ssd")
    return dict(rtol=2e-3, atol=2e-3) if loose else dict(rtol=5e-4, atol=5e-4)


def test_catalog_is_complete():
    assert list(list_kernels()) == ["decode_attention", "flash_attention",
                                    "mamba2_ssd", "mfma_gemm", "moe_gmm",
                                    "paged_decode_attention"]


@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kernel", sorted(SHAPES))
def test_catalog_parity_every_device(kernel, dt, device):
    """Planner-chosen tiles on ``device``, interpret mode, vs the oracle."""
    entry = get_kernel(kernel)
    shapes = SHAPES[kernel]
    args, ref_args = _case(kernel, shapes, dt)
    plan = plan_for(kernel, shapes, dtype=dt, device=device)
    y = entry.op_fn(*args, plan=plan, interpret=True)
    yr = entry.ref_fn(*ref_args)
    if isinstance(y, tuple):
        for got, want in zip(y, yr):
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       **_tol(kernel, dt))
    else:
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32),
                                   **_tol(kernel, dt))


@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("kernel", sorted(BIG_SHAPES))
def test_plan_aligned_and_budgeted_every_device(kernel, device):
    """Acceptance criterion: MXU-aligned, VMEM-budget-respecting tiles for
    every device in the repro.arch registry."""
    spec = get_device(device)
    plan = plan_for(kernel, BIG_SHAPES[kernel], dtype="bfloat16",
                    device=device)
    align = tile_align(spec)
    for name, block in plan.blocks.items():
        if name == "chunk":
            assert block % 8 == 0, plan
        else:
            assert block % align == 0, plan
    assert plan.vmem_bytes <= plan.vmem_budget, plan
    assert plan.vmem_budget <= spec.vmem_bytes
    assert all(g >= 1 for g in plan.grid), plan


def test_plan_respects_tight_budget():
    """A small-VMEM derived device forces smaller tiles than its base."""
    base = get_device("tpu_v5e")
    tiny = base.derive("tpu_tiny_vmem", vmem_bytes=1 << 20)
    big = plan_for("mfma_gemm", BIG_SHAPES["mfma_gemm"], device=base)
    small = plan_for("mfma_gemm", BIG_SHAPES["mfma_gemm"], device=tiny)
    assert small.vmem_bytes <= (1 << 20) // 2
    assert sum(small.blocks.values()) < sum(big.blocks.values())


def test_plan_override_pins_block():
    p = plan_for("mfma_gemm", {"M": 1024, "N": 1024, "K": 1024},
                 block_m=128)
    assert p.blocks["block_m"] == 128
    with pytest.raises(ValueError, match="block_m"):
        plan_for("mfma_gemm", {"M": 1024, "N": 1024, "K": 1024}, block_m=96)


def test_plan_unknown_override_rejected():
    with pytest.raises(ValueError, match="unknown block override"):
        plan_for("decode_attention",
                 {"B": 1, "T": 256, "H": 4, "KV": 2, "hd": 32}, block_m=128)


# ---------------------------------------------------------------------------
# Ragged-tail planning: pad=True models padded execution; pad=False keeps
# the descriptive ValueError contract
# ---------------------------------------------------------------------------

#: Sub-128 and non-divisor shapes real model configs produce (odd seq
#: lengths, capacity-trimmed MoE groups, small smoke dims).
RAGGED_SHAPES = {
    "mfma_gemm": {"M": 100, "N": 60, "K": 200},
    "moe_gmm": {"E": 4, "C": 20, "K": 100, "N": 60},
    "flash_attention": {"B": 1, "S": 100, "T": 100, "H": 4, "KV": 2,
                        "hd": 32},
    "decode_attention": {"B": 2, "T": 100, "H": 4, "KV": 2, "hd": 32},
    "paged_decode_attention": {"B": 2, "T": 100, "H": 4, "KV": 2, "hd": 32},
    "mamba2_ssd": {"B": 1, "S": 52, "nh": 2, "hd": 16, "ds": 16},
}

#: dim name -> (block keyword tiling it, quantum class): "mxu" aligns to
#: tile_align(spec); "sublane" to 8.
_RAGGED_DIMS = {
    "mfma_gemm": {"M": ("block_m", "mxu"), "N": ("block_n", "mxu"),
                  "K": ("block_k", "mxu")},
    "moe_gmm": {"C": ("block_m", "mxu"), "K": ("block_k", "mxu"),
                "N": ("block_n", "mxu")},
    "flash_attention": {"S": ("block_q", "mxu"), "T": ("block_kv", "mxu")},
    "decode_attention": {"T": ("block_kv", "mxu")},
    "paged_decode_attention": {"T": ("block_kv", "mxu")},
    "mamba2_ssd": {"S": ("chunk", "sublane")},
}


@pytest.mark.parametrize("kernel", sorted(RAGGED_SHAPES))
def test_ragged_plan_pads_and_records_mask_metadata(kernel):
    """pad=True: every planned dim is rounded up to its quantum, blocks
    tile the PADDED sizes, and the plan records the padded geometry
    (``dims`` + ``padded=True``) the ops-layer pad/mask/slice path needs."""
    shapes = RAGGED_SHAPES[kernel]
    spec = get_device(DEVICES[0])
    plan = plan_for(kernel, shapes, dtype="float32", device=spec, pad=True)
    assert plan.padded
    align = tile_align(spec)
    for dim, (block_name, klass) in _RAGGED_DIMS[kernel].items():
        q = align if klass == "mxu" else 8
        padded = plan.dims[dim]
        assert padded >= shapes[dim]
        assert padded % q == 0, (dim, plan)
        assert padded - shapes[dim] < q                   # minimal padding
        assert padded % plan.blocks[block_name] == 0, (dim, plan)


@pytest.mark.parametrize("kernel", sorted(RAGGED_SHAPES))
def test_ragged_plan_without_pad_keeps_error_contract(kernel):
    """pad=False: the same shapes raise a descriptive ValueError naming
    an offending dim WITH its size (no silent clamping, no padding)."""
    shapes = RAGGED_SHAPES[kernel]
    named_dim = "|".join(f"{d}={shapes[d]}" for d in _RAGGED_DIMS[kernel])
    with pytest.raises(ValueError, match=named_dim) as err:
        plan_for(kernel, shapes, dtype="float32",
                 device=DEVICES[0], pad=False)
    assert "pad" in str(err.value)       # the message points at the fix


def test_aligned_plan_pad_true_is_identity():
    """pad=True on already-aligned shapes changes nothing but the flag."""
    aligned = plan_for("mfma_gemm", SHAPES["mfma_gemm"], dtype="float32")
    padded = plan_for("mfma_gemm", SHAPES["mfma_gemm"], dtype="float32",
                      pad=True)
    assert padded.blocks == aligned.blocks
    assert padded.dims == dict(SHAPES["mfma_gemm"])
    assert padded.grid == aligned.grid


# ---------------------------------------------------------------------------
# Per-shard planning: the local shapes shard_map hands the kernels.
# BIG_SHAPES partitioned through each kernel's KernelEntry.logical
# contract on a production-class (pod-less) 8 x 8 mesh slice must still
# plan on every registered device — this is exactly what
# dispatch.decide(sharded=True) does per shard.
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Duck-typed mesh (.shape only): planning needs no devices."""

    def __init__(self, shape):
        self.shape = shape


_SHARD_MESH = _FakeMesh({"data": 8, "model": 8})

#: mesh-eligible kernels (KernelEntry.logical is the source of truth).
_SHARDED_KERNELS = ["decode_attention", "flash_attention", "mamba2_ssd",
                    "moe_gmm"]


def _local_big(kernel):
    from repro.parallel.api import local_shapes
    shapes = dict(BIG_SHAPES[kernel])
    if kernel == "mamba2_ssd":
        shapes["G"] = 8                      # grouped B/C projections
    return shapes, local_shapes(shapes, get_kernel(kernel).logical,
                                _SHARD_MESH)


@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("kernel", _SHARDED_KERNELS)
def test_per_shard_plan_every_device(kernel, device):
    """Head-sharded attention (H 32->4, KV 8->1), expert-sharded GMM
    rows (E 16->2) and head-sharded SSD locals plan with MXU-aligned,
    VMEM-budgeted tiles on every device."""
    shapes, local = _local_big(kernel)
    assert local != shapes                   # something actually sharded
    assert all(shapes[d] % local[d] == 0 for d in shapes)
    spec = get_device(device)
    plan = plan_for(kernel, local, dtype="bfloat16", device=device)
    align = tile_align(spec)
    for name, block in plan.blocks.items():
        assert block % (8 if name == "chunk" else align) == 0, plan
    assert plan.vmem_bytes <= plan.vmem_budget <= spec.vmem_bytes
    assert all(g >= 1 for g in plan.grid), plan


@pytest.mark.parametrize("device", DEVICES)
def test_sequence_sharded_ssd_chunks_plan(device):
    """Context-parallel SSD: an S/16 local slice still chunks exactly
    (chunked SSD is exact at any chunk, so CP shards stay eligible)."""
    local = dict(BIG_SHAPES["mamba2_ssd"],
                 S=BIG_SHAPES["mamba2_ssd"]["S"] // 16)
    plan = plan_for("mamba2_ssd", local, dtype="bfloat16", device=device)
    chunk = plan.blocks["chunk"]
    assert chunk <= local["S"] and local["S"] % chunk == 0, plan


def test_shard_too_small_to_tile_keeps_fallback_contract():
    """A local shard below the alignment quantum (pad=False) or over the
    VMEM budget must surface as a planner ValueError — the raw material
    of dispatch's mesh-sharded fallback reason."""
    # 16 rows per expert shard vs the 128 quantum, strict contract
    with pytest.raises(ValueError, match="C=16"):
        plan_for("moe_gmm", {"E": 1, "C": 16, "K": 128, "N": 128},
                 dtype="bfloat16", device=DEVICES[0], pad=False)
    # even one minimal tile of this head-sharded shard busts 1 KiB VMEM
    tiny = get_device("tpu_v5e").derive("tpu_shard_vmem",
                                        vmem_bytes=1 << 10)
    with pytest.raises(ValueError):
        plan_for("flash_attention",
                 {"B": 1, "S": 4096, "T": 4096, "H": 4, "KV": 1,
                  "hd": 128}, dtype="bfloat16", device=tiny, pad=True)
