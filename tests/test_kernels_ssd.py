"""mamba2_ssd kernel + model SSD: chunked algebra vs sequential recurrence."""

import numpy as np
import pytest
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked, ssd_step

RNG = np.random.RandomState(11)


def _inputs(B, S, nh, hd, G, ds):
    x = jnp.asarray(RNG.randn(B, S, nh, hd) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, nh)) * 0.4 + 0.05, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.randn(nh)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, G, ds) * 0.5, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, G, ds) * 0.5, jnp.float32)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("B,S,nh,hd,G,ds,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 256, 8, 16, 1, 32, 64),
])
def test_ssd_kernel_sweep(B, S, nh, hd, G, ds, chunk):
    x, dt, A, Bm, Cm = _inputs(B, S, nh, hd, G, ds)
    y, h = ops.mamba2_ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)


def test_ssd_kernel_planner_path():
    """Planner-chosen chunk (no explicit block) matches the oracle."""
    x, dt, A, Bm, Cm = _inputs(1, 128, 2, 16, 1, 16)
    y, h = ops.mamba2_ssd(x, dt, A, Bm, Cm)
    yr, hr = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)


def test_ssd_non_divisible_chunk_raises():
    x, dt, A, Bm, Cm = _inputs(1, 64, 2, 16, 1, 16)
    with pytest.raises(ValueError, match="S=64"):
        ops.mamba2_ssd(x, dt, A, Bm, Cm, chunk=48)


def test_ssd_ragged_pad():
    """Non-sublane-multiple S pads with dt=0 identity steps: y matches
    and the final state is NOT polluted by the padded tail."""
    x, dt, A, Bm, Cm = _inputs(2, 52, 2, 16, 1, 16)
    y, h = ops.mamba2_ssd(x, dt, A, Bm, Cm, pad=True)
    assert y.shape == x.shape
    yr, hr = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)


def test_model_ssd_chunked_vs_sequential():
    """The model's XLA chunked scan == sequential oracle."""
    x, dt, A, Bm, Cm = _inputs(2, 96, 4, 16, 1, 24)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    yr, hr = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64]))
def test_property_chunk_invariance(chunk):
    """SSD output must not depend on the chunk size (pure algebra)."""
    x, dt, A, Bm, Cm = _inputs(1, 64, 2, 8, 1, 8)
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)


def test_ssd_step_matches_chunked_tail():
    """Decode recurrence step == one more token through the chunked path."""
    x, dt, A, Bm, Cm = _inputs(1, 65, 2, 8, 1, 8)
    y_all, h_all = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm)
    # run 64 then step the 65th
    _, h64 = ref.mamba2_ssd_ref(x[:, :64], dt[:, :64], A, Bm[:, :64],
                                Cm[:, :64])
    y65, h65 = ssd_step(x[:, 64], dt[:, 64], A, Bm[:, 64], Cm[:, 64], h64)
    np.testing.assert_allclose(np.asarray(y65), np.asarray(y_all[:, 64]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h65), np.asarray(h_all), rtol=2e-3,
                               atol=2e-3)


def test_ssd_state_decay():
    """With dt*A very negative the state forgets (exp decay -> 0)."""
    x, dt, A, Bm, Cm = _inputs(1, 32, 2, 8, 1, 8)
    big_dt = dt * 0 + 50.0
    y, h = ssd_chunked(x, big_dt, A, Bm, Cm, chunk=16)
    # state is dominated by the very last tokens; y must stay finite
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(h)).all()
