"""Loop-aware HLO analysis: trip counts, dot FLOPs, collective parsing."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import analyze
from repro.core.hlo_bridge import parse_collectives

# analyze() is the legacy view of perf.hlo_ir.parse_module and warns by
# design; this suite pins the legacy result shape on purpose
pytestmark = pytest.mark.filterwarnings(
    "ignore:repro.core.hlo_analysis:DeprecationWarning")


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_dot_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    stats = analyze(_compiled_text(lambda x, y: x @ y, a, b))
    assert stats.flops == 2 * 128 * 256 * 64


def test_scan_multiplies_flops():
    """A dot inside a 7-trip scan must count 7x (XLA's own cost_analysis
    counts it once — the reason hlo_analysis exists)."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(x):
        def body(h, _):
            return h @ x * 0.99, None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    stats = analyze(_compiled_text(fn, a))
    assert stats.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)


def test_nested_scan_multiplier():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def fn(x):
        def outer(h, _):
            def inner(g, _):
                return g @ x, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    stats = analyze(_compiled_text(fn, a))
    assert stats.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_bytes_positive_and_sane():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    stats = analyze(_compiled_text(lambda x: jnp.tanh(x) + 1.0, a))
    assert stats.bytes_accessed >= 2 * 256 * 256 * 4  # read + write


# --- collective parsing on handwritten post-SPMD HLO ---

_HLO_COLLECTIVES = """
HloModule test

ENTRY %main (p0: bf16[128,256]) -> bf16[128,256] {
  %p0 = bf16[128,256] parameter(0)
  %ag = bf16[128,2048] all-gather(%p0), replica_groups=[32,8]<=[256], dimensions={1}
  %cp = bf16[128,256] collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
  %ar = bf16[128,256] all-reduce(%cp), replica_groups=[32,8]<=[256], to_apply=%add
  ROOT %rs = bf16[128,256] reduce-scatter(%ar), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}, to_apply=%add
}
"""


def test_parse_collectives_kinds_and_wire_bytes():
    st = parse_collectives(_HLO_COLLECTIVES)
    assert set(st) == {"all-gather", "collective-permute", "all-reduce",
                       "reduce-scatter"}
    ag = st["all-gather"]
    nbytes = 128 * 2048 * 2
    assert ag["result_bytes"] == nbytes
    assert ag["wire_bytes"] == pytest.approx(nbytes * 7 / 8)
    ar = st["all-reduce"]
    assert ar["wire_bytes"] == pytest.approx(2 * 128 * 256 * 2 * 7 / 8)
    rs = st["reduce-scatter"]
    assert rs["wire_bytes"] == pytest.approx(128 * 256 * 2 * 7)
    cp = st["collective-permute"]
    assert cp["wire_bytes"] == 128 * 256 * 2


def test_analyze_collectives_in_module():
    st = analyze(_HLO_COLLECTIVES.replace("HloModule test", "HloModule t"))
    assert st.collective_wire_bytes > 0
    assert "all-gather" in st.collectives
