"""Loss-path equivalences: chunked CE == log_softmax reference; triangle
attention split inside the model; gradient-accumulation equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.models.model import forward
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

CFG = get_config("qwen2-7b").reduced()


def _batch(B=4, S=32, seed=0):
    rng = np.random.RandomState(seed)
    return {"tokens": jnp.asarray(rng.randint(0, CFG.vocab_size, (B, S))),
            "labels": jnp.asarray(rng.randint(0, CFG.vocab_size, (B, S)))}


def test_chunked_ce_matches_log_softmax():
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = _batch()
    loss, m = loss_fn(CFG, params, batch)
    logits, _ = forward(CFG, params, batch, mode="train")
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.mean(jnp.take_along_axis(logp, batch["labels"][..., None],
                                         axis=-1))
    np.testing.assert_allclose(float(m["ce"]), float(want), rtol=1e-4)


def test_ce_gradients_match_reference():
    params = init_params(CFG, jax.random.PRNGKey(1))
    batch = _batch(seed=2)

    def ref_loss(p):
        logits, aux = forward(CFG, p, batch, mode="train")
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.mean(jnp.take_along_axis(logp, batch["labels"][..., None],
                                           axis=-1))
        return ce + aux

    g1 = jax.grad(lambda p: loss_fn(CFG, p, batch)[0])(params)
    g2 = jax.grad(ref_loss)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_grad_accum_matches_full_batch():
    """microbatches=2 must produce the same update as one full batch
    (linearity of gradients; f32 accumulation)."""
    params = init_params(CFG, jax.random.PRNGKey(2))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1e9,
                        weight_decay=0.0)
    batch = _batch(B=4, seed=3)
    s1 = make_train_step(CFG, opt_cfg, microbatches=1)
    s2 = make_train_step(CFG, opt_cfg, microbatches=2)
    p1, o1, m1 = s1(params, init_opt_state(params), batch)
    p2, o2, m2 = s2(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # atol = one bf16 quantisation step around the update magnitude
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=2e-3)
