"""Continuous-batching serve engine + block-paged KV cache.

Pins the ISSUE acceptance contracts: admission backpressure when the
block pool is exhausted, retirement returning blocks to the free list,
and — the load-bearing one — interleaved prefill/decode producing
bit-identical greedy tokens vs the synchronous ``ServeEngine`` oracle
for ragged, staggered-arrival request mixes.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import (PagedKVCache, PagedServeEngine, Request,
                         ServeEngine, default_page_size)

CFG = get_config("qwen2-7b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
PAGE = 128


def _engine(**kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_batch", 2)
    kw.setdefault("page", PAGE)
    return PagedServeEngine(CFG, PARAMS, **kw)


def _requests(specs, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, CFG.vocab_size, (s,))
                    .astype(np.int32), n_steps=n, arrival=a)
            for s, n, a in specs]


# ---------------------------------------------------------------------------
# PagedKVCache: allocator + layout contracts
# ---------------------------------------------------------------------------

def test_cache_alloc_free_roundtrip():
    pc = PagedKVCache(CFG, n_blocks=5, page=PAGE)
    assert pc.capacity == 4 and pc.free_blocks == 4
    ids = pc.alloc(3)
    assert len(ids) == 3 and len(set(ids)) == 3
    assert all(1 <= b < 5 for b in ids)          # null block 0 never leaves
    assert pc.used_blocks == 3
    assert pc.alloc(2) is None                   # all-or-nothing
    assert pc.free_blocks == 1                   # failed alloc took nothing
    pc.free(ids)
    assert pc.free_blocks == 4 and pc.occupancy() == 0.0


def test_cache_free_validates():
    pc = PagedKVCache(CFG, n_blocks=3, page=PAGE)
    ids = pc.alloc(1)
    pc.free(ids)
    with pytest.raises(ValueError, match="double-freed"):
        pc.free(ids)
    with pytest.raises(ValueError, match="allocatable range"):
        pc.free([0])


def test_cache_pool_shapes_mirror_init_cache():
    pc = PagedKVCache(CFG, n_blocks=3, page=PAGE)
    from repro.models.blocks import schedule
    first_k, period, n_periods = schedule(CFG)
    assert len(pc.pools["layers0"]) == first_k
    assert len(pc.pools["layers"]) == period
    k = pc.pools["layers"][0]["k"]
    assert k.shape == (n_periods, 3, PAGE, CFG.n_kv_heads, CFG.hd)


def test_cache_rejects_non_attention_layers():
    mamba = get_config("mamba2-370m").reduced()
    with pytest.raises(NotImplementedError, match="only plain GQA"):
        PagedKVCache(mamba, n_blocks=3, page=PAGE)


def test_default_page_size_is_planner_block():
    # the pool's gather granularity IS the paged kernel's kv tile
    page = default_page_size(CFG)
    from repro.kernels import plan_for
    plan = plan_for("paged_decode_attention",
                    {"B": 1, "T": 512, "H": CFG.n_heads,
                     "KV": CFG.n_kv_heads, "hd": CFG.hd},
                    dtype=CFG.dtype)
    assert page == plan.blocks["block_kv"]


def test_cache_rejects_misaligned_page():
    with pytest.raises(ValueError):
        PagedKVCache(CFG, n_blocks=3, page=100)


# ---------------------------------------------------------------------------
# Scheduler: admission backpressure + eviction
# ---------------------------------------------------------------------------

def test_admission_waits_when_pool_full():
    """Two 1-block requests on a 2-allocatable-block pool run concurrently;
    the third must wait for a retirement before being admitted."""
    eng = _engine(max_batch=3, n_blocks=3)      # capacity 2 < 3 requests
    reqs = _requests([(8, 4, 0), (8, 6, 0), (8, 3, 0)])
    results, stats = eng.run(reqs)
    assert len(results) == 3
    assert results[0].admitted == 0 and results[1].admitted == 0
    # req2 could only enter once req0 (the shortest) retired
    assert results[2].admitted > results[0].finished - 1
    assert stats["occupancy_max"] <= 1.0
    assert all(r.tokens.shape == (reqs[i].n_steps,)
               for i, r in enumerate(results))


def test_retirement_returns_blocks_to_free_list():
    eng = _engine(max_batch=2, n_blocks=3)
    reqs = _requests([(5, 3, 0), (9, 5, 1), (7, 2, 2), (6, 4, 2)])
    results, stats = eng.run(reqs)
    assert len(results) == 4
    assert eng.cache.free_blocks == eng.cache.capacity   # all returned
    assert eng.cache.occupancy() == 0.0
    assert stats["tokens"] == sum(r.n_steps for r in reqs)


def test_request_larger_than_pool_raises():
    eng = _engine(max_len=192, max_batch=2, n_blocks=2)   # capacity 1 block
    # needs ceil((120+16)/128) = 2 blocks > capacity: can never be admitted
    with pytest.raises(ValueError, match="blocks"):
        eng.run(_requests([(120, 16, 0)]), temperature=0.0)


def test_request_overflowing_max_len_raises():
    eng = _engine()
    with pytest.raises(ValueError, match="max_len"):
        eng.run(_requests([(60, 8, 0)]))


# ---------------------------------------------------------------------------
# Parity: interleaved prefill/decode == the synchronous oracle, bitwise
# ---------------------------------------------------------------------------

def test_greedy_parity_vs_sync_engine():
    """Ragged prompts, staggered arrivals, a pool small enough to force
    wait-then-admit interleaving: every request's greedy stream must be
    bit-identical to a solo run on the synchronous engine."""
    specs = [(5, 6, 0), (17, 9, 0), (12, 4, 2), (30, 3, 3), (9, 8, 5)]
    reqs = _requests(specs)
    eng = _engine(max_batch=2, n_blocks=3)
    results, stats = eng.run(reqs)
    assert stats["requests"] == len(specs)
    sync = ServeEngine(CFG, PARAMS, max_len=64)
    for i, (r, req) in enumerate(zip(results, reqs)):
        ref = sync.generate(req.prompt[None], n_steps=req.n_steps).tokens[0]
        np.testing.assert_array_equal(
            ref, r.tokens, err_msg=f"request {i} diverged from the oracle")


def test_generate_parity_batch_api():
    """The (B, S) convenience wrapper matches ServeEngine.generate."""
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, CFG.vocab_size, (3, 12)).astype(np.int32)
    ref = ServeEngine(CFG, PARAMS, max_len=64).generate(
        prompts, n_steps=8).tokens
    got = _engine(max_batch=4).generate(prompts, n_steps=8)
    np.testing.assert_array_equal(ref, got)


def test_run_is_deterministic_across_reuse():
    """Re-serving the same trace on a dirty pool (stale residue, permuted
    free list) reproduces the first run's tokens exactly — results must
    never depend on which physical blocks a request lands in."""
    reqs = _requests([(5, 4, 0), (17, 6, 0), (9, 5, 1)])
    eng = _engine(max_batch=2, n_blocks=3)
    first, _ = eng.run(reqs)
    second, _ = eng.run(reqs)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_temperature_seed_control():
    reqs = _requests([(8, 6, 0), (11, 6, 0)])
    eng = _engine(max_batch=2)
    a, _ = eng.run(reqs, temperature=1.0, seed=0)
    b, _ = eng.run(reqs, temperature=1.0, seed=0)
    c, _ = eng.run(reqs, temperature=5.0, seed=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
    assert any(not np.array_equal(x.tokens, y.tokens)
               for x, y in zip(a, c))
