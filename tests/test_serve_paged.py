"""Continuous-batching serve engine + block-paged KV cache.

Pins the ISSUE acceptance contracts: admission backpressure when the
block pool is exhausted, retirement returning blocks to the free list,
and — the load-bearing one — interleaved prefill/decode producing
bit-identical greedy tokens vs the synchronous ``ServeEngine`` oracle
for ragged, staggered-arrival request mixes.

Prefix-cache era additions: refcounted acquire/release round-trips,
chained-hash prefix match/register/revive/evict, copy-on-write fork
leaving the shared block bit-identical, chunked continuation prefill
holding the same bitwise parity on long prompts, and shared-prefix
traces reusing blocks (nonzero hit rate) without perturbing tokens.
The long-prompt oracles run ``ServeEngine(prefill_pad=True)``: bitwise
parity needs every attention contraction at the same aligned KV length
(ragged exact-length prefill rounds its tail reduction differently).
"""

import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import (PagedKVCache, PagedServeEngine, Request,
                         RunStats, ServeEngine, default_page_size,
                         prefix_digests)

CFG = get_config("qwen2-7b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
PAGE = 128


def _engine(**kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_batch", 2)
    kw.setdefault("page", PAGE)
    return PagedServeEngine(CFG, PARAMS, **kw)


def _requests(specs, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, CFG.vocab_size, (s,))
                    .astype(np.int32), n_steps=n, arrival=a)
            for s, n, a in specs]


# ---------------------------------------------------------------------------
# PagedKVCache: allocator + layout contracts
# ---------------------------------------------------------------------------

def test_cache_alloc_free_roundtrip():
    pc = PagedKVCache(CFG, n_blocks=5, page=PAGE)
    assert pc.capacity == 4 and pc.free_blocks == 4
    ids = pc.alloc(3)
    assert len(ids) == 3 and len(set(ids)) == 3
    assert all(1 <= b < 5 for b in ids)          # null block 0 never leaves
    assert pc.used_blocks == 3
    assert pc.alloc(2) is None                   # all-or-nothing
    assert pc.free_blocks == 1                   # failed alloc took nothing
    pc.free(ids)
    assert pc.free_blocks == 4 and pc.occupancy() == 0.0


def test_cache_free_validates():
    pc = PagedKVCache(CFG, n_blocks=3, page=PAGE)
    ids = pc.alloc(1)
    pc.free(ids)
    with pytest.raises(ValueError, match="double-freed"):
        pc.free(ids)
    with pytest.raises(ValueError, match="allocatable range"):
        pc.free([0])


def test_cache_pool_shapes_mirror_init_cache():
    pc = PagedKVCache(CFG, n_blocks=3, page=PAGE)
    from repro.models.blocks import schedule
    first_k, period, n_periods = schedule(CFG)
    assert len(pc.pools["layers0"]) == first_k
    assert len(pc.pools["layers"]) == period
    k = pc.pools["layers"][0]["k"]
    assert k.shape == (n_periods, 3, PAGE, CFG.n_kv_heads, CFG.hd)


def test_cache_rejects_non_attention_layers():
    mamba = get_config("mamba2-370m").reduced()
    with pytest.raises(NotImplementedError, match="only plain GQA"):
        PagedKVCache(mamba, n_blocks=3, page=PAGE)


def test_default_page_size_is_planner_block():
    # the pool's gather granularity IS the paged kernel's kv tile
    page = default_page_size(CFG)
    from repro.kernels import plan_for
    plan = plan_for("paged_decode_attention",
                    {"B": 1, "T": 512, "H": CFG.n_heads,
                     "KV": CFG.n_kv_heads, "hd": CFG.hd},
                    dtype=CFG.dtype)
    assert page == plan.blocks["block_kv"]


def test_cache_rejects_misaligned_page():
    with pytest.raises(ValueError):
        PagedKVCache(CFG, n_blocks=3, page=100)


# ---------------------------------------------------------------------------
# Scheduler: admission backpressure + eviction
# ---------------------------------------------------------------------------

def test_admission_waits_when_pool_full():
    """Two 1-block requests on a 2-allocatable-block pool run concurrently;
    the third must wait for a retirement before being admitted."""
    eng = _engine(max_batch=3, n_blocks=3)      # capacity 2 < 3 requests
    reqs = _requests([(8, 4, 0), (8, 6, 0), (8, 3, 0)])
    results, stats = eng.run(reqs)
    assert len(results) == 3
    assert results[0].admitted == 0 and results[1].admitted == 0
    # req2 could only enter once req0 (the shortest) retired
    assert results[2].admitted > results[0].finished - 1
    assert stats["occupancy_max"] <= 1.0
    assert all(r.tokens.shape == (reqs[i].n_steps,)
               for i, r in enumerate(results))


def test_retirement_returns_blocks_to_free_list():
    eng = _engine(max_batch=2, n_blocks=3)
    reqs = _requests([(5, 3, 0), (9, 5, 1), (7, 2, 2), (6, 4, 2)])
    results, stats = eng.run(reqs)
    assert len(results) == 4
    assert eng.cache.free_blocks == eng.cache.capacity   # all returned
    assert eng.cache.occupancy() == 0.0
    assert stats["tokens"] == sum(r.n_steps for r in reqs)


def test_request_larger_than_pool_raises():
    eng = _engine(max_len=192, max_batch=2, n_blocks=2)   # capacity 1 block
    # needs ceil((120+16)/128) = 2 blocks > capacity: can never be admitted
    with pytest.raises(ValueError, match="blocks"):
        eng.run(_requests([(120, 16, 0)]), temperature=0.0)


def test_request_overflowing_max_len_raises():
    eng = _engine()
    with pytest.raises(ValueError, match="max_len"):
        eng.run(_requests([(60, 8, 0)]))


# ---------------------------------------------------------------------------
# Parity: interleaved prefill/decode == the synchronous oracle, bitwise
# ---------------------------------------------------------------------------

def test_greedy_parity_vs_sync_engine():
    """Ragged prompts, staggered arrivals, a pool small enough to force
    wait-then-admit interleaving: every request's greedy stream must be
    bit-identical to a solo run on the synchronous engine."""
    specs = [(5, 6, 0), (17, 9, 0), (12, 4, 2), (30, 3, 3), (9, 8, 5)]
    reqs = _requests(specs)
    eng = _engine(max_batch=2, n_blocks=3)
    results, stats = eng.run(reqs)
    assert stats["requests"] == len(specs)
    sync = ServeEngine(CFG, PARAMS, max_len=64)
    for i, (r, req) in enumerate(zip(results, reqs)):
        ref = sync.generate(req.prompt[None], n_steps=req.n_steps).tokens[0]
        np.testing.assert_array_equal(
            ref, r.tokens, err_msg=f"request {i} diverged from the oracle")


def test_generate_parity_batch_api():
    """The (B, S) convenience wrapper matches ServeEngine.generate."""
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, CFG.vocab_size, (3, 12)).astype(np.int32)
    ref = ServeEngine(CFG, PARAMS, max_len=64).generate(
        prompts, n_steps=8).tokens
    got = _engine(max_batch=4).generate(prompts, n_steps=8)
    np.testing.assert_array_equal(ref, got)


def test_run_is_deterministic_across_reuse():
    """Re-serving the same trace on a dirty pool (stale residue, permuted
    free list) reproduces the first run's tokens exactly — results must
    never depend on which physical blocks a request lands in."""
    reqs = _requests([(5, 4, 0), (17, 6, 0), (9, 5, 1)])
    eng = _engine(max_batch=2, n_blocks=3)
    first, _ = eng.run(reqs)
    second, _ = eng.run(reqs)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_temperature_seed_control():
    reqs = _requests([(8, 6, 0), (11, 6, 0)])
    eng = _engine(max_batch=2)
    a, _ = eng.run(reqs, temperature=1.0, seed=0)
    b, _ = eng.run(reqs, temperature=1.0, seed=0)
    c, _ = eng.run(reqs, temperature=5.0, seed=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
    assert any(not np.array_equal(x.tokens, y.tokens)
               for x, y in zip(a, c))


# ---------------------------------------------------------------------------
# Refcounted block sharing: acquire/release, prefix index, COW fork
# ---------------------------------------------------------------------------

def _toks(n, seed=11):
    return np.random.default_rng(seed).integers(
        0, CFG.vocab_size, (n,)).astype(np.int32)


def test_cache_refcount_acquire_release_roundtrip():
    pc = PagedKVCache(CFG, n_blocks=4, page=PAGE)
    ids = pc.alloc(2)
    assert all(pc.ref_count(b) == 1 for b in ids)
    pc.acquire(ids)                              # second holder
    assert all(pc.ref_count(b) == 2 for b in ids)
    pc.free(ids)                                 # first holder leaves
    assert all(pc.ref_count(b) == 1 for b in ids)
    assert pc.used_blocks == 2                   # still held, not free
    pc.free(ids)                                 # last holder leaves
    assert pc.free_blocks == pc.capacity
    with pytest.raises(ValueError, match="double-freed"):
        pc.free(ids)
    with pytest.raises(ValueError, match="not live or cached"):
        pc.acquire(ids)                          # unwritten blocks: alloc only


def test_cache_prefix_match_register_revive():
    pc = PagedKVCache(CFG, n_blocks=4, page=PAGE)
    toks = _toks(2 * PAGE + 40)
    ids = pc.alloc(2)
    pc.register_prefix(toks, ids)
    assert pc.match_prefix(toks) == ids          # both full pages indexed
    assert pc.match_prefix(toks[:PAGE + 5]) == ids[:1]
    other = _toks(2 * PAGE, seed=99)
    assert pc.match_prefix(other) == []
    pc.free(ids)                                 # refcount 0: parked, not lost
    assert pc.free_blocks == pc.capacity and pc.cached_blocks == 2
    assert pc.match_prefix(toks) == ids          # still matchable
    pc.acquire(ids)                              # revival: a cache hit
    assert pc.cached_blocks == 0
    assert all(pc.ref_count(b) == 1 for b in ids)
    pc.free(ids)


def test_cache_eviction_only_reclaims_ref0_blocks():
    pc = PagedKVCache(CFG, n_blocks=4, page=PAGE)   # capacity 3
    toks_live, toks_dead = _toks(PAGE, seed=1), _toks(PAGE, seed=2)
    live = pc.alloc(1)
    pc.register_prefix(toks_live, live)
    dead = pc.alloc(1)
    pc.register_prefix(toks_dead, dead)
    pc.free(dead)                                # parked at refcount 0
    ids = pc.alloc(2)                            # 1 fresh + must evict `dead`
    assert dead[0] in ids and live[0] not in ids
    assert pc.match_prefix(toks_dead) == []      # evicted => deregistered
    assert pc.match_prefix(toks_live) == live    # live entry untouched
    assert pc.alloc(1) is None                   # live block is not takeable
    pc.free(ids)
    pc.free(live)


def test_cache_fork_leaves_shared_block_bit_identical():
    pc = PagedKVCache(CFG, n_blocks=4, page=PAGE)
    b = pc.alloc(1)[0]

    def paint(val, blk):
        def pt(p):
            return (p.at[:, blk].set(val) if p.ndim == 5
                    else p.at[blk].set(val))
        pc.pools = jax.tree.map(pt, pc.pools)

    def rows(blk):
        return [np.asarray(p[:, blk] if p.ndim == 5 else p[blk])
                for p in jax.tree.leaves(pc.pools)]

    paint(7.0, b)
    before = rows(b)
    pc.acquire([b])                              # two holders share b
    dst = pc.fork(b)                             # holder 2 goes private
    assert dst != b
    assert pc.ref_count(b) == 1 and pc.ref_count(dst) == 1
    for a, c in zip(rows(dst), before):
        np.testing.assert_array_equal(a, c)      # copy is bitwise
    paint(9.0, dst)                              # the forker writes...
    for a, c in zip(rows(b), before):
        np.testing.assert_array_equal(a, c)      # ...shared block untouched
    loose = pc.alloc(1)[0]
    pc.free([loose])
    with pytest.raises(ValueError, match="no references"):
        pc.fork(loose)                           # freed block: nothing to share


def test_prefix_digests_chain_over_pages():
    toks = _toks(3 * PAGE)
    ds = prefix_digests(toks, PAGE)
    assert len(ds) == 3 and len(set(ds)) == 3
    mut = toks.copy()
    mut[5] += 1                                  # flip a token in page 0
    ds2 = prefix_digests(mut, PAGE)
    assert all(a != b for a, b in zip(ds, ds2))  # chain: all suffixes move
    assert prefix_digests(toks[:PAGE - 1], PAGE) == []


# ---------------------------------------------------------------------------
# Chunked continuation prefill + prefix sharing: long-prompt parity
# ---------------------------------------------------------------------------

def _long_engine(**kw):
    kw.setdefault("max_len", 384)
    kw.setdefault("max_batch", 2)
    kw.setdefault("page", PAGE)
    return PagedServeEngine(CFG, PARAMS, **kw)


def _oracle():
    return ServeEngine(CFG, PARAMS, max_len=384, prefill_pad=True)


def test_chunked_prefill_long_prompt_parity():
    """Prompts spanning several pages prefill in 32-token chunks that
    attend back through the block table; greedy streams must stay
    bit-identical to the aligned-prefill synchronous oracle."""
    specs = [(129, 5, 0), (279, 6, 0), (200, 4, 2)]
    reqs = _requests(specs)
    eng = _long_engine()
    results, stats = eng.run(reqs)
    assert stats["prefill_chunks"] >= sum(-(-s // 32) for s, _, _ in specs)
    sync = _oracle()
    for i, (r, req) in enumerate(zip(results, reqs)):
        ref = sync.generate(req.prompt[None], n_steps=req.n_steps).tokens[0]
        np.testing.assert_array_equal(
            ref, r.tokens, err_msg=f"request {i} diverged from the oracle")


def test_prefill_chunk_size_invariance():
    """The chunk size is a scheduling knob, not a numerics knob."""
    reqs = _requests([(279, 5, 0), (150, 4, 1)])
    base, _ = _long_engine(prefill_chunk=32).run(reqs)
    for chunk in (64, 128):
        got, _ = _long_engine(prefill_chunk=chunk).run(reqs)
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)


def _shared_prefix_reqs(n=4, prefix_len=256, tail=24, steps=5):
    rng = np.random.default_rng(21)
    prefix = rng.integers(0, CFG.vocab_size, (prefix_len,)).astype(np.int32)
    return [Request(prompt=np.concatenate(
                [prefix, rng.integers(0, CFG.vocab_size, (tail,))
                 .astype(np.int32)]),
                    n_steps=steps, arrival=i) for i in range(n)]


def test_shared_prefix_parity_and_hit_rate():
    """Requests sharing a 2-page system prefix: later arrivals take the
    prefix blocks by refcount bump (zero prefill compute), tokens stay
    bit-identical to solo oracle runs, and the hit rate is visible in
    both the stats payload and the per-request results."""
    reqs = _shared_prefix_reqs()
    eng = _long_engine()
    results, stats = eng.run(reqs)
    assert stats["prefix_blocks_reused"] > 0
    assert stats["prefix_blocks_needed"] == 2 * len(reqs)
    assert 0.0 < stats["prefix_hit_rate"] <= 1.0
    assert results[0].prefix_blocks == 0         # first writer pays
    assert any(r.prefix_blocks == 2 for r in results[1:])
    sync = _oracle()
    for i, (r, req) in enumerate(zip(results, reqs)):
        ref = sync.generate(req.prompt[None], n_steps=req.n_steps).tokens[0]
        np.testing.assert_array_equal(
            ref, r.tokens, err_msg=f"request {i} diverged from the oracle")


def test_prefix_cache_off_is_equivalent_but_never_shares():
    reqs = _shared_prefix_reqs(n=3)
    on, s_on = _long_engine().run(reqs)
    off, s_off = _long_engine(prefix_cache=False).run(reqs)
    assert s_off["prefix_blocks_reused"] == 0
    assert s_off["prefix_hit_rate"] == 0.0
    assert s_on["prefix_blocks_reused"] > 0
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_ttft_fields_and_prefill_accounting():
    reqs = _requests([(129, 4, 0)])
    results, stats = _long_engine().run(reqs)
    r = results[0]
    assert r.admit_time > 0.0
    assert r.emit_times[0] >= r.admit_time       # TTFT = first emit - admit
    assert stats["prefill_chunks"] == -(-129 // 32)


def test_oversized_request_fails_fast_at_validation():
    """A too-big request must raise up front — not deadlock at the queue
    head while runnable requests starve behind it."""
    eng = _engine(max_len=192, max_batch=2, n_blocks=2)   # capacity 1 block
    ok, huge = _requests([(8, 4, 0), (120, 16, 0)])
    with pytest.raises(ValueError, match="blocks"):
        eng.run([ok, huge])


# ---------------------------------------------------------------------------
# Typed serve API: shared run(trace) protocol, RunStats, tuple shim
# ---------------------------------------------------------------------------

def test_run_protocol_parity_across_engines():
    """Both engines serve the same typed trace through the shared
    ``run(trace)`` protocol; the synchronous engine in its batch=1
    oracle mode must match the paged engine's greedy streams token for
    token, and both hand back a RunStats."""
    reqs = _requests([(5, 6, 0), (17, 9, 1), (12, 4, 2)])
    paged_res, paged_stats = _engine(max_batch=2, n_blocks=3).run(reqs)
    sync_res, sync_stats = ServeEngine(CFG, PARAMS, max_len=64).run(reqs)
    assert isinstance(paged_stats, RunStats)
    assert isinstance(sync_stats, RunStats)
    assert sync_stats["tokens"] == paged_stats["tokens"]
    assert sync_stats["batches"] == len(reqs)     # solo oracle groups
    for i, (a, b) in enumerate(zip(paged_res, sync_res)):
        np.testing.assert_array_equal(
            a.tokens, b.tokens,
            err_msg=f"request {i}: run() protocol engines diverged")
        assert a.prompt_len == b.prompt_len
        assert len(b.emit_times) == len(b.tokens)


def test_sync_run_batched_matches_generate_slices():
    """batch>1 replay is the padded-bucket semantics run_sync always had:
    group max steps, per-request slice."""
    reqs = _requests([(6, 4, 0), (11, 7, 0), (9, 3, 1)])
    eng = ServeEngine(CFG, PARAMS, max_len=64)
    results, stats = eng.run(reqs, batch=3)
    assert stats["batches"] == 1 and stats["decode_steps"] == 7
    s_max = max(r.prompt.shape[0] for r in reqs)
    padded = np.stack([np.pad(r.prompt, (0, s_max - r.prompt.shape[0]))
                       for r in reqs])
    ref = eng.generate(padded, n_steps=7).tokens
    for i, r in enumerate(results):
        np.testing.assert_array_equal(ref[i, :reqs[i].n_steps], r.tokens)


def test_tuple_trace_shim_warns_once_and_matches_typed():
    """Legacy (prompt, n_steps, arrival) tuples still run — coerced with
    a one-shot DeprecationWarning — and produce the same tokens as the
    typed trace."""
    import repro.serve.api as api
    reqs = _requests([(6, 4, 0), (9, 3, 1)])
    tuples = [(r.prompt.copy(), r.n_steps, r.arrival) for r in reqs]
    eng = _engine(max_batch=2)
    typed, _ = eng.run(reqs)
    api._WARNED.discard("tuple-trace")            # arm the one-shot
    with pytest.warns(DeprecationWarning, match="repro.serve.Request"):
        shim, _ = eng.run(tuples)
    with warnings.catch_warnings():               # second coercion: silent
        warnings.simplefilter("error", DeprecationWarning)
        eng.run(tuples)
    for a, b in zip(typed, shim):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_run_rejects_garbage_trace_entries():
    eng = _engine()
    with pytest.raises(TypeError, match="Request"):
        eng.run(["not a request"])
    with pytest.raises(ValueError, match="n_steps"):
        eng.run([Request(prompt=np.zeros(4, np.int32), n_steps=0)])


def test_runstats_is_dict_compatible():
    _, stats = _engine().run(_requests([(6, 3, 0)]))
    assert stats["tokens"] == stats.tokens == 3
    assert {"ticks", "decode_steps", "prefix_hit_rate"} <= set(stats.keys())
    assert stats.get("not_a_field", 42) == 42
    with pytest.raises(KeyError):
        stats["not_a_field"]
    assert stats.as_dict()["requests"] == 1
