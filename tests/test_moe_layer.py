"""MoE router/dispatch invariants + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.moe import _slot_maps, capacity, init_moe, moe_apply, \
    router_topk

CFG = get_config("qwen3-moe-235b-a22b").reduced()


def test_capacity_formula():
    c = capacity(CFG, 64)
    m = CFG.moe
    assert c >= 64 * m.top_k / m.n_experts
    assert c % 4 == 0


def test_router_gates_normalised():
    w = init_moe(CFG, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, CFG.d_model),
                          jnp.bfloat16)
    gates, idx, aux = router_topk(CFG, w["router"], x)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (2, 32, CFG.moe.top_k)
    assert float(aux) > 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_slot_maps_consistent(seed):
    """For every kept assignment, src[slot] maps back to the assignment."""
    rng = np.random.RandomState(seed)
    G, A, E = 2, 48, CFG.moe.n_experts
    C = 8
    idx = jnp.asarray(rng.randint(0, E, (G, A)), jnp.int32)
    pos, keep, src, used = _slot_maps(CFG, idx, C)
    pos, keep, src, used = map(np.asarray, (pos, keep, src, used))
    for g in range(G):
        for a in range(A):
            if keep[g, a]:
                slot = idx[g, a] * C + pos[g, a]
                assert used[g, slot]
                assert src[g, slot] == a
    # positions within an expert are unique and dense from 0
    for g in range(G):
        for e in range(E):
            ps = sorted(pos[g, (np.asarray(idx[g]) == e) & keep[g]])
            assert ps == list(range(len(ps)))


def test_moe_uniform_experts_equals_dense():
    """If every expert has IDENTICAL weights and capacity is ample, the MoE
    output equals a single dense expert MLP (gates sum to 1)."""
    import dataclasses
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=8.0))
    w = init_moe(cfg, jax.random.PRNGKey(0))
    w = dict(w)
    for k in ("we_g", "we_i", "we_o"):
        w[k] = jnp.broadcast_to(w[k][:1], w[k].shape)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.bfloat16) * 0.5
    y, _ = moe_apply(cfg, w, x)
    # dense single-expert reference
    h = jax.nn.silu(x.astype(jnp.float32) @ w["we_g"][0].astype(jnp.float32)) \
        * (x.astype(jnp.float32) @ w["we_i"][0].astype(jnp.float32))
    y_ref = h @ w["we_o"][0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=6e-2,
                               atol=6e-2)


def test_moe_capacity_drops_tokens():
    """With capacity_factor tiny, some assignments are dropped and the
    output norm shrinks (never NaN)."""
    import dataclasses
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=0.05))
    w = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    y, aux = moe_apply(cfg, w, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert np.isfinite(float(aux))
