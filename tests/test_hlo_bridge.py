"""HLO bridge: dot parsing, MFMA instruction selection/counting, and
analytic-vs-simulated throughput agreement (the paper's model applied to
compiled JAX programs)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_bridge as hb
from repro.core.machine import get_machine

# the legacy surface under test is deprecated by design (repro.perf is
# the replacement); the parity suite exercises it on purpose
pytestmark = pytest.mark.filterwarnings(
    "ignore:repro.core.hlo_bridge:DeprecationWarning")


def _lowered_text(fn, *args):
    return jax.jit(fn).lower(*args).as_text()


def test_predict_deprecation_is_one_shot():
    import warnings

    a = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    txt = _lowered_text(lambda x, y: x @ y, a, a)
    hb._WARNED = False                            # arm the one-shot
    with pytest.warns(DeprecationWarning, match="repro.perf.predict"):
        hb.predict(get_machine("mi300"), txt)
    with warnings.catch_warnings():               # second call: silent
        warnings.simplefilter("error", DeprecationWarning)
        hb.predict(get_machine("mi300"), txt)
        # the still-supported explicit-dot-list path never warns
        hb.predict_dots(get_machine("mi300"),
                        [(d, 1.0) for d in hb.parse_dots(txt)])


def test_parse_dots_stablehlo():
    a = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((512, 128), jnp.bfloat16)
    txt = _lowered_text(lambda x, y: x @ y, a, b)
    dots = hb.parse_dots(txt)
    assert len(dots) == 1
    d = dots[0]
    assert (d.m, d.n, d.k, d.batch) == (256, 128, 512, 1)
    assert d.in_dtype == "bf16"
    assert d.flops == 2 * 256 * 128 * 512


def test_parse_dots_batched():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    txt = _lowered_text(lambda x, y: jnp.einsum("bmk,bkn->bmn", x, y), a, b)
    d = hb.parse_dots(txt)[0]
    assert (d.batch, d.m, d.n, d.k) == (4, 64, 16, 32)


def test_best_instr_prefers_dense_fast():
    m200 = get_machine("mi200")
    assert hb.best_instr(m200, "f16") == "fp32_16x16x16fp16"
    assert hb.best_instr(m200, "f64") in ("fp64_16x16x4fp64",
                                          "fp64_4x4x4fp64")
    m300 = get_machine("mi300")
    # i8 16x16x16 removed on MI300; the replacements tie on throughput
    # (512 MACs/cy) — the larger-tile tie-break may pick either
    assert hb.best_instr(m300, "s8") in ("i32_16x16x32i8", "i32_32x32x16i8")


def test_mfma_count_exact_tiles():
    d = hb.DotOp(in_dtype="f16", batch=1, m=64, n=64, k=64)
    # fp32_16x16x16fp16: 4x4x4 = 64 instructions
    assert hb.mfma_count(d, "fp32_16x16x16fp16") == 64


def test_mfma_count_ceil_partial_tiles():
    d = hb.DotOp(in_dtype="f16", batch=1, m=17, n=16, k=16)
    assert hb.mfma_count(d, "fp32_16x16x16fp16") == 2  # ceil(17/16)=2


def test_predict_gemm_cycles():
    """256x256x256 bf16 GEMM on MI300: known closed-form MCE-bound time."""
    m300 = get_machine("mi300")
    a = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
    txt = _lowered_text(lambda x, y: x @ y, a, a)
    pred = hb.predict(m300, txt)
    n_instr = 16 * 16 * 16  # (256/16)^3
    lat = m300.mfma_cycles("fp32_16x16x16bf16")
    expect_cycles = n_instr * lat / (m300.mce_per_cu * m300.cu_count)
    assert pred.total_mfma == n_instr
    assert pred.mce_cycles == pytest.approx(expect_cycles)


def test_predict_scale_linear():
    m300 = get_machine("mi300")
    a = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    txt = _lowered_text(lambda x, y: x @ y, a, a)
    t1 = hb.predict(m300, txt).mce_time_s
    t2 = hb.predict(m300.with_scale(2.0), txt).mce_time_s
    assert t2 == pytest.approx(2 * t1)


def test_tpu_analytic_path():
    tpu = get_machine("tpu_v5e")
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)
    txt = _lowered_text(lambda x, y: x @ y, a, a)
    pred = hb.predict(tpu, txt)
    # 8 passes of 8x8x8 128-tiles: 512 passes * 128 rows / 8 MXUs
    assert pred.total_mfma == 512
    assert pred.mce_cycles == pytest.approx(512 * 128 / 8)


def test_simulated_matches_analytic_throughput():
    """Event-driven CU simulation reaches the analytic MCE throughput the
    predict() model assumes (>= 95% utilisation with full WF occupancy)."""
    m200 = get_machine("mi200")
    res = hb.simulate_gemm_cu(m200, "fp32_16x16x16fp16", tiles_per_wf=16,
                              n_wf=8)
    assert res["makespan"] <= 1.10 * res["analytic_cycles"]
    assert res["mce_utilization"] >= 0.90
