"""Checkpoint save/restore: roundtrip, async, latest-step, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                       "b": jnp.zeros((16,), jnp.float32)},
            "step_count": jnp.int32(7),
            "nested": [jnp.ones((3,)), {"m": jnp.arange(5)}]}


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 10, t)
    restored, step = restore(tmp_path, t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step_and_gc(tmp_path):
    assert latest_step(tmp_path) is None
    ck = Checkpointer(tmp_path, every=2, keep=2)
    t = _tree()
    for s in range(1, 9):
        ck.maybe_save(s, t)
    ck.wait()
    assert latest_step(tmp_path) == 8
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert len(steps) <= 2  # gc kept only the last `keep`


def test_restore_into_abstract(tmp_path):
    """Restore accepts ShapeDtypeStructs as the 'like' tree (fresh boot)."""
    t = _tree()
    save(tmp_path, 3, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    restored, step = restore(tmp_path, like)
    np.testing.assert_array_equal(
        np.asarray(restored["layers"]["w"], np.float32),
        np.asarray(t["layers"]["w"], np.float32))


def test_restore_shape_mismatch_raises(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    bad = dict(t)
    bad["layers"] = {"w": jnp.zeros((9, 16), jnp.bfloat16),
                     "b": t["layers"]["b"]}
    with pytest.raises(ValueError):
        restore(tmp_path, bad)


def test_elastic_restore_resharding(tmp_path):
    """sharding_fn re-places leaves on the current (1-device) mesh —
    the elastic-restart path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    save(tmp_path, 5, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def sharding_fn(key, arr):
        return NamedSharding(mesh, P(*([None] * arr.ndim)))

    restored, _ = restore(tmp_path, t, sharding_fn=sharding_fn)
    w = restored["layers"]["w"]
    assert isinstance(w.sharding, NamedSharding)
    np.testing.assert_array_equal(np.asarray(w, np.float32),
                                  np.asarray(t["layers"]["w"], np.float32))
