"""Model <-> kernel parity: the ``use_pallas=`` execution path.

Every catalog-backed mixer (attention train + decode, chunked SSD, MoE
grouped GEMM) must produce the same output under ``use_pallas=True``
(interpret-mode Pallas kernels) as the XLA reference formulation, within
dtype tolerance — including ragged (non-128-multiple) shapes, which run
the kernel path via ``plan_for(..., pad=True)`` + the ops-layer
pad/mask/slice plumbing.  ``repro.kernels.dispatch`` decision records are
asserted so a silent fallback can never masquerade as parity; the
contract-mismatch cases (MLA's asymmetric head dims, sharded dispatch
without a mesh or a logical-axis contract) must fall back with a
descriptive reason and bit-identical reference output.  This is the
``models-pallas`` CI job; its mesh leg additionally runs
``test_sharding_pallas.py`` on 8 fake host devices.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import MLASpec, ModelConfig, MoESpec, SSMSpec

KEY = jax.random.PRNGKey(0)

_TOL = {"float32": dict(rtol=2e-3, atol=2e-3),
        "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _cfg(dtype="float32", **kw) -> ModelConfig:
    base = dict(name="pallas-parity", family="dense", n_layers=2,
                d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                vocab_size=512, head_dim=32, dtype=dtype)
    base.update(kw)
    return ModelConfig(**base)


def _pair(cfg):
    """(reference cfg, use_pallas cfg) sharing everything else."""
    return cfg, dataclasses.replace(cfg, use_pallas=True)


def _assert_kernel_used(kernel: str):
    dec = kdispatch.last_decisions().get(kernel)
    assert dec is not None, f"{kernel}: no dispatch decision recorded"
    assert dec.use_kernel, f"{kernel}: fell back ({dec.reason})"
    assert dec.plan is not None and dec.plan.kernel == kernel


def _close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_TOL[dtype])


# ---------------------------------------------------------------------------
# attention: train + decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,dtype", [(128, "float32"), (100, "float32"),
                                     (100, "bfloat16")])
def test_attn_train_parity(S, dtype):
    """S=100 is the ragged case: kernel runs via pad + kv_len mask."""
    cfg, cfgp = _pair(_cfg(dtype=dtype))
    w = attn.init_attn(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model),
                          jnp.float32).astype(x_dtype(cfg))
    pos = jnp.arange(S)
    kdispatch.reset_decisions()
    y_pal = attn.attn_train(cfgp, w, x, pos)
    _assert_kernel_used("flash_attention")
    y_ref = attn.attn_train(cfg, w, x, pos)
    _close(y_pal, y_ref, dtype)


def x_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@pytest.mark.parametrize("max_len", [128, 100])
def test_attn_decode_parity(max_len):
    """max_len=100 is the ragged KV cache: padded tail is kv_len-masked."""
    cfg, cfgp = _pair(_cfg())
    w = attn.init_attn(cfg, KEY)
    cache = attn.init_attn_cache(cfg, 2, max_len)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model),
                          jnp.float32)
    kdispatch.reset_decisions()
    y_pal, c_pal = attn.attn_decode(cfgp, w, x, cache, jnp.int32(37))
    _assert_kernel_used("decode_attention")
    y_ref, c_ref = attn.attn_decode(cfg, w, x, cache, jnp.int32(37))
    _close(y_pal, y_ref, "float32")
    np.testing.assert_array_equal(np.asarray(c_pal["k"]),
                                  np.asarray(c_ref["k"]))


def test_decode_kernel_ignores_stale_cache_tail():
    """Positions >= kv_len (unwritten cache garbage) must not leak in."""
    cfg, cfgp = _pair(_cfg())
    w = attn.init_attn(cfg, KEY)
    cache = attn.init_attn_cache(cfg, 1, 100)
    cache = {"k": cache["k"].at[:, 50:].set(1e4),
             "v": cache["v"].at[:, 50:].set(-1e4)}
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 1, cfg.d_model),
                          jnp.float32)
    y_pal, _ = attn.attn_decode(cfgp, w, x, cache, jnp.int32(20))
    y_ref, _ = attn.attn_decode(cfg, w, x, cache, jnp.int32(20))
    _close(y_pal, y_ref, "float32")


@pytest.mark.parametrize("M", [128, 48])
def test_cross_attention_parity(M):
    """Non-causal kernel path; M=48 exercises the ragged KV mask."""
    cfg, cfgp = _pair(_cfg())
    w = attn.init_cross(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 100, cfg.d_model),
                          jnp.float32)
    media = jax.random.normal(jax.random.PRNGKey(4), (1, M, cfg.d_model),
                              jnp.float32)
    kdispatch.reset_decisions()
    y_pal = attn.cross_train(cfgp, w, x, media)
    _assert_kernel_used("flash_attention")
    y_ref = attn.cross_train(cfg, w, x, media)
    _close(y_pal, y_ref, "float32")


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [64, 52])
def test_ssm_train_parity(S):
    """S=52 is the ragged case: dt=0 identity-step padding."""
    cfg = _cfg(family="ssm", d_model=64, d_ff=0,
               ssm=SSMSpec(d_state=16, head_dim=16, chunk=32))
    cfg, cfgp = _pair(cfg)
    w = ssm_mod.init_ssm(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, S, cfg.d_model),
                          jnp.float32)
    kdispatch.reset_decisions()
    y_pal = ssm_mod.ssm_train(cfgp, w, x)
    _assert_kernel_used("mamba2_ssd")
    y_ref = ssm_mod.ssm_train(cfg, w, x)
    _close(y_pal, y_ref, "float32")


def test_ssd_chunked_h0_falls_back():
    """A carried initial state is outside the kernel contract."""
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 64, 2, 16))
    dt = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (1, 64, 2))) + 0.05
    A = -jnp.ones((2,))
    Bm = jax.random.normal(jax.random.PRNGKey(9), (1, 64, 1, 16))
    Cm = jax.random.normal(jax.random.PRNGKey(10), (1, 64, 1, 16))
    h0 = jnp.ones((1, 2, 16, 16), jnp.float32)
    kdispatch.reset_decisions()
    y_pal, h_pal = ssm_mod.ssd_chunked(x, dt, A, Bm, Cm, 32, h0,
                                       use_pallas=True)
    dec = kdispatch.last_decisions()["mamba2_ssd"]
    assert not dec.use_kernel and "initial state" in dec.reason
    y_ref, h_ref = ssm_mod.ssd_chunked(x, dt, A, Bm, Cm, 32, h0)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(h_pal), np.asarray(h_ref))


# ---------------------------------------------------------------------------
# MoE grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,dtype", [(32, "float32"), (32, "bfloat16")])
def test_moe_apply_parity(S, dtype):
    """Capacity C=20 and d_ff_expert=64 are both ragged vs the 128
    quantum — the kernel path must pad, not raise or fall back."""
    cfg = _cfg(family="moe", dtype=dtype,
               moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=64))
    assert moe_mod.capacity(cfg, S) % 128 != 0      # genuinely ragged
    cfg, cfgp = _pair(cfg)
    w = moe_mod.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, S, cfg.d_model),
                          jnp.float32).astype(x_dtype(cfg))
    kdispatch.reset_decisions()
    y_pal, aux_pal = moe_mod.moe_apply(cfgp, w, x)
    _assert_kernel_used("moe_gmm")
    y_ref, aux_ref = moe_mod.moe_apply(cfg, w, x)
    _close(y_pal, y_ref, dtype)
    np.testing.assert_allclose(float(aux_pal), float(aux_ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# Fallback contracts
# ---------------------------------------------------------------------------

def test_mla_falls_back_with_reason():
    """MLA's v_head_dim != qk dim cannot map onto the flash kernel; the
    flag must still be safe to set (identical output, logged reason)."""
    cfg = _cfg(head_dim=0, mla=MLASpec(kv_lora_rank=32, q_lora_rank=0,
                                       qk_nope_dim=16, qk_rope_dim=16,
                                       v_head_dim=16))
    cfg, cfgp = _pair(cfg)
    w = attn.init_mla(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 100, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(100)
    kdispatch.reset_decisions()
    y_pal = attn.mla_train(cfgp, w, x, pos)
    dec = kdispatch.last_decisions()["flash_attention"]
    assert not dec.use_kernel and "head dim" in dec.reason
    y_ref = attn.mla_train(cfg, w, x, pos)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))


def test_sharded_without_mesh_falls_back():
    """sharded=True with no active mesh cannot resolve per-shard shapes;
    the recorded reason keeps the mesh-sharded tag."""
    dec = kdispatch.decide("flash_attention",
                           {"B": 1, "S": 128, "T": 128, "H": 4, "KV": 2,
                            "hd": 32}, sharded=True)
    assert not dec.use_kernel
    assert "mesh-sharded" in dec.reason


def test_sharded_without_logical_contract_falls_back():
    """Kernels without a KernelEntry.logical map keep the legacy
    whole-op fallback (a bare pallas_call is single-device)."""
    class _FakeMesh:
        shape = {"data": 2, "model": 4}

    dec = kdispatch.decide("mfma_gemm", {"M": 512, "N": 512, "K": 512},
                           sharded=True, mesh=_FakeMesh())
    assert not dec.use_kernel
    assert "mesh-sharded" in dec.reason
    assert "GSPMD cannot partition" in dec.reason


def test_unplannable_shape_falls_back_with_planner_reason():
    """A working set no tiling can fit must fall back, carrying the
    planner's error text, not raise out of the model."""
    from repro.arch import get_device
    tiny = get_device("tpu_v5e").derive("tpu_pico_vmem", vmem_bytes=1 << 10)
    dec = kdispatch.decide("mfma_gemm", {"M": 4096, "N": 4096, "K": 4096},
                           device=tiny)
    assert not dec.use_kernel
    assert "working-set" in dec.reason


def test_dispatch_records_are_per_kernel():
    kdispatch.reset_decisions()
    kdispatch.decide("mfma_gemm", {"M": 128, "N": 128, "K": 128})
    kdispatch.fallback("moe_gmm", "test reason")
    recs = kdispatch.last_decisions()
    assert recs["mfma_gemm"].use_kernel
    assert not recs["moe_gmm"].use_kernel
    kdispatch.reset_decisions()
    assert kdispatch.last_decisions() == {}


def test_decision_scope_isolates_and_restores():
    """A scope starts empty, captures exactly its own trace's decisions,
    and restores the surrounding log on exit — so parity assertions
    can't be polluted by (or pollute) other tests' decisions."""
    kdispatch.reset_decisions()
    kdispatch.decide("mfma_gemm", {"M": 128, "N": 128, "K": 128})
    with kdispatch.decision_scope() as decs:
        assert decs == {} and kdispatch.last_decisions() == {}
        kdispatch.fallback("moe_gmm", "inner-scope reason")
        assert set(decs) == {"moe_gmm"}
    outer = kdispatch.last_decisions()
    assert "moe_gmm" not in outer and "mfma_gemm" in outer
    kdispatch.reset_decisions()
