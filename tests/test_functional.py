"""Functional MFMA semantics (D = C + A@B, blocked) vs numpy."""

import numpy as np
import pytest

from repro.core import isa
from repro.core.functional import mfma_apply, operand_dtypes, random_operands


@pytest.mark.parametrize("name", ["fp32_16x16x16fp16", "fp32_4x4x1fp32",
                                  "fp64_4x4x4fp64", "i32_16x16x16i8",
                                  "fp32_16x16x4fp32"])
def test_mfma_matches_numpy(name):
    a, b, c = random_operands(name, seed=3)
    d = mfma_apply(name, a, b, c)
    instr = isa.lookup(name)
    an = np.asarray(a, np.float64)
    bn = np.asarray(b, np.float64)
    cn = np.asarray(c, np.float64)
    want = cn + np.einsum("bmk,bkn->bmn", an, bn)
    assert d.shape == instr.d_shape
    tol = 1e-2 if instr.in_dtype in ("fp16", "bf16") else 1e-6
    np.testing.assert_allclose(np.asarray(d, np.float64), want, rtol=tol,
                               atol=tol)


def test_i8_exact():
    """Integer MFMA must be exact (no rounding)."""
    a, b, c = random_operands("i32_16x16x16i8", seed=0)
    d = mfma_apply("i32_16x16x16i8", a, b, c)
    want = np.asarray(c, np.int64) + np.einsum(
        "bmk,bkn->bmn", np.asarray(a, np.int64), np.asarray(b, np.int64))
    np.testing.assert_array_equal(np.asarray(d, np.int64), want)


def test_registry_shapes_consistent():
    for name, instr in isa.MFMA_REGISTRY.items():
        assert instr.flops == 2 * instr.m * instr.n * instr.k * instr.blocks
        assert instr.a_shape[0] == instr.b_shape[0] == instr.d_shape[0]


def test_operand_dtypes():
    import jax.numpy as jnp
    in_dt, out_dt = operand_dtypes("fp32_16x16x16fp16")
    assert in_dt == jnp.float16 and out_dt == jnp.float32
