"""Optional-`hypothesis` shim for the test suite.

The container image does not always ship ``hypothesis``; importing it
unguarded kills pytest at *collection* (the whole suite dies under ``-x``).
Importing from this module instead keeps every example-based test running
and skips only the ``@given`` property tests when the dependency is absent.
"""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for any `st.*` strategy constructor."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
