"""flash_attention + decode_attention kernels: sweeps vs full-softmax oracle,
plus model-level blockwise path (_flash_sdpa) vs plain sdpa equivalence."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.RandomState(3)
TOL = dict(rtol=5e-2, atol=5e-2)


def _qkv(B, S, T, H, KV, hd, dt):
    q = jnp.asarray(RNG.randn(B, S, H, hd), dt)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), dt)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), dt)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 128, 8, 1, 128),     # MQA
])
@pytest.mark.parametrize("dt", [jnp.bfloat16, jnp.float32])
def test_flash_attention_sweep(B, S, H, KV, hd, dt):
    q, k, v = _qkv(B, S, S, H, KV, hd, dt)
    y = ops.flash_attention(q, k, v, causal=True, block_q=128,
                            block_kv=128)
    yr = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL)


def test_flash_noncausal():
    q, k, v = _qkv(2, 128, 128, 4, 4, 32, jnp.float32)
    y = ops.flash_attention(q, k, v, causal=False, block_q=128,
                            block_kv=128)
    yr = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


def test_flash_block_shape_invariance():
    """Result must not depend on the BlockSpec tiling."""
    q, k, v = _qkv(1, 256, 256, 4, 4, 64, jnp.float32)
    y1 = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
    y2 = ops.flash_attention(q, k, v, block_q=256, block_kv=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("kv_len", [1, 65, 128, 255])
def test_decode_attention_kv_len(kv_len):
    B, T, H, KV, hd = 2, 256, 8, 2, 64
    q = jnp.asarray(RNG.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    y = ops.decode_attention(q, k, v, jnp.int32(kv_len), block_kv=128)
    yr = ref.decode_attention_ref(q, k, v, jnp.int32(kv_len))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


def test_decode_ignores_stale_cache():
    """Positions >= kv_len must not affect the result (cache garbage)."""
    B, T, H, KV, hd = 1, 128, 4, 4, 32
    q = jnp.asarray(RNG.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    y1 = ops.decode_attention(q, k, v, jnp.int32(64), block_kv=128)
    k2 = k.at[:, 64:].set(1e4)
    v2 = v.at[:, 64:].set(-1e4)
    y2 = ops.decode_attention(q, k2, v2, jnp.int32(64), block_kv=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_model_flash_vs_plain_sdpa():
    """The model's XLA blockwise path == plain softmax attention."""
    from repro.models.attention import _flash_sdpa, sdpa
    B, S, H, hd = 2, 256, 4, 32
    q = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    yf = _flash_sdpa(q, k, v, causal=True, scale=0.17, block=64)
    yp = sdpa(q, k, v, causal=True, scale=0.17)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yp), rtol=1e-4,
                               atol=1e-4)


def test_model_flash_ragged_tail():
    """T not a multiple of the block: padding + kv_len mask path."""
    from repro.models.attention import _flash_sdpa, sdpa
    B, S, H, hd = 1, 100, 2, 16
    q = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, hd), jnp.float32)
    yf = _flash_sdpa(q, k, v, causal=False, scale=0.25, block=64)
    yp = sdpa(q, k, v, causal=False, scale=0.25)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yp), rtol=1e-4,
                               atol=1e-4)


def test_kernel_matches_model_path():
    """Pallas kernel == the model's XLA formulation (same contract)."""
    from repro.models.attention import attention
    B, S, H, KV, hd = 1, 128, 4, 2, 64
    q, k, v = _qkv(B, S, S, H, KV, hd, jnp.float32)
    y_kernel = ops.flash_attention(q, k, v, causal=True, block_q=128,
                                   block_kv=128)
    y_model = attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Ragged tails: pad=True pads q/k/v, masks padded keys via kv_len, and
# slices padded query rows back off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,causal", [(100, True), (100, False), (64, True)])
def test_flash_ragged_pad(S, causal):
    q, k, v = _qkv(1, S, S, 4, 2, 32, jnp.float32)
    y = ops.flash_attention(q, k, v, causal=causal, pad=True)
    assert y.shape == q.shape
    yr = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)


def test_flash_kv_len_masks_tail():
    """An explicit kv_len < T (prefill against a longer cache) masks."""
    q, k, v = _qkv(1, 128, 256, 4, 4, 32, jnp.float32)
    y = ops.flash_attention(q, k, v, causal=False, kv_len=jnp.int32(200),
                            block_q=128, block_kv=128)
    yr = ref.flash_attention_ref(q, k[:, :200], v[:, :200], causal=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)


def test_decode_ragged_cache_pad():
    """A 100-slot (non-128-multiple) cache pads; kv_len masks the tail."""
    B, T, H, KV, hd = 2, 100, 4, 2, 32
    q = jnp.asarray(RNG.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    y = ops.decode_attention(q, k, v, jnp.int32(77), pad=True)
    yr = ref.decode_attention_ref(q, k, v, jnp.int32(77))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# Per-request kv_len vectors + the block-paged decode variant
# ---------------------------------------------------------------------------

def test_decode_vector_kv_len():
    """A (B,) per-request length vector: each row masks independently."""
    B, T, H, KV, hd = 3, 256, 8, 2, 64
    q = jnp.asarray(RNG.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    lens = jnp.asarray([65, 128, 255], jnp.int32)
    y = ops.decode_attention(q, k, v, lens, block_kv=128)
    yr = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    # backward compat: a scalar is every-row broadcast of the vector form
    ys = ops.decode_attention(q, k, v, jnp.int32(65), block_kv=128)
    yv = ops.decode_attention(q, k, v, jnp.full((B,), 65, jnp.int32),
                              block_kv=128)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yv), rtol=1e-6,
                               atol=1e-6)


def test_decode_vector_kv_len_bad_shape_raises():
    import pytest
    B, T, H, KV, hd = 2, 128, 4, 2, 32
    q = jnp.zeros((B, H, hd), jnp.float32)
    k = jnp.zeros((B, T, KV, hd), jnp.float32)
    v = jnp.zeros((B, T, KV, hd), jnp.float32)
    with pytest.raises(ValueError, match="kv_len"):
        ops.decode_attention(q, k, v, jnp.zeros((B, 2), jnp.int32),
                             block_kv=128)


def _paged_case(B, n_prompt_blocks, page, KV, hd, H, dt, seed=11):
    """Pools + shuffled per-request block tables + ragged kv_lens."""
    rng = np.random.RandomState(seed)
    P = B * n_prompt_blocks + 1                  # + the null block 0
    q = jnp.asarray(rng.randn(B, H, hd), dt)
    k_pool = jnp.asarray(rng.randn(P, page, KV, hd), dt)
    v_pool = jnp.asarray(rng.randn(P, page, KV, hd), dt)
    perm = rng.permutation(np.arange(1, P))      # blocks land anywhere
    tables = jnp.asarray(perm.reshape(B, n_prompt_blocks), jnp.int32)
    return q, k_pool, v_pool, tables


@pytest.mark.parametrize("lens", [
    [256, 256],            # aligned full blocks
    [129, 200],            # partial last block
    [1, 255],              # single-key edge + almost-full
])
def test_paged_decode_vs_contiguous(lens):
    """Gathering the table into a contiguous cache and running plain
    decode_attention must match the paged kernel bit-for-tolerance."""
    B, NB, page, H, KV, hd = 2, 2, 128, 4, 2, 32
    q, k_pool, v_pool, tables = _paged_case(B, NB, page, KV, hd, H,
                                            jnp.float32)
    kv_len = jnp.asarray(lens, jnp.int32)
    y = ops.paged_decode_attention(q, k_pool, v_pool, tables, kv_len)
    k = k_pool[tables].reshape(B, NB * page, KV, hd)
    v = v_pool[tables].reshape(B, NB * page, KV, hd)
    yc = ops.decode_attention(q, k, v, kv_len, block_kv=page)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yc), rtol=1e-5,
                               atol=1e-5)
    yr = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, kv_len)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


def test_paged_decode_ignores_unmapped_blocks():
    """Junk in pool blocks outside every table (incl. the null block)
    must never leak into results."""
    B, NB, page, H, KV, hd = 2, 2, 128, 4, 2, 32
    q, k_pool, v_pool, tables = _paged_case(B, NB, page, KV, hd, H,
                                            jnp.float32)
    kv_len = jnp.asarray([200, 129], jnp.int32)
    y1 = ops.paged_decode_attention(q, k_pool, v_pool, tables, kv_len)
    k2 = k_pool.at[0].set(1e4)                   # poison the null block
    v2 = v_pool.at[0].set(-1e4)
    y2 = ops.paged_decode_attention(q, k2, v2, tables, kv_len)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6,
                               atol=1e-6)


def test_paged_decode_page_block_mismatch_raises():
    """A plan whose block_kv != the pool page is a geometry bug: raise."""
    import pytest
    from repro.kernels import plan_for
    B, NB, page, H, KV, hd = 1, 1, 128, 4, 2, 32
    q, k_pool, v_pool, tables = _paged_case(B, NB, page, KV, hd, H,
                                            jnp.float32)
    plan = plan_for("paged_decode_attention",
                    {"B": B, "T": 256, "H": H, "KV": KV, "hd": hd,
                     "page": 256})
    with pytest.raises(ValueError, match="page"):
        ops.paged_decode_attention(q, k_pool, v_pool, tables,
                                   jnp.asarray([100], jnp.int32), plan=plan)


# ---------------------------------------------------------------------------
# Tiling contract: misalignment raises instead of silently clamping
# ---------------------------------------------------------------------------

def test_flash_sub128_block_raises():
    """block_q=64 used to be clamp-accepted; now a non-MXU block raises."""
    q, k, v = _qkv(1, 128, 128, 4, 4, 64, jnp.float32)
    with pytest.raises(ValueError, match="block_q=64"):
        ops.flash_attention(q, k, v, block_q=64, block_kv=128)


def test_flash_sub128_seq_raises():
    q, k, v = _qkv(1, 64, 64, 4, 4, 64, jnp.float32)
    with pytest.raises(ValueError, match="S=64"):
        ops.flash_attention(q, k, v)


def test_decode_non_divisible_block_raises():
    B, T, H, KV, hd = 1, 256, 4, 4, 32
    q = jnp.zeros((B, H, hd), jnp.float32)
    k = jnp.zeros((B, T, KV, hd), jnp.float32)
    v = jnp.zeros((B, T, KV, hd), jnp.float32)
    with pytest.raises(ValueError, match="T=256"):
        ops.decode_attention(q, k, v, jnp.int32(7), block_kv=384)
