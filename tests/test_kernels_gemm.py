"""mfma_gemm + moe_gmm Pallas kernels: shape/dtype sweeps vs oracles."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.RandomState(7)


def _tol(dt):
    # f32 tolerance covers K-split reassociation vs the single-dot oracle
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 \
        else dict(rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 512),
                                   (384, 256, 256), (128, 512, 1024)])
@pytest.mark.parametrize("dt", [jnp.bfloat16, jnp.float32])
def test_mfma_gemm_sweep(m, n, k, dt):
    a = jnp.asarray(RNG.randn(m, k), dt)
    b = jnp.asarray(RNG.randn(k, n), dt)
    c = jnp.asarray(RNG.randn(m, n), jnp.float32)
    y = ops.mfma_gemm(a, b, c, block_m=128, block_n=128, block_k=128)
    yr = ref.mfma_gemm_ref(a, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dt))


def test_mfma_gemm_is_accumulate():
    """D = C + A@B: the C operand must actually accumulate (the MFMA
    contract, not a plain matmul)."""
    a = jnp.asarray(RNG.randn(128, 128), jnp.float32)
    b = jnp.asarray(RNG.randn(128, 128), jnp.float32)
    c0 = jnp.zeros((128, 128), jnp.float32)
    c1 = jnp.ones((128, 128), jnp.float32) * 3.0
    y0 = ops.mfma_gemm(a, b, c0)
    y1 = ops.mfma_gemm(a, b, c1)
    np.testing.assert_allclose(np.asarray(y1 - y0),
                               np.full((128, 128), 3.0), rtol=1e-5, atol=1e-5)


def test_mfma_gemm_matches_mfma_microops():
    """Kernel result == composing fp32_16x16x4fp32 MFMA micro-ops over the
    same GEMM (the paper's instruction semantics scaled to an MXU tile)."""
    from repro.core.functional import mfma_apply
    M = N = 128
    K = 8  # two K-steps of the 16x16x4 instruction
    a = jnp.asarray(RNG.randn(M, K), jnp.float32)
    b = jnp.asarray(RNG.randn(K, N), jnp.float32)
    c = jnp.asarray(RNG.randn(M, N), jnp.float32)
    # micro-op composition: D accumulates over (M/16 x N/16 x K/4) tiles
    d = np.asarray(c).copy()
    for i in range(M // 16):
        for j in range(N // 16):
            for kk in range(K // 4):
                blk = mfma_apply(
                    "fp32_16x16x4fp32",
                    np.asarray(a)[None, i*16:(i+1)*16, kk*4:(kk+1)*4],
                    np.asarray(b)[None, kk*4:(kk+1)*4, j*16:(j+1)*16],
                    d[None, i*16:(i+1)*16, j*16:(j+1)*16])
                d[i*16:(i+1)*16, j*16:(j+1)*16] = np.asarray(blk[0])
    y = ops.mfma_gemm(a, b, c, block_m=128, block_n=128, block_k=8)
    np.testing.assert_allclose(np.asarray(y), d, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("e,c,k,n", [(4, 128, 256, 128), (8, 128, 128, 256),
                                     (2, 256, 512, 128)])
@pytest.mark.parametrize("dt", [jnp.bfloat16, jnp.float32])
def test_moe_gmm_sweep(e, c, k, n, dt):
    x = jnp.asarray(RNG.randn(e, c, k), dt)
    w = jnp.asarray(RNG.randn(e, k, n), dt)
    y = ops.moe_gmm(x, w)           # planner-chosen MXU-aligned tiles
    yr = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dt))


def test_moe_gmm_expert_isolation():
    """Each expert's output depends only on its own slice."""
    x = jnp.asarray(RNG.randn(4, 128, 128), jnp.float32)
    w = jnp.asarray(RNG.randn(4, 128, 128), jnp.float32)
    y = ops.moe_gmm(x, w, block_m=128, block_n=128, block_k=128)
    x2 = x.at[2].set(0.0)
    y2 = ops.moe_gmm(x2, w, block_m=128, block_n=128, block_k=128)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y2[0]))
    np.testing.assert_allclose(np.asarray(y2[2]), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Ragged tails: pad=True zero-pads onto the MXU contract and slices back
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(100, 60, 200), (64, 64, 64),
                                   (130, 128, 250)])
def test_mfma_gemm_ragged_pad(m, n, k):
    """Zero row/col/contraction padding is exact for the accumulate-GEMM."""
    a = jnp.asarray(RNG.randn(m, k), jnp.float32)
    b = jnp.asarray(RNG.randn(k, n), jnp.float32)
    c = jnp.asarray(RNG.randn(m, n), jnp.float32)
    y = ops.mfma_gemm(a, b, c, pad=True)
    assert y.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.mfma_gemm_ref(a, b, c)),
                               rtol=5e-4, atol=5e-4)


def test_moe_gmm_ragged_pad():
    """Capacity-trimmed C (a multiple of 4, not 128) runs the kernel."""
    x = jnp.asarray(RNG.randn(4, 20, 100), jnp.float32)
    w = jnp.asarray(RNG.randn(4, 100, 60), jnp.float32)
    y = ops.moe_gmm(x, w, pad=True)
    assert y.shape == (4, 20, 60)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.moe_gmm_ref(x, w)),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Tiling contract: misalignment raises instead of silently clamping
# ---------------------------------------------------------------------------

def test_gemm_sub128_dim_raises():
    """M=64 used to pass via the min(block, dim) clamp with a non-MXU
    64-wide block; it must now raise naming the offending dim."""
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    c = jnp.zeros((64, 128), jnp.float32)
    with pytest.raises(ValueError, match="M=64"):
        ops.mfma_gemm(a, b, c)


def test_gemm_non_divisible_block_raises():
    a = jnp.zeros((256, 256), jnp.float32)
    b = jnp.zeros((256, 256), jnp.float32)
    c = jnp.zeros((256, 256), jnp.float32)
    with pytest.raises(ValueError, match="N=256"):
        ops.mfma_gemm(a, b, c, block_n=192)


def test_gemm_unaligned_block_raises():
    a = jnp.zeros((256, 256), jnp.float32)
    b = jnp.zeros((256, 256), jnp.float32)
    c = jnp.zeros((256, 256), jnp.float32)
    with pytest.raises(ValueError, match="block_m=64"):
        ops.mfma_gemm(a, b, c, block_m=64)


def test_moe_gmm_sub128_dim_raises():
    x = jnp.zeros((4, 64, 128), jnp.float32)
    w = jnp.zeros((4, 128, 128), jnp.float32)
    with pytest.raises(ValueError, match="C=64"):
        ops.moe_gmm(x, w)


def test_moe_gmm_shape_mismatch_message():
    """The bare shape assert is now a descriptive ValueError (the
    ServeEngine.generate error-contract precedent)."""
    from repro.kernels.moe_gmm import moe_gmm as raw
    x = jnp.zeros((4, 128, 128), jnp.float32)
    w = jnp.zeros((2, 128, 128), jnp.float32)
    with pytest.raises(ValueError, match="expert count"):
        raw(x, w, block_m=128, block_n=128, block_k=128)


def test_gemm_operand_mismatch_message():
    a = jnp.zeros((128, 128), jnp.float32)
    b = jnp.zeros((256, 128), jnp.float32)
    c = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError, match="incompatible operands"):
        from repro.kernels.mfma_gemm import mfma_gemm as raw
        raw(a, b, c, block_m=128, block_n=128, block_k=128)
