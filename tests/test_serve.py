"""Serve engine: greedy determinism, temperature sampling, cache reuse."""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import ServeEngine

CFG = get_config("qwen2-7b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def test_greedy_deterministic():
    eng = ServeEngine(CFG, PARAMS, max_len=64)
    prompt = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % CFG.vocab_size
    r1 = eng.generate(prompt, n_steps=8, temperature=0.0)
    r2 = eng.generate(prompt, n_steps=8, temperature=0.0)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 8)
    assert r1.tokens.min() >= 0 and r1.tokens.max() < CFG.vocab_size


def test_temperature_seed_control():
    eng = ServeEngine(CFG, PARAMS, max_len=64)
    prompt = np.ones((2, 8), np.int32)
    a = eng.generate(prompt, n_steps=8, temperature=1.0, seed=0)
    b = eng.generate(prompt, n_steps=8, temperature=1.0, seed=0)
    c = eng.generate(prompt, n_steps=8, temperature=5.0, seed=1)
    np.testing.assert_array_equal(a.tokens, b.tokens)   # same seed
    assert not np.array_equal(a.tokens, c.tokens)       # different seed/temp


def test_bucket_overflow_raises_value_error():
    """S + n_steps past the jitted (batch, max_len) bucket must raise a
    ValueError naming the bucket size, not a bare assert."""
    import pytest
    eng = ServeEngine(CFG, PARAMS, max_len=32)
    prompt = np.ones((1, 24), np.int32)
    with pytest.raises(ValueError, match=r"max_len bucket of 32"):
        eng.generate(prompt, n_steps=16)   # 24 + 16 > 32
    # boundary case still fits
    out = eng.generate(prompt, n_steps=8)
    assert out.tokens.shape == (1, 8)


def test_sampling_keys_distinct_and_root_never_consumed():
    """Regression: the first sampled token used the raw root PRNGKey and
    step 0 reused it via the first split — two draws from one key.  The
    root must only ever be split: every key handed to ``_sample`` has to
    differ from ``PRNGKey(seed)`` and from every other sampling key."""
    eng = ServeEngine(CFG, PARAMS, max_len=64)
    seen = []
    orig = eng._sample

    def spy(logits, key, temperature):
        seen.append(np.asarray(key))
        return orig(logits, key, temperature)

    eng._sample = spy
    eng.generate(np.ones((1, 8), np.int32), n_steps=4, temperature=1.0,
                 seed=0)
    root = np.asarray(jax.random.PRNGKey(0))
    assert len(seen) == 5                        # prefill sample + 4 steps
    for k in seen:
        assert not np.array_equal(k, root)
    for i in range(len(seen)):
        for j in range(i + 1, len(seen)):
            assert not np.array_equal(seen[i], seen[j]), (i, j)


def test_batch_isolation():
    """Each request decodes independently of its batch neighbours."""
    eng = ServeEngine(CFG, PARAMS, max_len=64)
    p = np.arange(3 * 12, dtype=np.int32).reshape(3, 12) % CFG.vocab_size
    full = eng.generate(p, n_steps=6).tokens
    solo = eng.generate(p[1:2], n_steps=6).tokens
    np.testing.assert_array_equal(full[1:2], solo)
