"""Fleet capacity planner: queueing model, frontier, calibration.

Three layers under test, in increasing integration order:

1. the closed-form queueing model (monotonicity, SLO binding, the
   bisection) on hand-built :class:`ServeCost` fixtures — no perf, no
   jax;
2. the scenario registry + the frontier sweep (analytic cost graphs
   through ``perf.predict``/``perf.sweep``), including the overlay
   what-if composing into the frontier and the compute <-> collective
   bound switch;
3. calibration: ``simulate_trace`` must reproduce the *exact* tick
   accounting of a real ``PagedServeEngine`` replay, and tick costs
   fitted from measured walls must predict a held-out trace's
   per-token latency within the calibration band.
"""

import dataclasses
import math
import time

import numpy as np
import pytest

from repro.arch.overlay import IDENTITY, Overlay
from repro.fleet import (SLO, ServeCost, TickCosts, TrafficScenario,
                         fit_tick_costs, frontier, get_scenario,
                         list_scenarios, max_sustainable_qps, p99_latency_s,
                         register_scenario, serve_cost, simulate_trace,
                         token_latency_s)
from repro.fleet.capacity import analytic_graphs
from repro.fleet.cli import main as fleet_main
from repro.fleet.cli import parse_overlay

DEVICES = ("mi200", "mi300", "mi300x", "tpu_v5e", "tpu_v5p")


def _cost(decode_ms=5.0, prefill_ms=20.0, max_batch=8, chunks=2):
    """A hand-built ServeCost: the queueing model needs nothing else."""
    return ServeCost(scenario="synthetic", device="unit", max_batch=max_batch,
                     decode_tick_s=decode_ms / 1e3,
                     prefill_chunk_s=prefill_ms / 1e3,
                     decode_bound="memory", prefill_bound="compute",
                     prefill_chunks_per_request=chunks)


def _scn(**kw):
    kw.setdefault("name", "unit")
    kw.setdefault("prompt_mean", 512)
    kw.setdefault("output_mean", 64)
    kw.setdefault("max_batch", 8)
    kw.setdefault("prefill_chunk", 256)
    return TrafficScenario(**kw)


# ---------------------------------------------------------------------------
# 1. Queueing model
# ---------------------------------------------------------------------------

def test_latency_strictly_monotonic_in_qps():
    scn, cost = _scn(), _cost()
    qs = np.linspace(0.0, 4.0, 60)
    p99 = [p99_latency_s(q, scn, cost) for q in qs]
    tok = [token_latency_s(q, scn, cost) for q in qs]
    assert all(b > a for a, b in zip(p99, p99[1:]))
    assert all(b >= a for a, b in zip(tok, tok[1:]))
    # overload is infinite, idle equals the bare decode tick
    assert p99[0] == pytest.approx(cost.decode_tick_s)
    assert p99_latency_s(1e9, scn, cost) == math.inf


def test_burstiness_inflates_tail_not_idle():
    scn_calm, cost = _scn(burstiness=1.0), _cost()
    scn_burst = _scn(burstiness=4.0)
    assert p99_latency_s(0.0, scn_calm, cost) == \
        p99_latency_s(0.0, scn_burst, cost)
    assert p99_latency_s(1.0, scn_burst, cost) > \
        p99_latency_s(1.0, scn_calm, cost)


def test_max_qps_is_zero_when_idle_device_misses_slo():
    scn = _scn(slo=SLO(p99_token_ms=1.0))       # < the 5ms decode tick
    assert max_sustainable_qps(scn, _cost()) == 0.0


def test_slo_binding_switches_latency_vs_throughput():
    """Loose SLO: the binding constraint is overload (rho -> 1), so
    max_qps approaches the work-conservation ceiling.  Tight SLO: the
    binding constraint is the latency target, max_qps sits well below
    the ceiling and p99 lands ON the target."""
    cost = _cost()
    ceiling = 1.0 / (2 * cost.prefill_chunk_s
                     + 64 * cost.decode_tick_s / cost.max_batch)
    loose = max_sustainable_qps(_scn(slo=SLO(p99_token_ms=1e6)), cost)
    tight_scn = _scn(slo=SLO(p99_token_ms=8.0))
    tight = max_sustainable_qps(tight_scn, cost)
    assert loose == pytest.approx(ceiling, rel=1e-3)
    assert tight < 0.9 * ceiling
    assert p99_latency_s(tight, tight_scn, cost) * 1e3 == \
        pytest.approx(8.0, rel=1e-3)
    # and the ttft SLO can be the binding one instead
    ttft_scn = _scn(slo=SLO(p99_token_ms=1e6, ttft_p99_ms=45.0))
    ttft = max_sustainable_qps(ttft_scn, cost)
    assert 0.0 < ttft < loose


def test_bisection_result_is_the_feasibility_boundary():
    scn, cost = _scn(slo=SLO(p99_token_ms=25.0)), _cost()
    q = max_sustainable_qps(scn, cost)
    assert p99_latency_s(q, scn, cost) <= scn.slo.p99_token_ms / 1e3
    assert p99_latency_s(q * 1.01, scn, cost) > scn.slo.p99_token_ms / 1e3


# ---------------------------------------------------------------------------
# 2. Scenario registry + cost graphs + frontier
# ---------------------------------------------------------------------------

def test_builtin_scenarios_registered():
    assert {"chat", "long_context", "bursty_batch"} <= set(list_scenarios())
    chat = get_scenario("chat")
    assert chat.trace == "base" and chat.slo.p99_token_ms == 200.0
    assert chat.prefill_chunks_per_request == 2


def test_scenario_registry_roundtrip_and_duplicates():
    scn = register_scenario(_scn(name="test-roundtrip"))
    try:
        assert get_scenario("test-roundtrip") is scn
        assert "test-roundtrip" in list_scenarios()
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(_scn(name="test-roundtrip"))
    finally:
        from repro.fleet import scenario as mod
        del mod._REGISTRY["test-roundtrip"]
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("test-roundtrip")


def test_scenario_validation():
    with pytest.raises(ValueError, match="qps"):
        _scn(name="bad", qps=0.0)
    with pytest.raises(ValueError, match="output_mean"):
        _scn(name="bad", output_mean=0)


def test_analytic_graph_aggregates_consistent_with_ops():
    """The roofline engine consumes the aggregates, the MFMA engines the
    per-op list — both views of the same graph must agree."""
    for name in list_scenarios():
        graphs = analytic_graphs(get_scenario(name))
        for kind, g in graphs.items():
            dot_flops = sum(op.count * op.flops for op in g.ops)
            assert g.flops == pytest.approx(dot_flops), (name, kind)
            wire = sum(op.count * op.wire_bytes for op in g.ops)
            assert g.collective_wire == pytest.approx(wire)
            assert g.bytes_accessed > 0 and g.flops > 0


def test_tensor_parallel_adds_collectives_and_shrinks_memory():
    base = _scn(name="tp1", arch="yi-34b", tp=1)
    tp4 = _scn(name="tp4", arch="yi-34b", tp=4)
    g1 = analytic_graphs(base)["decode"]
    g4 = analytic_graphs(tp4)["decode"]
    assert g1.collective_wire == 0.0
    assert g4.collective_wire > 0.0
    assert any(op.kind == "collective" and op.opcode == "all-reduce"
               and op.group == 4 for op in g4.ops)
    # sharding 4 ways streams roughly a quarter of the weights
    assert g4.bytes_accessed < 0.5 * g1.bytes_accessed


def test_serve_cost_bound_switches_compute_to_collective():
    """A tp=8 short-context batch is compute-bound at baseline (the LM
    head GEMM); an overlay that speeds the matrix units 8x leaves the
    per-layer all-reduces as the bottleneck — the planner must surface
    the switch, because it changes what a faster interconnect buys."""
    scn = _scn(name="tp8-probe", arch="qwen2-7b", prompt_mean=16,
               output_mean=16, max_batch=256, prefill_chunk=16, tp=8)
    base = serve_cost(scn, "mi300")
    fast_mfma = serve_cost(scn, "mi300", overlay=Overlay(mfma_scale=0.125))
    assert base.decode_bound == "compute"
    assert fast_mfma.decode_bound == "collective"
    assert fast_mfma.decode_tick_s < base.decode_tick_s


def test_serve_cost_bound_switches_memory_to_compute():
    chat = get_scenario("chat")
    assert serve_cost(chat, "mi300").decode_bound == "memory"
    assert serve_cost(chat, "mi300",
                      overlay=Overlay(bw_scale=100.0)).decode_bound \
        == "compute"


def test_frontier_all_devices_all_scenarios_finite():
    """Every registered built-in scenario must yield a finite, feasible
    frontier on every catalog device (also linted standalone by
    scripts/check_device_specs.py)."""
    rep = frontier(list_scenarios(), DEVICES)
    assert len(rep.rows) == len(list_scenarios()) * len(DEVICES)
    for r in rep.rows:
        assert r.feasible, (r.scenario, r.device)
        assert 0 < r.max_qps < math.inf
        assert 1 <= r.devices_needed < 1000
        assert r.p99_token_ms <= r.slo_p99_ms
        assert math.isfinite(r.cost_per_mtok)
        assert r.bound in ("compute", "memory", "collective", "matrix")
    for name in list_scenarios():
        assert rep.best(name) is not None


def test_frontier_deterministic():
    a = frontier("chat", ("mi300", "tpu_v5p"))
    b = frontier("chat", ("mi300", "tpu_v5p"))
    assert a.rows == b.rows


def test_overlay_composes_into_frontier():
    """The acceptance what-if: an mfma_scale overlay must move the
    frontier, and the overlay rows must be labelled as such."""
    rep = frontier("chat", ("mi300",),
                   overlays=[IDENTITY, Overlay(mfma_scale=2.0)])
    base, what_if = rep.rows
    assert base.overlay == "baseline" and what_if.overlay == "mfma x2"
    assert what_if.max_qps != base.max_qps
    assert what_if.prefill_chunk_ms != base.prefill_chunk_ms


def test_frontier_infeasible_slo_reports_inf():
    scn = dataclasses.replace(get_scenario("chat"), name="chat-impossible",
                              slo=SLO(p99_token_ms=1e-3))
    rep = frontier(scn, ("mi300",))
    row = rep.rows[0]
    assert not row.feasible
    assert row.devices_needed == 0 and row.cost_per_mtok == math.inf
    assert rep.best("chat-impossible") is None
    assert "inf" in rep.table()


def test_fleet_report_table_shape():
    rep = frontier("chat", ("mi300", "mi300x"))
    lines = rep.table().splitlines()
    assert len(lines) == 2 + 2                      # header + rule + rows
    assert lines[0].startswith("| scenario | device |")
    d = rep.as_dict()
    assert {r["device"] for r in d["rows"]} == {"mi300", "mi300x"}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_smoke_small(capsys):
    assert fleet_main(["--small", "--devices", "mi300,mi300x,tpu_v5p"]) == 0
    out = capsys.readouterr().out
    assert "| scenario | device |" in out
    assert "mi300" in out and "mi300x" in out and "tpu_v5p" not in out
    assert "cheapest feasible device" in out


def test_cli_json_and_overrides(capsys):
    assert fleet_main(["--scenario", "chat", "--devices", "mi300",
                       "--slo-p99-ms", "50", "--qps", "100",
                       "--json"]) == 0
    import json
    rows = json.loads(capsys.readouterr().out)["rows"]
    assert rows[0]["slo_p99_ms"] == 50.0
    assert rows[0]["scenario"] == "chat"


def test_cli_overlay_parsing():
    ov = parse_overlay("mfma_scale=2, bw_scale=1.5")
    assert ov.mfma_scale == 2.0 and ov.bw_scale == 1.5
    with pytest.raises(ValueError, match="unknown overlay knob"):
        parse_overlay("warp_scale=2")
    with pytest.raises(ValueError, match="knob=value"):
        parse_overlay("mfma_scale")


# ---------------------------------------------------------------------------
# 3. Calibration against the real PagedServeEngine
# ---------------------------------------------------------------------------

def _sim_kwargs(eng):
    return dict(max_len=eng.max_len, max_batch=eng.max_batch, page=eng.page,
                n_blocks=eng.cache.n_blocks, prefill_chunk=eng.prefill_chunk)


@pytest.fixture(scope="module")
def paged_engine():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import PagedServeEngine
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = PagedServeEngine(cfg, params, max_len=160, max_batch=2,
                           page=128, prefix_cache=False)
    return cfg, eng


def _trace(cfg, specs, seed=7):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, (s,))
                    .astype(np.int32), n_steps=n, arrival=a)
            for s, n, a in specs]


# trace mixes with linearly independent (decode, prefill, tick) columns:
# decode-heavy, prefill-heavy (chunked long prompts), arrival-gapped
# (overhead-only ticks while the queue waits), and backpressured
_TRACES = {
    "decode_heavy": [(6, 24, 0), (9, 30, 0), (7, 18, 1)],
    "prefill_heavy": [(130, 3, 0), (120, 2, 0), (96, 2, 1)],
    "gapped": [(8, 6, 0), (10, 5, 14), (12, 4, 30)],
    "mixed": [(64, 10, 0), (9, 20, 0), (100, 4, 2), (12, 12, 3)],
}


def test_simulate_trace_matches_engine_tick_accounting(paged_engine):
    """The host replica must agree with the real scheduler EXACTLY on
    ticks, decode steps and prefill chunks — that is what makes fitted
    tick costs transferable to unseen traces."""
    cfg, eng = paged_engine
    for name, specs in _TRACES.items():
        trace = _trace(cfg, specs)
        _, stats = eng.run(trace)
        sim = simulate_trace(trace, **_sim_kwargs(eng))
        for field in ("requests", "tokens", "ticks", "decode_steps",
                      "prefill_chunks"):
            assert getattr(sim, field) == stats[field], (name, field)
        assert sim.occupancy_max == pytest.approx(stats["occupancy_max"])


def test_simulate_trace_models_block_backpressure():
    """Third request must wait for a retirement on a 2-block pool —
    visible as extra ticks vs an uncontended pool (no jax needed)."""
    rng = np.random.default_rng(0)

    def mk(n_reqs):
        from repro.serve.api import Request
        return [Request(prompt=rng.integers(0, 64, (8,)).astype(np.int32),
                        n_steps=4, arrival=0) for _ in range(n_reqs)]

    tight = simulate_trace(mk(3), max_len=64, max_batch=3, page=128,
                           n_blocks=3, prefill_chunk=32)
    roomy = simulate_trace(mk(3), max_len=64, max_batch=3, page=128,
                           n_blocks=4, prefill_chunk=32)
    assert tight.ticks > roomy.ticks
    assert tight.decode_steps >= roomy.decode_steps


def test_simulate_trace_validates_like_the_engine():
    from repro.serve.api import Request
    big = Request(prompt=np.zeros(120, np.int32), n_steps=16)
    with pytest.raises(ValueError, match="max_len"):
        simulate_trace([big], max_len=64, max_batch=2, page=64)
    with pytest.raises(ValueError, match="blocks"):
        simulate_trace([big], max_len=192, max_batch=2, page=128, n_blocks=2)


def test_fit_tick_costs_recovers_exact_synthetic_costs():
    true = TickCosts(decode_s=3e-3, prefill_s=1.5e-3, overhead_s=2e-4)
    obs = []
    for d, p, t in [(10, 2, 13), (3, 9, 12), (20, 5, 26), (7, 7, 20)]:
        from repro.fleet.capacity import SimStats
        st = SimStats(requests=1, tokens=d + 1, ticks=t, decode_steps=d,
                      prefill_chunks=p, occupancy_mean=0.5, occupancy_max=1.0)
        obs.append((st, true.wall_s(st)))
    fit = fit_tick_costs(obs)
    assert fit.decode_s == pytest.approx(true.decode_s, rel=1e-6)
    assert fit.prefill_s == pytest.approx(true.prefill_s, rel=1e-6)
    assert fit.overhead_s == pytest.approx(true.overhead_s, rel=1e-6)
    with pytest.raises(ValueError, match=">= 3"):
        fit_tick_costs(obs[:2])


def test_fitted_costs_predict_heldout_trace_latency(paged_engine):
    """The acceptance band: tick costs fitted on three probe traces must
    predict a held-out trace's measured per-token latency within
    [0.5, 2.0]x — the tolerance that makes the planner's capacity
    numbers trustworthy at fleet granularity."""
    cfg, eng = paged_engine
    eng.run(_trace(cfg, [(8, 3, 0)]))             # warm the jit caches

    def timed(specs, seed):
        trace = _trace(cfg, specs, seed=seed)
        t0 = time.perf_counter()
        _, stats = eng.run(trace)
        return stats, time.perf_counter() - t0

    obs = [timed(_TRACES[k], seed)
           for seed, k in enumerate(("decode_heavy", "prefill_heavy",
                                     "gapped"))]
    costs = fit_tick_costs(obs)
    held_stats, held_wall = timed(_TRACES["mixed"], seed=99)
    predicted = costs.token_latency_s(held_stats)
    measured = held_wall / held_stats["tokens"]
    assert 0.5 * measured <= predicted <= 2.0 * measured, \
        f"predicted {predicted * 1e3:.2f}ms/tok vs measured " \
        f"{measured * 1e3:.2f}ms/tok"
