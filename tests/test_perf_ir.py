"""The unified HLO -> KernelGraph parser: typed ops, loop multipliers
(nested whiles, trip-count fallbacks), static dot parsing, upcast bytes."""

import jax
import jax.numpy as jnp
import pytest

from repro.perf import hlo_ir
from repro.perf.hlo_ir import KernelGraph, parse_module, parse_static_dots


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


# ---------------------------------------------------------------------------
# Typed ops from real compiled modules
# ---------------------------------------------------------------------------

def test_plain_dot_graph():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    g = parse_module(_compiled_text(lambda x, y: x @ y, a, b))
    assert g.flops == 2 * 128 * 256 * 64
    dots = g.dots
    assert len(dots) == 1
    d = dots[0]
    assert (d.batch, d.m, d.n, d.k) == (1, 128, 64, 256)
    assert d.count == 1.0
    assert d.kind == "dot" and d.label.startswith("dot[")
    assert g.key  # content-hashed
    assert g.source == "hlo"


def test_memory_ops_aggregate_to_totals():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    g = parse_module(_compiled_text(lambda x: jnp.tanh(x) + 1.0, a))
    assert g.bytes_accessed >= 2 * 256 * 256 * 4  # read + write
    mem = [op for op in g.ops if op.kind == "memory"]
    assert mem, "memory-bound fusions must appear as typed ops"
    # per-opcode memory ops tile the bytes_by_opcode aggregate exactly
    assert sum(op.bytes for op in mem) == pytest.approx(
        sum(v for k, v in g.bytes_by_opcode.items() if k != "dot"))


def test_scan_multiplies_counts():
    """A dot inside a 7-trip scan must carry count=7 (XLA's own
    cost_analysis counts it once — the reason the loop walk exists)."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(x):
        def body(h, _):
            return h @ x * 0.99, None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    g = parse_module(_compiled_text(fn, a))
    assert g.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)
    counts = [c for _, c in g.dot_pairs()]
    assert 7.0 in counts


def test_nested_scan_multiplier():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def fn(x):
        def outer(h, _):
            def inner(g, _):
                return g @ x, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    g = parse_module(_compiled_text(fn, a))
    assert g.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)
    # the inner-body dot's executed count is the PRODUCT of trip counts
    assert any(c == pytest.approx(15.0) for _, c in g.dot_pairs())


# ---------------------------------------------------------------------------
# Trip-count plumbing on handwritten HLO (every fallback layer)
# ---------------------------------------------------------------------------

def _while_module(outer_attrs: str, inner_attrs: str,
                  cond_body: str = "") -> str:
    """Nested while(while(dot)) module; attrs inject backend configs."""
    cond_body = cond_body or """
  %ci = s32[] get-tuple-element(%cp), index=0
  %cn = s32[] constant(3)
  ROOT %clt = pred[] compare(%ci, %cn), direction=LT
"""
    return f"""
HloModule nested_whiles

%inner_cond (cp: (s32[], f32[16,16])) -> pred[] {{
  %cp = (s32[], f32[16,16]) parameter(0)
{cond_body.strip()}
}}

%inner_body (bp: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {{
  %bp = (s32[], f32[16,16]) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %x = f32[16,16] get-tuple-element(%bp), index=1
  %d = f32[16,16] dot(%x, %x), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %bt = (s32[], f32[16,16]) tuple(%i2, %d)
}}

%outer_cond (op: (s32[], f32[16,16])) -> pred[] {{
  %op = (s32[], f32[16,16]) parameter(0)
  %oi = s32[] get-tuple-element(%op), index=0
  %on = s32[] constant(5)
  ROOT %olt = pred[] compare(%oi, %on), direction=LT
}}

%outer_body (obp: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {{
  %obp = (s32[], f32[16,16]) parameter(0)
  %oj = s32[] get-tuple-element(%obp), index=0
  %ox = f32[16,16] get-tuple-element(%obp), index=1
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,16]) tuple(%zero, %ox)
  %w = (s32[], f32[16,16]) while(%init), condition=%inner_cond, body=%inner_body{inner_attrs}
  %wi = s32[] get-tuple-element(%w), index=1
  %oone = s32[] constant(1)
  %oj2 = s32[] add(%oj, %oone)
  ROOT %obt = (s32[], f32[16,16]) tuple(%oj2, %wi)
}}

ENTRY %main (p0: f32[16,16]) -> f32[16,16] {{
  %p0 = f32[16,16] parameter(0)
  %ezero = s32[] constant(0)
  %einit = (s32[], f32[16,16]) tuple(%ezero, %p0)
  %ew = (s32[], f32[16,16]) while(%einit), condition=%outer_cond, body=%outer_body{outer_attrs}
  ROOT %out = f32[16,16] get-tuple-element(%ew), index=1
}}
"""


DOT_FLOPS = 2 * 16 ** 3


def test_nested_while_known_trip_counts():
    """known_trip_count backend configs on both loops: counts multiply."""
    txt = _while_module(
        ', backend_config={"known_trip_count":{"n":"5"}}',
        ', backend_config={"known_trip_count":{"n":"3"}}')
    g = parse_module(txt)
    pairs = g.dot_pairs()
    assert len(pairs) == 1
    assert pairs[0][1] == pytest.approx(15.0)       # 5 outer * 3 inner
    assert g.flops == pytest.approx(15 * DOT_FLOPS)


def test_nested_while_condition_fallback():
    """No backend config: trip counts come from the conditions'
    compare(i, constant(N), direction=LT) pattern."""
    g = parse_module(_while_module("", ""))
    assert g.dot_pairs()[0][1] == pytest.approx(15.0)
    assert g.flops == pytest.approx(15 * DOT_FLOPS)


def test_unknown_trip_count_falls_back_to_one():
    """An inner while whose condition has no LT-vs-constant pattern (and
    no backend config) charges its body exactly once."""
    opaque_cond = """
  %ci = s32[] get-tuple-element(%cp), index=0
  %cz = s32[] constant(0)
  ROOT %cne = pred[] compare(%ci, %cz), direction=NE
"""
    g = parse_module(_while_module("", "", cond_body=opaque_cond))
    # outer still resolves to 5 via its LT condition; inner falls to 1
    assert g.dot_pairs()[0][1] == pytest.approx(5.0)
    assert g.flops == pytest.approx(5 * DOT_FLOPS)


def test_known_trip_count_beats_condition_fallback():
    """The backend config wins over a (different) condition constant."""
    txt = _while_module(', backend_config={"known_trip_count":{"n":"2"}}',
                        "")
    g = parse_module(txt)
    assert g.dot_pairs()[0][1] == pytest.approx(2 * 3.0)


def test_no_entry_raises():
    with pytest.raises(ValueError, match="ENTRY"):
        parse_module("HloModule empty\n")


# ---------------------------------------------------------------------------
# Static dots / upcast bytes / totals constructor
# ---------------------------------------------------------------------------

def test_parse_static_dots_stablehlo():
    a = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((512, 128), jnp.bfloat16)
    txt = jax.jit(lambda x, y: x @ y).lower(a, b).as_text()
    dots = parse_static_dots(txt)
    assert len(dots) == 1
    d = dots[0]
    assert (d.m, d.n, d.k, d.batch) == (256, 128, 512, 1)
    assert d.in_dtype == "bf16" and d.dtype == "bf16"
    assert d.flops == 2 * 256 * 128 * 512


def test_cpu_upcast_bytes_counts_large_buffer_converts():
    dims = "8388608,4"  # 32M elements -> 128MiB f32, above the 64MiB floor
    txt = (f"ENTRY %e (p: bf16[{dims}]) -> f32[{dims}] {{\n"
           f"  %p = bf16[{dims}] parameter(0)\n"
           f"  ROOT %c = f32[{dims}] convert(%p)\n"
           "}\n")
    assert hlo_ir.cpu_upcast_bytes(txt) == 8388608 * 4 * 4
    # inside a fused computation: not a hoisted legalisation buffer
    fused = txt.replace("ENTRY %e", "%fused_computation.1")
    assert hlo_ir.cpu_upcast_bytes(fused) == 0


def test_from_totals_roofline_grade():
    g = KernelGraph.from_totals(flops=1e12, bytes_accessed=2e9,
                                collective_wire=3e8, key="cell")
    assert g.source == "totals" and not g.ops
    assert g.flops == 1e12 and g.collective_wire == 3e8
