"""AdamW optimizer + schedule + clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optim import (OptConfig, adamw_update, global_norm,
                               init_opt_state, lr_schedule)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4            # peak after warmup
    assert lrs[-1] < lrs[50]                     # cosine decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9          # floor


def test_adamw_minimises_quadratic():
    cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_grad_clipping():
    cfg = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0)
    params = {"x": jnp.zeros((4,))}
    state = init_opt_state(params)
    huge = {"x": jnp.full((4,), 1e6)}
    p2, state, m = adamw_update(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e5           # reported raw norm
    assert np.isfinite(np.asarray(p2["x"])).all()
    # post-clip first moment bounded by (1-b1) * clip_norm
    assert float(jnp.abs(state["m"]["x"]).max()) <= 1.0


def test_weight_decay_mask():
    """Norm-like leaves ('ln1', 'bias') are not decayed."""
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=1.0, clip_norm=1e9)
    params = {"wq": jnp.ones((2, 2)), "ln1": jnp.ones((2,))}
    state = init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, params, zero_g, state)
    assert float(p2["wq"][0, 0]) < 1.0           # decayed
    assert float(p2["ln1"][0]) == pytest.approx(1.0)  # not decayed


def test_moments_are_f32():
    params = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    state = init_opt_state(params)
    assert state["m"]["w"].dtype == jnp.float32
    assert state["v"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    p2, s2, _ = adamw_update(OptConfig(), params, grads, state)
    assert p2["w"].dtype == jnp.bfloat16         # params keep their dtype
    assert s2["v"]["w"].dtype == jnp.float32


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
