"""Reproduction of the paper's result tables (II-VI).

The simulator is deterministic (no KVM jitter), so measured latencies must
match the 'Expected' column exactly for every N in {2..5} — the paper's
real-hardware samples deviate by ~1.4% due to KVM mode; Section V
attributes all deviation to measurement noise, not model error.
"""

import pytest

from repro.core import isa
from repro.core.machine import get_machine
from repro.core.microbench import latency_table, measure_latency
from repro.core.whatif import scale_table

# Tables II & III: MI200, {instr: expected_cycles}
MI200_EXPECTED = {
    "fp64_16x16x4fp64": 32,
    "fp32_4x4x1fp32": 8,
    "fp32_16x16x4fp32": 32,
    "fp32_16x16x16fp16": 32,
    "i32_16x16x16i8": 32,
    "fp64_4x4x4fp64": 16,
    "fp32_4x4x4fp16": 8,
}

# Tables IV & V: MI300 (fp16 16x16x16 halved; i8 16x16x16 removed)
MI300_EXPECTED = {
    "fp64_16x16x4fp64": 32,
    "fp32_4x4x1fp32": 8,
    "fp32_16x16x4fp32": 32,
    "fp32_16x16x16fp16": 16,
    "fp64_4x4x4fp64": 16,
    "fp32_4x4x4fp16": 8,
}


@pytest.mark.parametrize("gpu,expected", [("mi200", MI200_EXPECTED),
                                          ("mi300", MI300_EXPECTED)])
def test_tables_latency(gpu, expected):
    t = latency_table(get_machine(gpu))
    assert set(t) == set(expected)
    for name, exp in expected.items():
        for n in (2, 3, 4, 5):
            assert t[name][n] == pytest.approx(exp), (name, n)


def test_mi300_improved_fp16_latency():
    """Section III-A: MI300 halves fp32_16x16x16fp16 (32 -> 16 cycles)."""
    assert isa.mfma_cycles("mi200", "fp32_16x16x16fp16") == 32
    assert isa.mfma_cycles("mi300", "fp32_16x16x16fp16") == 16


def test_i8_removed_on_mi300():
    """Section III-A: i32_16x16x16i8 was removed on MI300."""
    assert isa.mfma_cycles("mi200", "i32_16x16x16i8") == 32
    with pytest.raises(isa.UnsupportedInstructionError):
        isa.mfma_cycles("mi300", "i32_16x16x16i8")


def test_table_vi_scale2():
    """Table VI: --mfma-scale=2 doubles every measured MI300 latency."""
    m = get_machine("mi300")
    t = scale_table(m, scales=(1.0, 2.0))
    for name, per_scale in t.items():
        assert per_scale[2.0] == pytest.approx(2 * per_scale[1.0]), name


@pytest.mark.parametrize("scale", [0.5, 1.5, 3.0])
def test_scale_generalises(scale):
    m = get_machine("mi300", mfma_scale=scale)
    got = measure_latency(m, "fp64_16x16x4fp64", 4)
    assert got == pytest.approx(round(32 * scale))


def test_gpr_idx_instructions_unsupported():
    """Section VI: s_set_gpr_idx-mode MFMAs are not implemented."""
    for name in ("fp32_32x32x8fp16", "fp32_32x32x1fp32"):
        with pytest.raises(isa.UnsupportedInstructionError):
            isa.mfma_cycles("mi200", name)


def test_padding_does_not_change_measurement():
    """Blue-highlighted rows needed i-cache padding on real HW; in the
    deterministic simulator padding must leave Eq. 1's answer unchanged."""
    m = get_machine("mi200")
    for pad in (0, 4, 16):
        assert measure_latency(m, "fp32_16x16x4fp32", 3,
                               padding_nops=pad) == pytest.approx(32)
