"""GPipe pipeline: schedule correctness on a real multi-device axis
(subprocess with 4 fake devices) + bubble accounting."""

import subprocess
import sys
import textwrap

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(2, 14) == 1 / 15


def test_gpipe_matches_sequential():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import gpipe_apply

        mesh = jax.make_mesh((4,), ("pod",))
        n_stages, n_micro, mb, d = 4, 6, 2, 8
        ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage(w, h):
            return jnp.tanh(h @ w)

        got = gpipe_apply(stage, ws, x, mesh=mesh, axis="pod")

        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=600)
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
