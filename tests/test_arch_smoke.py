"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU; output shapes +
no-NaN asserted.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.models.model import decode_step, prefill
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

B, S = 2, 64


def _batch(cfg, with_labels=True):
    rng = np.random.RandomState(0)
    b = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)
    if cfg.cross_attn:
        b["media"] = jnp.asarray(
            rng.randn(B, cfg.cross_attn.n_media_tokens, cfg.d_model) * 0.1,
            jnp.bfloat16)
    if cfg.encoder:
        b["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder.n_frames, cfg.d_model) * 0.1,
            jnp.bfloat16)
    return b


@pytest.fixture(scope="module")
def smoke(request):
    return {}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                          total_steps=10))
    opt = init_opt_state(params)
    p2, opt2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(opt2["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert d0.shape == d1.shape
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, with_labels=False)
    # prefill: last-token logits + cache
    logits, cache = prefill(cfg, params, batch, max_len=S + 8)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # decode two steps from the prefilled cache
    pos = jnp.int32(S)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for i in range(2):
        logits_d, cache = decode_step(cfg, params, cache, tok, pos + i)
        assert logits_d.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits_d, np.float32)).all(), arch
        tok = jnp.argmax(logits_d[:, -1], axis=-1).astype(jnp.int32)[:, None]


def test_prefill_decode_consistency():
    """Teacher-forced decode after prefill == train forward logits (dense)."""
    from repro.models.model import forward
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, with_labels=False)
    full_logits, _ = forward(cfg, params, batch, mode="train")
    # prefill on the first S-1 tokens, then decode token S-1
    short = {"tokens": batch["tokens"][:, :S - 1]}
    _, cache = prefill(cfg, params, short, max_len=S)
    logits_d, _ = decode_step(cfg, params, cache,
                              batch["tokens"][:, S - 1:S], jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_ssm_prefill_decode_consistency():
    """Same consistency for the SSD recurrence (state handoff)."""
    from repro.models.model import forward
    cfg = get_config("mamba2-370m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    batch = _batch(cfg, with_labels=False)
    full_logits, _ = forward(cfg, params, batch, mode="train")
    short = {"tokens": batch["tokens"][:, :S - 1]}
    _, cache = prefill(cfg, params, short, max_len=S)
    logits_d, _ = decode_step(cfg, params, cache,
                              batch["tokens"][:, S - 1:S], jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=3e-2, atol=3e-2)
