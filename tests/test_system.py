"""End-to-end behaviour: a tiny LM actually LEARNS the synthetic stream
(loss decreases substantially), through the full production stack — data
pipeline -> train step (grad accumulation) -> fault-tolerant controller ->
checkpoint -> serve engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.train.fault_tolerance import FailureInjector, TrainController
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def test_end_to_end_learns(tmp_path):
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                        weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))
    data = SyntheticLM(cfg.vocab_size, batch=8, seq_len=64, seed=0,
                       correlation=1.0)

    def data_fn(i):
        return {k: jnp.asarray(v) for k, v in data(i).items()}

    ctl = TrainController(step, tmp_path / "ck", ckpt_every=20,
                          injector=FailureInjector(at_steps=[30]))
    state = (params, init_opt_state(params))
    state, log = ctl.run(state, data_fn, n_steps=60)

    losses = [e["loss"] for e in log if "loss" in e]
    first = float(np.mean(losses[:5]))
    last = float(np.mean(losses[-5:]))
    assert ctl.restarts == 1                    # failure happened + recovered
    assert last < first - 1.0, (first, last)    # actually learned

    # the learned model predicts the fixed permutation greedily
    eng = ServeEngine(cfg, state[0], max_len=96)
    prompt = data(999)["tokens"][:2, :16]
    res = eng.generate(prompt, n_steps=8)
    want = prompt[:, -1]
    got = res.tokens[:, 0]
    acc = float((got == data._perm[want]).mean())
    assert acc >= 0.5, acc                      # >> 1/512 chance level
