"""Listing-1 microbenchmark construction + Eq. 1 extraction."""

import pytest

from repro.core.machine import get_machine
from repro.core.microbench import (build_listing1, eq1_latency,
                                   measure_latency)
from repro.core.scoreboard import simulate_program

M = get_machine("mi200")


def test_listing1_structure():
    prog = build_listing1("fp32_4x4x1fp32", 4, padding_nops=2)
    ops = [i.opcode for i in prog]
    assert ops == ["s_waitcnt", "s_nop", "s_nop", "s_memtime",
                   "mfma", "mfma", "mfma", "mfma", "s_memtime", "s_waitcnt"]


def test_listing1_needs_two_mfma():
    """The final MFMA isn't waited on (no data dep on s_memtime) — one
    MFMA alone is unmeasurable (Section IV-C)."""
    with pytest.raises(ValueError):
        build_listing1("fp32_4x4x1fp32", 1)


def test_eq1_roundtrip():
    for name, lat in [("fp32_4x4x1fp32", 8), ("fp64_16x16x4fp64", 32)]:
        for n in (2, 3, 4, 5):
            assert measure_latency(M, name, n) == pytest.approx(lat)


def test_final_mfma_not_counted():
    """T_total includes only (N-1) MFMAs + probe overhead: the second
    s_memtime doesn't wait for the last MFMA (scalar pipe independence)."""
    prog = build_listing1("fp32_16x16x4fp32", 3)
    res = simulate_program(M, prog)
    end = res.by_tag("end")
    last_mfma = res.by_tag("mfma2")
    assert end.issue < last_mfma.complete  # probe raced ahead of MFMA #3


def test_eq1_formula_direct():
    assert eq1_latency(2 * 32 + M.t_memtime + M.t_inst, 3, M) == pytest.approx(32)
