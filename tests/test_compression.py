"""int8 gradient compression: quantisation error bounds, error feedback,
and the shard_map int8 all-reduce (subprocess with 8 fake devices)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (compress_decompress, dequantize,
                                        init_residuals, quantize)


def test_quantize_bounds():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 3.0
    q, scale = quantize(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-6  # round-to-nearest bound


def test_error_feedback_reduces_bias():
    """With EF, the RUNNING SUM of compressed grads tracks the true sum
    (quantisation error is carried, not lost)."""
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (32, 32)) * 0.01}
    res = init_residuals(grads)
    total_hat = np.zeros((32, 32), np.float32)
    total_true = np.zeros((32, 32), np.float32)
    for i in range(20):
        g = {"w": grads["w"] * (1.0 + 0.1 * i)}
        g_hat, res = compress_decompress(g, res)
        total_hat += np.asarray(g_hat["w"], np.float32)
        total_true += np.asarray(g["w"], np.float32)
    # residual carries what the sum is missing
    gap = np.abs(total_true - total_hat - np.asarray(res["w"]))
    assert gap.max() < 1e-4


def test_compress_is_noop_for_zero():
    g = {"w": jnp.zeros((8, 8))}
    g_hat, res = compress_decompress(g, init_residuals(g))
    np.testing.assert_array_equal(np.asarray(g_hat["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(res["w"]), 0.0)


def test_int8_psum_multidevice():
    """shard_map int8 all-reduce over a real 8-device 'pod' axis matches
    the f32 mean within quantisation tolerance (subprocess: fake devices)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.compression import int8_psum

        mesh = jax.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 2.0

        f = shard_map(lambda a: int8_psum(a, "pod"), mesh=mesh,
                      in_specs=P("pod"), out_specs=P("pod"))
        got = np.asarray(f(x))
        want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), (8, 128))
        err = np.abs(got - np.repeat(want[:1], 8, 0))
        scale = np.abs(np.asarray(x)).max() / 127.0
        assert err.max() <= scale * 1.5, (err.max(), scale)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
