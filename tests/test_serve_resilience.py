"""Graceful degradation for the paged serve engine.

Pins the ISSUE 10 acceptance contracts: every request reaches exactly
one terminal status with refcount-exact block reclamation, deadlines
and cancellations fire queued or in-flight, overload sheds instead of
growing the queue without bound, mid-flight pool exhaustion preempts
and recomputes instead of deadlocking, and — the load-bearing one — a
preempted-then-recomputed request emits bit-identical greedy tokens to
an uninterrupted run (the PR 7 aligned-T recipe, now under preemption).

The fault-injection harness is exercised three ways: hand-written plans
that force each fault kind, a seeded ``FaultPlan.random`` chaos sweep
(any red run names its seed and replays exactly), and per-tick
``PagedKVCache.check_invariants()`` which the engine asserts after
every tick whenever a plan is active.

The allocator gets a property test (random op interleavings preserve
the invariants) via the optional-hypothesis shim, plus a deterministic
rng stress twin so the coverage exists even without hypothesis.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_config
from repro.fleet.capacity import simulate_trace
from repro.models import init_params
from repro.serve import (CANCELLED, OK, PREEMPTED, SHED, STATUSES, TIMEOUT,
                         DeadlineAwareShed, Fault, FaultPlan, FIFOPolicy,
                         PagedKVCache, PagedServeEngine, QueueCapPolicy,
                         Request, ServeEngine, min_service_ticks)
from tests._hypothesis_compat import given, settings, st

CFG = get_config("qwen2-7b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
PAGE = 128


def _engine(**kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_batch", 2)
    kw.setdefault("page", PAGE)
    return PagedServeEngine(CFG, PARAMS, **kw)


def _requests(specs, seed=7, **extra):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, CFG.vocab_size, (s,))
                    .astype(np.int32), n_steps=n, arrival=a, **extra)
            for s, n, a in specs]


# ---------------------------------------------------------------------------
# resilience.py host logic (no jax)
# ---------------------------------------------------------------------------

def test_min_service_ticks():
    # 1 chunk covering the prompt + first token, then n-1 decode ticks
    assert min_service_ticks(8, 1, 32) == 1
    assert min_service_ticks(8, 5, 32) == 5
    assert min_service_ticks(64, 5, 32) == 6       # 2 chunks + 4 decodes
    assert min_service_ticks(65, 5, 32) == 7
    assert min_service_ticks(0, 3, 32) == 3        # empty prompt still ticks


def test_queue_cap_policy_sheds_newest_first():
    from repro.serve.resilience import queue_entries
    reqs = _requests([(8, 4, 0), (8, 4, 1), (8, 4, 2)])
    entries = queue_entries(5, [0, 1, 2], reqs, 32)
    shed = QueueCapPolicy(2).shed(5, entries)
    assert [rid for rid, _ in shed] == [2]          # newest arrival goes
    assert "max_queue 2" in shed[0][1]
    assert QueueCapPolicy(3).shed(5, entries) == []
    with pytest.raises(ValueError, match="max_queue"):
        QueueCapPolicy(0)


def test_deadline_aware_shed_rejects_only_unreachable():
    from repro.serve.resilience import queue_entries
    reqs = [Request(prompt=np.zeros(8, np.int32), n_steps=4, arrival=0,
                    deadline=3),                    # needs 4 ticks: t3 ok
            Request(prompt=np.zeros(8, np.int32), n_steps=4, arrival=0,
                    deadline=2),                    # finish t3 > 2: doomed
            Request(prompt=np.zeros(8, np.int32), n_steps=4, arrival=0)]
    entries = queue_entries(0, [0, 1, 2], reqs, 32)
    shed = DeadlineAwareShed().shed(0, entries)
    assert [rid for rid, _ in shed] == [1]
    assert "unreachable" in shed[0][1]
    assert DeadlineAwareShed(slack=1).shed(0, entries) == []
    assert FIFOPolicy().shed(0, entries) == []


def test_fault_validation_and_periodic_firing():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("melt", tick=0)
    with pytest.raises(ValueError, match="tick"):
        Fault("stall", tick=-1)
    with pytest.raises(ValueError, match="duration"):
        Fault("stall", tick=0, duration=0)
    with pytest.raises(ValueError, match="every"):
        Fault("exhaust", tick=0, every=0)
    f = Fault("preempt", tick=4, every=3, until=10)
    assert [t for t in range(14) if f.fires_at(t)] == [4, 7, 10]
    one = Fault("preempt", tick=4)
    assert [t for t in range(14) if one.fires_at(t)] == [4]


def test_fault_plan_effects_are_pure_functions_of_tick():
    plan = FaultPlan(seed=1, faults=[
        Fault("exhaust", tick=2, n=3, duration=2),
        Fault("preempt", tick=5, n=2),
        Fault("preempt", tick=5),
        Fault("stall", tick=7, duration=2),
        Fault("stall", tick=20, every=5, until=30, duration=2)])
    assert [f.n for f in plan.seizures(2)] == [3]
    assert plan.seizures(3) == []
    assert plan.forced_preemptions(5) == 3          # 2 + default 1
    assert plan.forced_preemptions(6) == 0
    assert plan.stalled(7) and plan.stalled(8) and not plan.stalled(9)
    # periodic stall: 2-tick windows at 20, 25, 30 — `until` bounds the
    # whole window, so the tick-30 firing is clipped to a single tick
    assert [t for t in range(19, 33) if plan.stalled(t)] == \
        [20, 21, 25, 26, 30]
    # replay: same queries give same answers (no hidden run state)
    assert plan.forced_preemptions(5) == 3
    with pytest.raises(TypeError, match="Fault objects"):
        FaultPlan(faults=["stall"])


def test_fault_plan_random_is_reproducible():
    a = FaultPlan.random(3, horizon=40)
    b = FaultPlan.random(3, horizon=40)
    assert a.faults == b.faults and a.seed == 3
    assert len(a.faults) == 6
    assert all(0 <= f.tick < 40 for f in a.faults)
    assert FaultPlan.random(4, horizon=40).faults != a.faults


# ---------------------------------------------------------------------------
# PagedKVCache invariants: example, stress, and property coverage
# ---------------------------------------------------------------------------

def _apply_ops(pc, ops):
    """Drive the allocator through an op script, mirroring how the
    engine holds references; invalid ops (refused by the cache) are
    skipped — the property is that *accepted* ops preserve invariants."""
    rng = np.random.default_rng(0)
    held = []                                       # engine-side ownership
    registered = 0
    for kind, arg in ops:
        if kind == "alloc":
            ids = pc.alloc(arg)
            if ids is not None:
                held.append(ids)
        elif kind == "free" and held:
            pc.free(held.pop(arg % len(held)))
        elif kind == "acquire" and held:
            ids = held[arg % len(held)]
            pc.acquire(ids)
            held.append(list(ids))
        elif kind == "register" and held:
            ids = held[arg % len(held)]
            toks = rng.integers(0, 97, (len(ids) * pc.page,))
            registered += 1
            pc.register_prefix(toks.astype(np.int32), ids)
        elif kind == "fork" and held and pc.free_blocks >= 1:
            ids = held[arg % len(held)]
            b = ids[arg % len(ids)]
            ids[ids.index(b)] = pc.fork(b)
        pc.check_invariants()
    for ids in held:
        pc.free(ids)
    pc.check_invariants()


_OP_KINDS = ("alloc", "free", "acquire", "register", "fork")


def test_cache_invariants_under_deterministic_stress():
    """Hypothesis-free twin of the property test below: 300 random ops
    from a fixed seed, invariants checked after every accepted op (and
    park/evict paths exercised via register + realloc)."""
    rng = np.random.default_rng(42)
    pc = PagedKVCache(CFG, n_blocks=9, page=PAGE)
    ops = [(_OP_KINDS[int(rng.integers(0, len(_OP_KINDS)))],
            int(rng.integers(0, 8))) for _ in range(300)]
    _apply_ops(pc, ops)
    assert pc.free_blocks == pc.capacity            # everything reclaimed


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(_OP_KINDS),
                          st.integers(min_value=0, max_value=7)),
                max_size=60))
def test_cache_invariants_property(ops):
    """Random interleavings of alloc/acquire/free/park/evict/fork must
    preserve check_invariants() after every accepted op — the refcount
    leaks example-based tests can't reach."""
    _apply_ops(PagedKVCache(CFG, n_blocks=6, page=PAGE), ops)


def test_check_invariants_catches_seeded_corruption():
    pc = PagedKVCache(CFG, n_blocks=5, page=PAGE)
    ids = pc.alloc(2)
    pc.check_invariants()
    pc._refs[ids[0]] = 0                            # leak: held but unowned
    with pytest.raises(AssertionError):
        pc.check_invariants()
    pc._refs[ids[0]] = 1
    pc.check_invariants()
    pc._fresh.append(ids[1])                        # double-owned
    with pytest.raises(AssertionError):
        pc.check_invariants()


# ---------------------------------------------------------------------------
# Terminal states on the engine
# ---------------------------------------------------------------------------

def test_deadline_timeout_in_flight_keeps_partial_tokens():
    eng = _engine()
    trace = _requests([(8, 40, 0)])
    trace[0].deadline = 5
    results, stats = eng.run(trace)
    (r,) = results
    assert r.status == TIMEOUT and "deadline 5" in r.detail
    assert 0 < len(r.tokens) < 40                   # partial stream kept
    assert r.admitted == 0 and r.finished == 6      # fired at tick 6 > 5
    assert stats.timeouts == 1 and stats.completed == 0
    assert eng.cache.free_blocks == eng.cache.capacity
    eng.cache.check_invariants()


def test_deadline_timeout_while_queued_never_admits():
    eng = _engine(max_batch=1)
    trace = _requests([(8, 30, 0), (8, 30, 0)])
    trace[1].deadline = 4                           # dies behind request 0
    results, stats = eng.run(trace)
    assert [r.status for r in results] == [OK, TIMEOUT]
    assert results[1].admitted == -1 and len(results[1].tokens) == 0
    assert "while queued" in results[1].detail
    assert stats.timeouts == 1 and stats.completed == 1


def test_cancellation_queued_and_in_flight():
    eng = _engine(max_batch=1)
    trace = _requests([(8, 30, 0), (8, 30, 0), (8, 6, 0)])
    trace[0].cancel_at = 3                          # in flight by then
    trace[1].cancel_at = 1                          # still queued
    results, stats = eng.run(trace)
    assert [r.status for r in results] == [CANCELLED, CANCELLED, OK]
    assert 0 < len(results[0].tokens) < 30
    assert len(results[1].tokens) == 0 and results[1].admitted == -1
    assert stats.cancelled == 2 and stats.completed == 1
    assert len(results[2].tokens) == 6
    assert eng.cache.free_blocks == eng.cache.capacity


def test_max_queue_sheds_newest_with_reason():
    eng = _engine(max_batch=1, max_queue=2)
    trace = _requests([(8, 12, 0), (8, 12, 0), (8, 12, 0), (8, 12, 0)])
    results, stats = eng.run(trace)
    statuses = [r.status for r in results]
    # the cap bounds the queue BEFORE admission runs: 4 arrive at tick 0,
    # the 2 newest are shed, the 2 oldest keep their FIFO claim
    assert statuses == [OK, OK, SHED, SHED]
    assert "max_queue 2" in results[3].detail
    assert stats.shed == 2 and stats.completed == 2


def test_deadline_aware_shed_policy_on_engine():
    eng = _engine(max_batch=1, admission=DeadlineAwareShed())
    trace = _requests([(8, 30, 0), (8, 30, 0)])
    trace[1].deadline = 10                          # unreachable behind r0
    results, stats = eng.run(trace)
    assert [r.status for r in results] == [OK, SHED]
    assert "unreachable" in results[1].detail
    # shed beats timing out: rejected the moment it became doomed, not
    # after burning queue time until the deadline passed
    assert results[1].finished < 10
    assert stats.shed == 1 and stats.timeouts == 0


def test_oversized_request_error_names_capacity_and_need():
    eng = _engine(max_len=192, n_blocks=2)          # capacity 1 block
    trace = _requests([(100, 60, 0)])               # needs 2 blocks
    with pytest.raises(ValueError) as ei:
        eng.run(trace)
    msg = str(ei.value)
    assert "needs 2 blocks" in msg
    assert "capacity is 1 blocks" in msg
    assert "n_blocks >= 3" in msg
    with pytest.raises(ValueError, match="max_len"):
        eng.run(_requests([(150, 60, 0)]))          # 210 > max_len 192


# ---------------------------------------------------------------------------
# Preemption: organic exhaustion, forced faults, and bitwise parity
# ---------------------------------------------------------------------------

def test_organic_preemption_recompute_is_bit_identical():
    """THE regression this PR exists for: a pool too small for both
    growing requests forces preempt-and-recompute, and the preempted
    stream must match both an uncontended paged run and the synchronous
    aligned-T oracle bit for bit."""
    trace = _requests([(8, 150, 0), (8, 140, 0)], seed=11)
    roomy = PagedServeEngine(CFG, PARAMS, max_len=384, max_batch=2,
                             page=PAGE)
    r_results, r_stats = roomy.run(trace)
    assert r_stats.preemptions == 0

    # capacity 3 < the 4 blocks both requests eventually need: the
    # second request self-preempts at its page boundary and recomputes
    tight = PagedServeEngine(CFG, PARAMS, max_len=384, max_batch=2,
                             page=PAGE, n_blocks=4, check_invariants=True)
    t_results, t_stats = tight.run(trace, max_ticks=2000)
    assert t_stats.preemptions >= 1
    assert [r.status for r in t_results] == [OK, OK]
    assert any(r.preemptions > 0 for r in t_results)
    for a, b in zip(r_results, t_results):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert tight.cache.free_blocks == tight.cache.capacity

    oracle = ServeEngine(CFG, PARAMS, max_len=384, prefill_pad=True)
    o_results, _ = oracle.run(trace)
    for a, b in zip(o_results, t_results):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_forced_preemption_fault_is_bit_identical():
    trace = _requests([(8, 20, 0), (12, 16, 0)])
    eng = _engine()
    clean, _ = eng.run(trace)
    plan = FaultPlan(faults=[Fault("preempt", tick=4, n=1)])
    faulted, stats = _engine().run(trace, fault_plan=plan, max_ticks=500)
    assert stats.preemptions >= 1
    assert [r.status for r in faulted] == [OK, OK]
    for a, b in zip(clean, faulted):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_preemption_budget_is_terminal():
    """max_preemptions=0: the first eviction is final — partial tokens
    kept, status PREEMPTED, blocks reclaimed."""
    trace = _requests([(8, 20, 0), (12, 16, 0)])
    plan = FaultPlan(faults=[Fault("preempt", tick=4, n=1)])
    eng = _engine(max_preemptions=0)
    results, stats = eng.run(trace, fault_plan=plan, max_ticks=500)
    statuses = sorted(r.status for r in results)
    assert statuses == [OK, PREEMPTED]
    victim = next(r for r in results if r.status == PREEMPTED)
    assert victim.preemptions == 1 and "max_preemptions=0" in victim.detail
    assert stats.preemptions == 1
    assert eng.cache.free_blocks == eng.cache.capacity


# ---------------------------------------------------------------------------
# The chaos harness: exhaustion mid-flight, stalls, seeded sweeps
# ---------------------------------------------------------------------------

def test_exhaustion_fault_mid_flight_completes_without_deadlock():
    """ISSUE acceptance: seize the whole pool mid-flight, stall the data
    plane, force preemptions — the run must still terminate with every
    request in a terminal state and invariants green after every tick
    (the engine asserts them itself whenever a fault_plan is active)."""
    trace = _requests([(8, 24, 0), (40, 16, 0), (12, 20, 2), (8, 12, 4)])
    trace[2].deadline = 30
    trace[3].cancel_at = 18
    plan = FaultPlan(seed=0, faults=[
        Fault("exhaust", tick=3, n=None, duration=4),   # seize everything
        Fault("stall", tick=9, duration=2),
        Fault("preempt", tick=13, n=2),
        Fault("exhaust", tick=16, n=2, duration=3)])
    eng = _engine(max_batch=2, n_blocks=4)
    results, stats = eng.run(trace, fault_plan=plan, max_ticks=1000)
    assert len(results) == len(trace)
    assert all(r.status in STATUSES for r in results)
    assert stats.stalled_ticks == 2
    assert stats.preemptions >= 1
    assert stats.completed + stats.shed + stats.timeouts \
        + stats.cancelled \
        + sum(1 for r in results if r.status == PREEMPTED) \
        == stats.requests
    assert eng.cache.free_blocks == eng.cache.capacity  # nothing leaked
    eng.cache.check_invariants()


def test_seizure_outliving_run_is_released():
    """A seizure window can extend past the last request's completion
    (seed 10 of the CI sweep found this): the engine must hand the
    fault-held blocks back when the run drains, not leak them."""
    trace = _requests([(8, 4, 0)])
    plan = FaultPlan(faults=[Fault("exhaust", tick=1, n=2, duration=500)])
    eng = _engine(max_batch=2, n_blocks=5, check_invariants=True)
    results, _ = eng.run(trace, fault_plan=plan, max_ticks=1000)
    assert results[0].status == OK
    assert eng.cache.free_blocks == eng.cache.capacity


def test_random_fault_plans_seed_sweep():
    """Chaos sweep: any seed's plan must terminate every request and
    keep the pool conserved; a failure names its seed for exact replay."""
    trace = _requests([(8, 10, 0), (16, 8, 1), (8, 12, 3)])
    for seed in range(4):
        plan = FaultPlan.random(seed, horizon=25)
        eng = _engine(max_batch=2, n_blocks=4)
        results, _ = eng.run(trace, fault_plan=plan, max_ticks=3000)
        assert len(results) == len(trace), f"seed {seed}"
        assert all(r.status in STATUSES for r in results), f"seed {seed}"
        assert eng.cache.free_blocks == eng.cache.capacity, f"seed {seed}"


def test_stall_fault_ages_deadlines():
    """Stalls lose data-plane ticks but the control plane keeps running:
    a deadline that fits without the stall times out under it."""
    trace = _requests([(8, 10, 0)])
    trace[0].deadline = 11
    clean, _ = _engine().run(trace)
    assert clean[0].status == OK
    plan = FaultPlan(faults=[Fault("stall", tick=1, duration=6)])
    stalled, stats = _engine().run(trace, fault_plan=plan, max_ticks=200)
    assert stalled[0].status == TIMEOUT
    assert stats.stalled_ticks == 6


def test_check_invariants_flag_without_faults():
    eng = _engine(check_invariants=True)
    results, _ = eng.run(_requests([(8, 6, 0), (12, 5, 1)]))
    assert [r.status for r in results] == [OK, OK]


# ---------------------------------------------------------------------------
# The fleet replica stays tick-exact under resilience
# ---------------------------------------------------------------------------

def test_simulate_trace_tick_exact_on_overload_with_faults():
    """The calibration contract extended to the degraded regime: same
    trace, same policies, same FaultPlan — every tick counter and every
    resilience counter must match the real engine exactly."""
    from repro.serve.traces import get_trace
    trace = get_trace("overload")(10, CFG.vocab_size, seed=3)
    plan = FaultPlan(faults=[Fault("exhaust", tick=4, n=2, duration=3),
                             Fault("preempt", tick=8, n=1),
                             Fault("stall", tick=11, duration=2)])
    policy = DeadlineAwareShed(slack=2)
    eng = PagedServeEngine(CFG, PARAMS, max_len=160, max_batch=2,
                           page=PAGE, prefix_cache=False, max_queue=4,
                           admission=policy)
    _, stats = eng.run(trace, fault_plan=plan, max_ticks=5000)
    sim = simulate_trace(trace, max_len=160, max_batch=2, page=PAGE,
                         n_blocks=eng.cache.n_blocks, prefill_chunk=32,
                         max_queue=4, admission=policy, fault_plan=plan,
                         max_ticks=5000)
    for field in ("requests", "tokens", "ticks", "decode_steps",
                  "prefill_chunks", "completed", "shed", "timeouts",
                  "cancelled", "preemptions", "stalled_ticks"):
        assert getattr(sim, field) == stats[field], field
    assert sim.occupancy_max == pytest.approx(stats["occupancy_max"])
    assert stats.shed + stats.timeouts > 0          # overload actually bit
