"""Fault-tolerant controller: injected failures, restart/replay determinism,
straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.train.fault_tolerance import (FailureInjector, StragglerStats,
                                         TrainController)
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

CFG = get_config("qwen2-7b").reduced()
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)


def _setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(CFG, OPT))
    data = SyntheticLM(CFG.vocab_size, batch=2, seq_len=32, seed=1)

    def data_fn(step_idx):
        b = data(step_idx)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return (params, opt), step, data_fn


def _leaves(state):
    return [np.asarray(x, np.float32) for x in jax.tree.leaves(state[0])]


def test_run_without_failures(tmp_path):
    state, step, data_fn = _setup()
    ctl = TrainController(step, tmp_path / "ck", ckpt_every=4)
    state, log = ctl.run(state, data_fn, n_steps=6)
    losses = [e["loss"] for e in log if "loss" in e]
    assert len(losses) == 6
    assert all(np.isfinite(losses))


def test_failure_restart_matches_uninterrupted(tmp_path):
    """Kill the 'node' mid-run; restart must replay to EXACTLY the same
    final parameters as an uninterrupted run (deterministic data+step)."""
    state_a, step, data_fn = _setup()
    ctl_a = TrainController(step, tmp_path / "a", ckpt_every=3)
    state_a, _ = ctl_a.run(state_a, data_fn, n_steps=9)

    state_b, step_b, data_fn_b = _setup()
    ctl_b = TrainController(step_b, tmp_path / "b", ckpt_every=3,
                            injector=FailureInjector(at_steps=[5, 7]))
    state_b, log_b = ctl_b.run(state_b, data_fn_b, n_steps=9)
    assert ctl_b.restarts == 2
    assert any(e.get("event") == "restart" for e in log_b)
    for a, b in zip(_leaves(state_a), _leaves(state_b)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_restart_budget(tmp_path):
    state, step, data_fn = _setup()
    ctl = TrainController(step, tmp_path / "c", ckpt_every=100,
                          injector=FailureInjector(at_steps=[2]),
                          max_restarts=0)
    import pytest
    with pytest.raises(RuntimeError):
        # failure at step 2 with no checkpoint and no restart budget
        ctl.run(state, data_fn, n_steps=5)


def test_straggler_detection():
    s = StragglerStats(beta=0.5)
    assert not s.observe(0, 1.0, factor=3.0)   # primes the EMA
    assert not s.observe(1, 1.1, factor=3.0)
    assert s.observe(2, 10.0, factor=3.0)      # 10x the EMA -> straggler
    assert s.events and s.events[0]["step"] == 2


def test_straggler_hook_called(tmp_path):
    state, step, data_fn = _setup()
    seen = []
    ctl = TrainController(step, tmp_path / "d", ckpt_every=100,
                          straggler_factor=0.0,  # everything is "slow"
                          on_straggler=lambda s, dt: seen.append(s))
    ctl.run(state, data_fn, n_steps=3)
    assert seen  # hook fired
