"""Elastic scaling: a checkpoint saved on ONE device restores onto an
8-device production-style mesh with FSDP/TP shardings (subprocess with
fake devices) — the restart-on-different-cluster-size path."""

import subprocess
import sys
import textwrap

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.train.checkpoint import save


def test_save_one_device_restore_eight(tmp_path):
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    save(tmp_path, 42, params)

    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_CPU_F32_DOTS"] = "1"
        import sys; sys.path.insert(0, "src")
        import jax, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.models import init_params
        from repro.models.model import param_axes_rule
        from repro.parallel.api import logical_to_spec
        from repro.train.checkpoint import restore

        cfg = get_config("qwen2-7b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        like = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.random.PRNGKey(0))

        # path-keyed shardings (the elastic-restore contract)
        specs = {{}}
        import jax.tree_util as jtu
        for path, leaf in jtu.tree_flatten_with_path(like)[0]:
            key = "::".join(str(p.key) if hasattr(p, "key") else
                            "#%d" % p.idx for p in path)
            specs[key] = NamedSharding(
                mesh, logical_to_spec(leaf.shape, param_axes_rule(path, leaf),
                                      mesh))

        restored, step = restore(r"{tmp_path}", like,
                                 sharding_fn=lambda k, a: specs[k])
        assert step == 42
        leaves = jax.tree.leaves(restored)
        # sharded across the 8 devices, and values intact
        assert any(len(l.sharding.device_set) == 8 for l in leaves)
        total = float(sum(np.abs(np.asarray(l, np.float32)).sum()
                          for l in leaves))
        assert np.isfinite(total) and total > 0
        print("OK", step, len(leaves))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=600)
    assert "OK 42" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
