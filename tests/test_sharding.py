"""logical_to_spec divisibility guard + rule behaviour (no fake devices:
uses a (1,1) mesh for plumbing and pure-function checks for the guard),
plus the op-level shard_assignment/local_shapes contract the sharded
kernel dispatch plans against."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.api import (AxisSpec, local_shapes, logical_to_spec,
                                set_mesh, shard, shard_assignment,
                                current_mesh)


class _FakeMesh:
    """Duck-typed mesh exposing .shape for guard tests."""
    def __init__(self, shape):
        self.shape = shape


def _spec(shape, logical, mesh_shape):
    return logical_to_spec(shape, logical, _FakeMesh(mesh_shape))


def test_divisible_dims_shard():
    assert _spec((64000, 7168), ("vocab", "fsdp"),
                 {"data": 16, "model": 16}) == P("model", "data")


def test_indivisible_dims_drop():
    # 51865 % 16 != 0 -> vocab axis dropped
    assert _spec((51865, 512), ("vocab", "fsdp"),
                 {"data": 16, "model": 16}) == P(None, "data")


def test_axis_used_once():
    # batch takes pod+data; fsdp (data) already consumed -> dropped
    assert _spec((256, 4096, 16), ("batch", "seq", "fsdp"),
                 {"pod": 2, "data": 16, "model": 16}) \
        == P(("pod", "data"), "model", None)


def test_batch_multi_axis_partial():
    # batch 8 on (pod=2, data=16): pod divides, pod*data doesn't -> pod only
    assert _spec((8, 10), ("batch", None), {"pod": 2, "data": 16}) \
        == P("pod", None)


def test_kv_seq_uses_model_then_data():
    # long_500k: batch 1 -> both axes free for the sequence
    assert _spec((1, 524288, 8, 128), ("batch", "kv_seq", None, None),
                 {"data": 16, "model": 16}) == P(None, ("model", "data"), None,
                                                 None)


def test_missing_axis_ignored():
    assert _spec((128, 128), ("batch", None), {"model": 4}) == P(None, None)


def test_no_mesh_is_noop():
    import jax.numpy as jnp
    assert current_mesh() is None
    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)  # must not raise without a mesh
    assert (y == x).all()


def test_set_mesh_plumbing():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh):
        assert current_mesh() is mesh
        spec = logical_to_spec((16, 16), ("fsdp", "tp"))
        assert spec == P("data", "model")
    assert current_mesh() is None


def test_custom_rules():
    rules = AxisSpec((("batch", ("x",)),))
    assert logical_to_spec((8,), ("batch",), _FakeMesh({"x": 4}),
                           rules) == P("x")
    assert logical_to_spec((8,), ("unknown",), _FakeMesh({"x": 4}),
                           rules) == P(None)


def test_rank_mismatch_raises_descriptive_valueerror():
    """Shape/logical rank disagreement names both, with or without a
    mesh (the guard is not mesh-gated)."""
    with pytest.raises(ValueError) as err:
        logical_to_spec((4, 8), ("batch",), _FakeMesh({"data": 2}))
    msg = str(err.value)
    assert "(4, 8)" in msg and "('batch',)" in msg
    with pytest.raises(ValueError, match="same rank"):
        logical_to_spec((4, 8), ("batch",))        # no mesh: still raises


# ---------------------------------------------------------------------------
# shard_assignment / local_shapes: the op-level contract the sharded
# kernel dispatch plans against
# ---------------------------------------------------------------------------

_MESH = _FakeMesh({"data": 2, "model": 4})
_ATTN = {"B": 4, "S": 128, "T": 128, "H": 8, "KV": 4, "hd": 32}
_ATTN_LOGICAL = {"B": "batch", "H": "heads", "KV": "heads"}


def test_grouped_dims_co_shard():
    """Q heads and KV heads share "heads": both shard by the same factor,
    so the kernel's H/KV ratio (GQA group size) survives partitioning."""
    asn = shard_assignment(_ATTN, _ATTN_LOGICAL, _MESH)
    assert asn.counts["H"] == 4 and asn.counts["KV"] == 4
    assert asn.counts["B"] == 2
    assert asn.axes_of["H"] == ("model",) == asn.axes_of["KV"]
    assert local_shapes(_ATTN, _ATTN_LOGICAL, _MESH) == {
        "B": 2, "S": 128, "T": 128, "H": 2, "KV": 1, "hd": 32}


def test_group_member_indivisible_blocks_the_axis():
    """KV=2 cannot take the 4-way model axis, so H must not either —
    sharding H alone would break the grouped ratio."""
    shapes = dict(_ATTN, KV=2)
    asn = shard_assignment(shapes, _ATTN_LOGICAL, _MESH)
    assert asn.counts["H"] == 1 and asn.counts["KV"] == 1
    assert "H" not in asn.axes_of


def test_size_one_group_member_broadcasts():
    """Mamba-2's single B/C group (or MQA's single KV head) never blocks
    head sharding: size-1 dims replicate and every local head still maps
    to group 0."""
    ssd = {"B": 4, "S": 64, "nh": 8, "hd": 16, "ds": 16, "G": 1}
    logical = {"B": "batch", "nh": "heads", "G": "heads"}
    asn = shard_assignment(ssd, logical, _MESH)
    assert asn.counts["nh"] == 4 and asn.counts["G"] == 1
    assert asn.spec("B", None, "G", None) == P("data", None, None, None)


def test_assignment_axis_used_once():
    """A mesh axis feeds at most one logical axis (first-appearance
    order), mirroring logical_to_spec."""
    shapes = {"E": 8, "H": 8}
    asn = shard_assignment(shapes, {"E": "expert", "H": "heads"}, _MESH)
    assert asn.counts["E"] == 4 and asn.counts["H"] == 1


def test_assignment_spec_matches_counts():
    asn = shard_assignment(_ATTN, _ATTN_LOGICAL, _MESH)
    assert asn.spec("B", None, "H", None) == P("data", None, "model", None)
    assert asn.spec("B", None, "KV", None) == P("data", None, "model", None)
    assert asn.spec("B") == P("data")


def test_local_shapes_without_mesh_is_identity():
    assert current_mesh() is None
    assert local_shapes(_ATTN, _ATTN_LOGICAL) == _ATTN


def test_assignment_unknown_dim_raises():
    with pytest.raises(ValueError, match="names dims"):
        shard_assignment({"B": 4}, {"B": "batch", "G": "heads"}, _MESH)
