"""logical_to_spec divisibility guard + rule behaviour (no fake devices:
uses a (1,1) mesh for plumbing and pure-function checks for the guard)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.parallel.api import (AxisSpec, logical_to_spec,
                                set_mesh, shard, current_mesh)


class _FakeMesh:
    """Duck-typed mesh exposing .shape for guard tests."""
    def __init__(self, shape):
        self.shape = shape


def _spec(shape, logical, mesh_shape):
    return logical_to_spec(shape, logical, _FakeMesh(mesh_shape))


def test_divisible_dims_shard():
    assert _spec((64000, 7168), ("vocab", "fsdp"),
                 {"data": 16, "model": 16}) == P("model", "data")


def test_indivisible_dims_drop():
    # 51865 % 16 != 0 -> vocab axis dropped
    assert _spec((51865, 512), ("vocab", "fsdp"),
                 {"data": 16, "model": 16}) == P(None, "data")


def test_axis_used_once():
    # batch takes pod+data; fsdp (data) already consumed -> dropped
    assert _spec((256, 4096, 16), ("batch", "seq", "fsdp"),
                 {"pod": 2, "data": 16, "model": 16}) \
        == P(("pod", "data"), "model", None)


def test_batch_multi_axis_partial():
    # batch 8 on (pod=2, data=16): pod divides, pod*data doesn't -> pod only
    assert _spec((8, 10), ("batch", None), {"pod": 2, "data": 16}) \
        == P("pod", None)


def test_kv_seq_uses_model_then_data():
    # long_500k: batch 1 -> both axes free for the sequence
    assert _spec((1, 524288, 8, 128), ("batch", "kv_seq", None, None),
                 {"data": 16, "model": 16}) == P(None, ("model", "data"), None,
                                                 None)


def test_missing_axis_ignored():
    assert _spec((128, 128), ("batch", None), {"model": 4}) == P(None, None)


def test_no_mesh_is_noop():
    import jax.numpy as jnp
    assert current_mesh() is None
    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)  # must not raise without a mesh
    assert (y == x).all()


def test_set_mesh_plumbing():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh):
        assert current_mesh() is mesh
        spec = logical_to_spec((16, 16), ("fsdp", "tp"))
        assert spec == P("data", "model")
    assert current_mesh() is None


def test_custom_rules():
    rules = AxisSpec((("batch", ("x",)),))
    assert logical_to_spec((8,), ("batch",), _FakeMesh({"x": 4}),
                           rules) == P("x")
    assert logical_to_spec((8,), ("unknown",), _FakeMesh({"x": 4}),
                           rules) == P(None)
