"""Legacy-parity + new-device tests for the ``repro.arch`` capability layer.

The refactor's no-regression harness: every (gpu, instr) pair in the old
``MI200_CYCLES``/``MI300_CYCLES`` tables must yield identical cycles,
peaks, and supported-instruction sets through the new ``DeviceSpec`` path —
including under ``mfma_scale`` overlays — and the newly registered devices
must be usable end-to-end by ``scoreboard.simulate`` and
``hlo_bridge.predict``.
"""

import pytest

from repro.arch import (DeviceSpec, Overlay, get_device, list_devices,
                        overlay_grid)
from repro.arch.registry import MI200_CYCLES, MI300_CYCLES
from repro.core import isa
from repro.core.hlo_bridge import best_instr, predict_dots, DotOp
from repro.core.machine import as_machine, get_machine
from repro.core.program import mfma
from repro.core.scoreboard import simulate_program
from repro.core.whatif import scale_table

LEGACY_TABLES = {"mi200": MI200_CYCLES, "mi300": MI300_CYCLES}
SCALES = (0.25, 0.5, 1.0, 1.5, 2.0, 3.7)


# ---------------------------------------------------------------------------
# Legacy parity: cycles, supported sets, peaks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gpu", ["mi200", "mi300"])
def test_cycles_parity_all_instructions(gpu):
    spec = get_device(gpu)
    legacy = LEGACY_TABLES[gpu]
    assert set(spec.cycle_table) == set(legacy)
    for name, (cycles, validated) in legacy.items():
        entry = spec.cycle_table[name]
        assert entry.cycles == cycles, name
        assert entry.validated == validated, name
        if not isa.lookup(name).gpr_idx_mode:
            assert spec.mfma_cycles(name) == cycles
            assert isa.mfma_cycles(gpu, name) == cycles


@pytest.mark.parametrize("gpu", ["mi200", "mi300"])
@pytest.mark.parametrize("scale", SCALES)
def test_cycles_parity_under_scale(gpu, scale):
    """The gem5 rounding rule max(1, round(base*scale)) must agree across
    the isa view, the machine facade, and a baked-in overlay."""
    spec = get_device(gpu)
    machine = get_machine(gpu, mfma_scale=scale)
    overlaid = get_machine(gpu).with_overlay(Overlay(mfma_scale=scale))
    for name, (base, _) in LEGACY_TABLES[gpu].items():
        if isa.lookup(name).gpr_idx_mode:
            continue
        expect = max(1, int(round(base * scale)))
        assert isa.mfma_cycles(gpu, name, mfma_scale=scale) == expect
        assert spec.mfma_cycles(name, mfma_scale=scale) == expect
        assert machine.mfma_cycles(name) == expect
        assert overlaid.mfma_cycles(name) == expect


@pytest.mark.parametrize("gpu", ["mi200", "mi300"])
@pytest.mark.parametrize("validated_only", [False, True])
def test_supported_set_parity(gpu, validated_only):
    spec = get_device(gpu)
    legacy = {name for name, (_, v) in LEGACY_TABLES[gpu].items()
              if (v or not validated_only)
              and not isa.lookup(name).gpr_idx_mode}
    assert set(spec.supported_instructions(
        validated_only=validated_only)) == legacy
    assert set(isa.supported_instructions(
        gpu, validated_only=validated_only)) == legacy


@pytest.mark.parametrize("gpu", ["mi200", "mi300", "tpu_v5e"])
def test_peak_parity(gpu):
    spec = get_device(gpu)
    machine = get_machine(gpu)
    assert machine.matrix_flops_per_cycle == pytest.approx(
        spec.matrix_flops_per_cycle)
    assert machine.peak_matrix_tflops == pytest.approx(
        spec.peak_matrix_tflops)


def test_legacy_isa_table_views():
    """isa.MI200_CYCLES / MI300_CYCLES remain importable in the legacy
    {name: (cycles, validated)} form."""
    assert isa.MI200_CYCLES == MI200_CYCLES
    assert isa.MI300_CYCLES == MI300_CYCLES


# ---------------------------------------------------------------------------
# Error contracts (satellite bugfixes)
# ---------------------------------------------------------------------------

def test_supported_instructions_unknown_gpu_error_contract():
    """supported_instructions raises UnsupportedInstructionError for an
    unknown device, consistently with mfma_cycles (not a bare KeyError)."""
    with pytest.raises(isa.UnsupportedInstructionError):
        isa.supported_instructions("no_such_gpu")
    with pytest.raises(isa.UnsupportedInstructionError):
        isa.mfma_cycles("no_such_gpu", "fp32_16x16x16fp16")


def test_scale_table_tpu_clear_error():
    """scale_table on a table-less (TPU) machine raises a clear
    UnsupportedInstructionError, not KeyError: None."""
    with pytest.raises(isa.UnsupportedInstructionError,
                       match="no MFMA cycle table"):
        scale_table(get_machine("tpu_v5e"))


def test_scale_table_explicit_instrs_still_rejects_tableless():
    with pytest.raises(isa.UnsupportedInstructionError):
        scale_table(get_machine("tpu_v5e"),
                    instr_names=["fp32_16x16x16fp16"])


# ---------------------------------------------------------------------------
# New devices: registered and usable end-to-end
# ---------------------------------------------------------------------------

def test_new_devices_registered():
    assert {"mi300x", "tpu_v5p"} <= set(list_devices())


def test_mi300x_is_a_delta_of_mi300():
    base, x = get_device("mi300"), get_device("mi300x")
    assert set(x.cycle_table) == set(base.cycle_table)
    for name, entry in x.cycle_table.items():
        assert entry.cycles == base.cycle_table[name].cycles
        # inherited timing is not hardware-validated on the derived part
        assert not entry.validated
    assert x.cu_count > base.cu_count
    assert x.clock_mhz > base.clock_mhz


def test_new_devices_simulate():
    prog = [mfma("fp32_16x16x16fp16", d="d", a="a", b="b", c="d"),
            mfma("fp32_16x16x16fp16", d="d", a="a", b="b", c="d")]
    for dev in ("mi300x",):
        res = simulate_program(dev, prog)  # by-name coercion
        lat = get_machine(dev).mfma_cycles("fp32_16x16x16fp16")
        assert res.records[1].issue - res.records[0].issue == lat


def test_new_devices_predict():
    dot = DotOp(in_dtype="bf16", batch=1, m=256, n=256, k=256)
    t = {}
    for dev in ("mi300", "mi300x", "tpu_v5e", "tpu_v5p"):
        pred = predict_dots(get_machine(dev), [(dot, 1.0)])
        assert pred.total_mfma > 0
        assert pred.mce_time_s > 0
        t[dev] = pred.mce_time_s
    # more CUs at higher clock must be faster on the same table
    assert t["mi300x"] < t["mi300"]
    # v5p sustains a higher clock than v5e at the same MXU count
    assert t["tpu_v5p"] < t["tpu_v5e"]


def test_new_device_best_instr():
    assert best_instr(get_machine("mi300x"), "bf16") is not None
    assert best_instr(as_machine(get_device("tpu_v5p")), "bf16") is None


# ---------------------------------------------------------------------------
# Overlays
# ---------------------------------------------------------------------------

def test_overlay_compose_multiplies():
    ov = Overlay(mfma_scale=2.0).compose(Overlay(mfma_scale=1.5,
                                                 clock_scale=1.2))
    assert ov.mfma_scale == pytest.approx(3.0)
    assert ov.clock_scale == pytest.approx(1.2)


def test_overlay_table_patch():
    m = get_machine("mi300").with_overlay(
        Overlay(table_patches={"fp32_16x16x16fp16": 8}))
    assert m.mfma_cycles("fp32_16x16x16fp16") == 8
    # untouched entries keep their cycles and provenance
    assert m.mfma_cycles("fp64_16x16x4fp64") == 32
    assert m.spec.cycle_table["fp64_16x16x4fp64"].validated
    assert not m.spec.cycle_table["fp32_16x16x16fp16"].validated


def test_overlay_mem_latency_scale():
    m = get_machine("mi200").with_overlay(Overlay(mem_latency_scale=2.0))
    assert m.l1d_latency == 280
    assert m.lds_latency == 130
    # a memory what-if must NOT slow the vector ALU (compute pipe)
    assert m.valu_latency == get_machine("mi200").valu_latency


def test_overlay_reports_effective_mfma_scale():
    """Prediction.mfma_scale must report the scenario's scale whether it
    arrived via the legacy knob or an Overlay."""
    dot = DotOp(in_dtype="bf16", batch=1, m=64, n=64, k=64)
    via_knob = predict_dots(get_machine("mi300", mfma_scale=2.0),
                            [(dot, 1.0)])
    via_overlay = predict_dots(
        get_machine("mi300", overlay=Overlay(mfma_scale=2.0)), [(dot, 1.0)])
    assert via_knob.mfma_scale == via_overlay.mfma_scale == 2.0
    assert via_knob.mce_time_s == pytest.approx(via_overlay.mce_time_s)


def test_overlay_patch_adds_missing_instruction():
    """A table patch for an instruction the device lacks ADDS support
    (hypothesised-new-instruction what-if), mirroring derive()."""
    assert "fp32_16x16x32fp8" not in get_device("mi200").cycle_table
    m = get_machine("mi200").with_overlay(
        Overlay(table_patches={"fp32_16x16x32fp8": 8}))
    assert m.mfma_cycles("fp32_16x16x32fp8") == 8
    assert not m.spec.cycle_table["fp32_16x16x32fp8"].validated


def test_overlay_preserves_machine_field_tweaks():
    """replace()-tweaked machine fields survive an overlay (no silent
    rebuild from the backing spec)."""
    import dataclasses
    m = dataclasses.replace(get_machine("mi200"), cu_count=10)
    out = m.with_overlay(Overlay(clock_scale=2.0))
    assert out.cu_count == 10
    assert out.clock_mhz == pytest.approx(2 * 1801.0)
    # tweaked topology feeds the peak formula too
    assert out.matrix_flops_per_cycle == pytest.approx(
        get_machine("mi200").matrix_flops_per_cycle * 10 / 60)


def test_specless_machine_rejects_non_mfma_overlay():
    """A hand-built MachineModel (no backing spec) cannot silently drop
    overlay knobs it can't honour."""
    from repro.core.machine import MachineModel
    hb = MachineModel(name="hb", gpu_table="mi200", clock_mhz=1801.0)
    assert hb.with_overlay(Overlay(mfma_scale=2.0)).mfma_scale == 2.0
    with pytest.raises(ValueError):
        hb.with_overlay(Overlay(clock_scale=2.0))


def test_overlay_grid_cartesian():
    grid = overlay_grid(mfma_scale=(0.5, 1, 2), clock_scale=(1, 1.2))
    assert len(grid) == 6
    assert len({(o.mfma_scale, o.clock_scale) for o in grid}) == 6


def test_overlay_grid_rejects_unknown_axis():
    with pytest.raises(TypeError):
        overlay_grid(bogus_scale=(1, 2))


def test_overlay_tpu_analytic_scale():
    """mfma_scale overlays reach the MXU analytic path (no cycle table)."""
    dot = DotOp(in_dtype="bf16", batch=1, m=512, n=512, k=512)
    base = predict_dots(get_machine("tpu_v5e"), [(dot, 1.0)]).mce_time_s
    doubled = predict_dots(
        get_machine("tpu_v5e").with_overlay(Overlay(mfma_scale=2.0)),
        [(dot, 1.0)]).mce_time_s
    assert doubled == pytest.approx(2 * base)


# ---------------------------------------------------------------------------
# Registry hygiene
# ---------------------------------------------------------------------------

def test_every_registered_spec_is_valid():
    for name in list_devices():
        spec = get_device(name)
        assert isinstance(spec, DeviceSpec)
        assert spec.clock_mhz > 0
        assert spec.cu_count >= 1 and spec.simd_per_cu >= 1
        assert spec.has_cycle_table or spec.mxu_count > 0
        for instr, entry in spec.cycle_table.items():
            assert instr in isa.MFMA_REGISTRY, (name, instr)
            assert entry.cycles >= 1
            assert isinstance(entry.validated, bool)
