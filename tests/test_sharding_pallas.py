"""Sharded ``use_pallas=True`` parity: the Pallas kernels on the mesh.

Every catalog-backed mixer (flash attention, decode attention, Mamba-2
SSD, MoE grouped GEMM) must match its GSPMD reference when the kernels
execute under ``shard_map`` on an active mesh, and ``last_decisions()``
must prove the kernel path actually ran sharded — zero ``mesh-sharded``
fallbacks for shardable shapes.  The suite adapts to whatever host
topology exists: the CI mesh leg runs it with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (a (2, 4)
data x model mesh, so batch, heads and experts genuinely partition);
under plain tier-1 (one device) the mesh degenerates to (1, 1) and the
shard_map plumbing still executes with replicated specs.  A subprocess
test pins the real 8-device topology into tier-1 itself, and the
fallback-contract tests pin when the legacy ``mesh-sharded`` reason is
still allowed to appear: kernels without a logical-axis contract and
local shards that genuinely fail the tiling/VMEM contract.
"""

import dataclasses
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.arch import get_device
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig, MoESpec, SSMSpec
from repro.parallel.api import set_mesh

KEY = jax.random.PRNGKey(0)

_TOL = {"float32": dict(rtol=2e-3, atol=2e-3),
        "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _mesh():
    """Largest (data, model) mesh the host supports; (1, 1) on one CPU."""
    n = jax.device_count()
    model = 4 if n % 4 == 0 else (2 if n % 2 == 0 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))


def _close(got, want, dtype="float32"):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_TOL[dtype])


def _assert_kernel_sharded(decs, kernel):
    dec = decs.get(kernel)
    assert dec is not None, f"{kernel}: no dispatch decision recorded"
    assert dec.use_kernel, f"{kernel}: fell back ({dec.reason})"
    assert dec.sharded and dec.plan is not None and dec.local_dims
    assert "mesh-sharded" not in dec.reason
    return dec


# ---------------------------------------------------------------------------
# mixer parity under the mesh
# ---------------------------------------------------------------------------

def _attn_cfgs(dtype="float32"):
    # 8 Q / 4 KV heads: both divide the 4-way model axis, so heads
    # genuinely shard on the 8-device topology (and the GQA ratio holds)
    cfg = ModelConfig(name="shard-parity", family="dense", n_layers=2,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=512, head_dim=32, dtype=dtype)
    return cfg, dataclasses.replace(cfg, use_pallas=True)


@pytest.mark.parametrize("S,dtype", [(128, "float32"), (100, "float32"),
                                     (128, "bfloat16")])
def test_attn_train_sharded_parity(S, dtype):
    """S=100 is the ragged case: each shard pads/masks its local block."""
    cfg, cfgp = _attn_cfgs(dtype)
    mesh = _mesh()
    w = attn.init_attn(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, S, cfg.d_model),
                          jnp.float32).astype(
                              jnp.bfloat16 if dtype == "bfloat16"
                              else jnp.float32)
    pos = jnp.arange(S)
    with set_mesh(mesh):
        with kdispatch.decision_scope() as decs:
            y_pal = attn.attn_train(cfgp, w, x, pos)
        dec = _assert_kernel_sharded(decs, "flash_attention")
        mm = mesh.shape["model"]
        assert dec.local_dims["H"] == cfg.n_heads // mm
        assert dec.local_dims["KV"] == cfg.n_kv_heads // mm
        y_ref = attn.attn_train(cfg, w, x, pos)
    _close(y_pal, y_ref, dtype)


def test_attn_decode_sharded_parity():
    cfg, cfgp = _attn_cfgs()
    mesh = _mesh()
    w = attn.init_attn(cfg, KEY)
    cache = attn.init_attn_cache(cfg, 4, 128)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model),
                          jnp.float32)
    with set_mesh(mesh):
        with kdispatch.decision_scope() as decs:
            y_pal, c_pal = attn.attn_decode(cfgp, w, x, cache,
                                            jnp.int32(37))
        dec = _assert_kernel_sharded(decs, "decode_attention")
        assert dec.local_dims["H"] == cfg.n_heads // mesh.shape["model"]
        y_ref, c_ref = attn.attn_decode(cfg, w, x, cache, jnp.int32(37))
    _close(y_pal, y_ref)
    np.testing.assert_array_equal(np.asarray(c_pal["k"]),
                                  np.asarray(c_ref["k"]))


@pytest.mark.parametrize("S", [64, 52])
def test_ssm_train_sharded_parity(S):
    """nh=8 heads shard; the single B/C group (G=1) broadcasts."""
    cfg = ModelConfig(name="shard-ssm", family="ssm", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
                      vocab_size=512, dtype="float32",
                      ssm=SSMSpec(d_state=16, head_dim=16, chunk=32))
    cfgp = dataclasses.replace(cfg, use_pallas=True)
    mesh = _mesh()
    w = ssm_mod.init_ssm(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, S, cfg.d_model),
                          jnp.float32)
    with set_mesh(mesh):
        with kdispatch.decision_scope() as decs:
            y_pal = ssm_mod.ssm_train(cfgp, w, x)
        dec = _assert_kernel_sharded(decs, "mamba2_ssd")
        assert dec.local_dims["nh"] == 8 // mesh.shape["model"]
        assert dec.local_dims["G"] == 1
        y_ref = ssm_mod.ssm_train(cfg, w, x)
    _close(y_pal, y_ref)


def test_moe_apply_sharded_parity():
    """E=8 experts shard over the model axis; the dispatch/combine
    gathers (the EP collectives) stay in the surrounding XLA program."""
    cfg = ModelConfig(name="shard-moe", family="moe", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=512, head_dim=32, dtype="float32",
                      moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=64))
    cfgp = dataclasses.replace(cfg, use_pallas=True)
    mesh = _mesh()
    w = moe_mod.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 32, cfg.d_model),
                          jnp.float32)
    with set_mesh(mesh):
        with kdispatch.decision_scope() as decs:
            y_pal, aux_pal = moe_mod.moe_apply(cfgp, w, x)
        dec = _assert_kernel_sharded(decs, "moe_gmm")
        assert dec.local_dims["E"] == 8 // mesh.shape["model"]
        y_ref, aux_ref = moe_mod.moe_apply(cfg, w, x)
    _close(y_pal, y_ref)
    np.testing.assert_allclose(float(aux_pal), float(aux_ref), rtol=1e-5)


def test_sharded_kernels_survive_jit():
    """The launch path jits the step function: decisions still record at
    trace time and the shard_map kernels compile inside the jit."""
    cfg, cfgp = _attn_cfgs()
    mesh = _mesh()
    w = attn.init_attn(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(128)
    step = jax.jit(lambda x: attn.attn_train(cfgp, w, x, pos))
    with set_mesh(mesh):
        with kdispatch.decision_scope() as decs:
            y_pal = step(x)
        _assert_kernel_sharded(decs, "flash_attention")
        y_ref = attn.attn_train(cfg, w, x, pos)
    _close(y_pal, y_ref)


# ---------------------------------------------------------------------------
# fallback contract: when "mesh-sharded" may still appear
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Duck-typed mesh (.shape only) — dispatch plans without devices."""

    def __init__(self, shape):
        self.shape = shape


def test_no_logical_contract_keeps_legacy_fallback():
    """paged_decode_attention has no logical map: a bare pallas_call is
    single-device, so the whole-op reference fallback survives."""
    with kdispatch.decision_scope():
        dec = kdispatch.decide(
            "paged_decode_attention",
            {"B": 2, "T": 512, "H": 4, "KV": 2, "hd": 32, "page": 128},
            sharded=True, mesh=_FakeMesh({"data": 2, "model": 4}))
    assert not dec.use_kernel
    assert "mesh-sharded" in dec.reason
    assert "GSPMD cannot partition" in dec.reason


def test_untileable_local_shard_falls_back_with_planner_reason():
    """A local shard whose working set busts VMEM is genuinely
    untileable: the fallback reason carries the planner's error."""
    tiny = get_device("tpu_v5e").derive("tpu_nano_vmem", vmem_bytes=1 << 10)
    with kdispatch.decision_scope():
        dec = kdispatch.decide(
            "flash_attention",
            {"B": 2, "S": 4096, "T": 4096, "H": 8, "KV": 4, "hd": 128},
            device=tiny, sharded=True,
            mesh=_FakeMesh({"data": 2, "model": 4}))
    assert not dec.use_kernel
    assert "mesh-sharded local shard" in dec.reason


def test_misaligned_local_shard_without_pad_falls_back():
    """pad=False keeps the strict tiling contract per shard: a ragged
    local dim is a recorded fallback, not an exception."""
    with kdispatch.decision_scope():
        dec = kdispatch.decide(
            "moe_gmm", {"E": 4, "C": 20, "K": 100, "N": 60},
            pad=False, sharded=True, mesh=_FakeMesh({"model": 4}))
    assert not dec.use_kernel
    assert "mesh-sharded local shard" in dec.reason


def test_shardable_shapes_never_hit_mesh_fallback():
    """The acceptance bar: for shardable shapes the sharded Decision is
    a kernel Decision — the blanket mesh-sharded fallback is gone."""
    with kdispatch.decision_scope() as decs:
        for kernel, shapes in (
            ("flash_attention", {"B": 4, "S": 128, "T": 128, "H": 8,
                                 "KV": 4, "hd": 32}),
            ("decode_attention", {"B": 4, "T": 128, "H": 8, "KV": 4,
                                  "hd": 32}),
            ("mamba2_ssd", {"B": 4, "S": 64, "nh": 8, "hd": 16, "ds": 16,
                            "G": 1}),
            ("moe_gmm", {"E": 8, "C": 64, "K": 128, "N": 128}),
        ):
            kdispatch.decide(kernel, shapes, sharded=True,
                             mesh=_FakeMesh({"data": 2, "model": 4}))
    assert all(d.use_kernel and d.sharded for d in decs.values()), \
        {k: d.reason for k, d in decs.items() if not d.use_kernel}


# ---------------------------------------------------------------------------
# the real 8-device topology, pinned into tier-1 via a subprocess
# ---------------------------------------------------------------------------

def test_sharded_parity_8_devices():
    """Heads shard 4-way and batch 2-way on a true (2, 4) host mesh; the
    kernel output matches the GSPMD reference and the decision record
    proves the shard_map path ran."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_CPU_F32_DOTS"] = "1"
        import sys; sys.path.insert(0, "src")
        import dataclasses
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.kernels import dispatch as kdispatch
        from repro.models import attention as attn
        from repro.models.config import ModelConfig
        from repro.parallel.api import set_mesh

        assert jax.device_count() == 8
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig(name="m", family="dense", n_layers=2,
                          d_model=256, n_heads=8, n_kv_heads=4, d_ff=256,
                          vocab_size=512, head_dim=32, dtype="float32")
        cfgp = dataclasses.replace(cfg, use_pallas=True)
        w = attn.init_attn(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, cfg.d_model),
                              jnp.float32)
        pos = jnp.arange(128)
        with set_mesh(mesh):
            with kdispatch.decision_scope() as decs:
                y_pal = attn.attn_train(cfgp, w, x, pos)
            dec = decs["flash_attention"]
            assert dec.use_kernel and dec.sharded, dec.reason
            assert dec.local_dims == {"B": 2, "S": 128, "T": 128, "H": 2,
                                      "KV": 1, "hd": 32}, dec.local_dims
            y_ref = attn.attn_train(cfg, w, x, pos)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=600)
    assert "OK" in out.stdout, out.stderr[-2000:]
